"""Fault-tolerant checkpointing: msgpack + zstd, atomic, async, and
topology-elastic (a checkpoint saved under one mesh restores under any other).

Format: one directory per step,
    ckpt_dir/step_000123/
        manifest.json        (treedef, shapes, dtypes, step, extra metadata)
        data.msgpack.zst     (flat list of raw little-endian buffers)
        _COMMITTED           (written last; restore ignores dirs without it)

Leaves are gathered to host (global arrays) before serialization, so the
restore path is free to re-shard onto a different mesh/topology — the elastic
restart path.  Saves are atomic (tmp dir + rename) and optionally async
(background thread), so a mid-save failure never corrupts the latest
committed checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

import zlib

try:
    import zstandard
    _HAVE_ZSTD = True
except ImportError:                   # gate: container without zstd bindings
    zstandard = None
    _HAVE_ZSTD = False


def _compress(payload: bytes) -> tuple[bytes, str]:
    """Returns (bytes, codec); codec is recorded in the manifest so restore
    never has to guess the frame format."""
    if _HAVE_ZSTD:
        return zstandard.ZstdCompressor(level=3).compress(payload), "zstd"
    return zlib.compress(payload, 3), "zlib"


def _decompress(data: bytes, codec: str) -> bytes:
    if codec == "zlib":
        return zlib.decompress(data)
    if codec == "zstd":
        if not _HAVE_ZSTD:
            raise RuntimeError(
                "checkpoint was written with zstd but the zstandard module "
                "is not installed in this environment")
        return zstandard.ZstdDecompressor().decompress(data)
    raise ValueError(f"unknown checkpoint codec {codec!r}")


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = [jax.tree_util.keystr(p) for p, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def tree_to_host(tree: Any) -> Any:
    """Gather every leaf to host as a materialized ``np.ndarray`` (sharded
    globals gather fully).  Shared by the serializer below and the serving
    scheduler's rolling fault-recovery snapshots — a host copy is the only
    safe snapshot under buffer donation (a device reference would alias the
    very buffer the next dispatch overwrites)."""
    return jax.tree_util.tree_map(
        lambda leaf: np.asarray(jax.device_get(leaf)), tree)


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[dict] = None,
         async_save: bool = False) -> threading.Thread | None:
    """Serialize ``tree`` (gathered to host) atomically under ``ckpt_dir``."""
    paths, leaves, _ = _flatten_with_paths(tree)
    host_leaves = jax.tree_util.tree_leaves(tree_to_host(leaves))

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        payload = msgpack.packb([leaf.tobytes() for leaf in host_leaves])
        blob, codec = _compress(payload)
        manifest = {
            "step": step,
            "paths": paths,
            "shapes": [list(leaf.shape) for leaf in host_leaves],
            "dtypes": [str(leaf.dtype) for leaf in host_leaves],
            "codec": codec,
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "data.msgpack.zst"), "wb") as f:
            f.write(blob)
        with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_save:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp") and \
                os.path.exists(os.path.join(ckpt_dir, d, "_COMMITTED")):
            steps.append(int(d[5:]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, target_tree: Any, step: Optional[int] = None,
            shardings: Any = None) -> tuple[Any, dict]:
    """Restore into the structure of ``target_tree``; re-shards if
    ``shardings`` (a matching pytree of NamedSharding) is given — this is the
    elastic path: the checkpoint has no knowledge of the saving topology.

    Returns (tree, manifest_extra).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    with open(os.path.join(d, "data.msgpack.zst"), "rb") as f:
        payload = msgpack.unpackb(
            _decompress(f.read(), manifest.get("codec", "zstd")))
    paths, leaves, treedef = _flatten_with_paths(target_tree)
    if paths != manifest["paths"]:
        missing = set(manifest["paths"]) ^ set(paths)
        raise ValueError(f"checkpoint/model structure mismatch: {sorted(missing)[:5]}")
    out = []
    flat_sh = (treedef.flatten_up_to(shardings) if shardings is not None
               else [None] * len(leaves))
    for buf, shape, dtype, tgt, sh in zip(payload, manifest["shapes"],
                                          manifest["dtypes"], leaves, flat_sh):
        arr = np.frombuffer(buf, dtype=np.dtype(dtype)).reshape(shape)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr))
    return treedef.unflatten(out), manifest["extra"]
