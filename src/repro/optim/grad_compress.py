"""Error-feedback int8 gradient compression for cross-pod all-reduce.

At 1000+-node scale the pod-level gradient all-reduce crosses DCN links that
are ~10x slower than in-pod ICI; compressing the cross-pod leg 4x (fp32->int8
with per-leaf scale) with error feedback [1-bit Adam / EF-SGD lineage] keeps
convergence while cutting the dominant collective term.

Implemented as a shard_map-compatible primitive: grads are quantized, psum'd
over the named axis in int32, dequantized, and the quantization residual is
carried to the next step (error feedback).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_residual(grads):
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_decompress(g: jax.Array, residual: jax.Array, scale: jax.Array):
    """Quantize (g + residual) with a given shared scale."""
    gf = g.astype(jnp.float32) + residual
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, gf - q.astype(jnp.float32) * scale


def compressed_psum(grads, residuals, axis_name: str):
    """Error-feedback compressed mean over ``axis_name`` (use in shard_map).

    Returns (reduced_grads, new_residuals).
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        # shared scale across the axis so the int32 sum is exact
        scale = jax.lax.pmax(jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12),
                             axis_name) / 127.0
        q, r_new = compress_decompress(g, r, scale)
        # int32 sum avoids overflow (<= 127 * n per element)
        s = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return (s.astype(jnp.float32) * scale / n), r_new

    flat, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out = [one(g, r) for g, r in zip(flat, flat_r)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
