"""AdamW in pure JAX, pytree-native, shard-friendly (m/v inherit param specs)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init(params, keep_master: bool = False) -> dict:
    """``keep_master=True``: params may be bf16 for compute/all-gather; a
    fp32 master copy lives in the optimizer state (mixed-precision FSDP —
    halves the per-layer parameter all-gather volume)."""
    def zeros(p):
        return jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, jnp.float32), p)
    st = {"m": zeros(params), "v": zeros(params),
          "step": jnp.zeros((), jnp.int32)}
    if keep_master:
        st["master"] = jax.tree_util.tree_map(
            lambda x: x.astype(jnp.float32), params)
    return st


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(leaf.astype(jnp.float32)))
                        for leaf in leaves))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gn


def update(params, grads, opt_state: dict, lr: jax.Array,
           cfg: AdamWConfig = AdamWConfig()):
    """Returns (new_params, new_opt_state, grad_norm)."""
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    step = opt_state["step"] + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    src = opt_state.get("master", params)   # fp32 master if present
    flat_p, treedef = jax.tree_util.tree_flatten(src)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_src = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    if "master" in opt_state:
        new_state["master"] = new_src
        new_p = jax.tree_util.tree_map(
            lambda x, p: x.astype(p.dtype), new_src, params)
    else:
        new_p = new_src
    return new_p, new_state, gn
