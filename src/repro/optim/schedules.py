"""LR schedules: cosine, and WSD (Warmup-Stable-Decay) from MiniCPM
[arXiv:2404.06395] — the schedule the minicpm-2b config trains with."""
from __future__ import annotations

import jax.numpy as jnp


def cosine(step, *, peak_lr: float, warmup: int, total: int,
           final_frac: float = 0.1):
    s = step.astype(jnp.float32)
    warm = peak_lr * s / max(warmup, 1)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = final_frac * peak_lr + (1 - final_frac) * peak_lr \
        * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < warmup, warm, cos)


def wsd(step, *, peak_lr: float, warmup: int, stable: int, decay: int,
        final_frac: float = 0.1):
    """Warmup -> constant ("stable") -> short exponential-ish decay tail.

    MiniCPM: decay over the last ~10% of tokens; we use the paper's
    f(s) in the decay branch: peak * final_frac ** ((s - w - st)/decay).
    """
    s = step.astype(jnp.float32)
    warm = peak_lr * s / max(warmup, 1)
    dec_prog = jnp.clip((s - warmup - stable) / max(decay, 1), 0.0, 1.0)
    dec = peak_lr * (final_frac ** dec_prog)
    return jnp.where(s < warmup, warm,
                     jnp.where(s < warmup + stable, peak_lr, dec))


def make(name: str, **kw):
    fn = {"cosine": cosine, "wsd": wsd}[name]
    return lambda step: fn(step, **kw)
