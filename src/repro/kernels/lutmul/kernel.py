"""Pallas TPU kernels: LUT-based quantized matmul (paper Sec. 3.5, TPU-adapted).

``lutmul``: the faithful adaptation — weights stationary in VMEM as packed
int4 nibbles, multiplication performed by *gathering* from a 256-entry product
table (the VMEM analogue of the paper's LUT6 constant multipliers), int32
accumulation, K-innermost grid with output-block revisiting.

``int_matmul``: the "DSP packing" baseline — int8 x int8 MXU dot with int32
accumulation under identical tiling, so the bench comparison isolates the
multiplication mechanism.

Block shapes are MXU/VPU aligned: (bm, bk, bn) multiples of (8, 128, 128) —
int8 operand tiles use (32, 128) native tiling on TPU; the defaults keep the
per-block VMEM footprint under ~1.5 MB:
  a tile   bm*bk          (uint8)
  w tile   bk*bn/2        (uint8, packed)
  out tile bm*bn*4        (int32)
  table    256*4 = 1 KiB
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _lutmul_body(a_ref, w_ref, t_ref, out_ref, *, unroll: int = 8):
    """Grid: (M/bm, N/bn, K/bk); K is the innermost ('arbitrary') dimension."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...].astype(jnp.int32)                 # [bm, bk] 4-bit codes
    wp = w_ref[...].astype(jnp.int32)                # [bk//2, bn] packed
    w_lo = wp & 0xF
    w_hi = (wp >> 4) & 0xF
    w = jnp.stack([w_lo, w_hi], axis=1).reshape(-1, wp.shape[1])  # [bk, bn]
    table = t_ref[...]                               # [256] int32

    bk = a.shape[1]

    def body(i, acc):
        # The LUT6 analogue: product via table gather, not multiplication.
        idx = (w[i, :][None, :] << 4) | a[:, i][:, None]          # [bm, bn]
        return acc + jnp.take(table, idx, axis=0)

    acc = jax.lax.fori_loop(0, bk, body,
                            jnp.zeros(out_ref.shape, jnp.int32),
                            unroll=unroll)
    out_ref[...] += acc


def lutmul_pallas(a_codes: jax.Array, w_packed: jax.Array, table: jax.Array,
                  *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                  bk: int = DEFAULT_BK, interpret: bool = True) -> jax.Array:
    """a_codes: [M, K] uint8; w_packed: [K//2, N] uint8; table: [256] int32.

    Shapes must be pre-padded to block multiples (ops.py handles padding).
    """
    M, K = a_codes.shape
    N = w_packed.shape[1]
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        _lutmul_body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((256,), lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        interpret=interpret,
    )(a_codes, w_packed, table)


def _int_matmul_body(a_ref, w_ref, out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...]
    w = w_ref[...]
    out_ref[...] += jax.lax.dot_general(
        a, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def int_matmul_pallas(a: jax.Array, w: jax.Array, *, bm: int = DEFAULT_BM,
                      bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
                      interpret: bool = True) -> jax.Array:
    """a: [M, K] int8; w: [K, N] int8 -> int32 [M, N]."""
    M, K = a.shape
    N = w.shape[1]
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        _int_matmul_body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        interpret=interpret,
    )(a, w)
