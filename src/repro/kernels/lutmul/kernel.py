"""Pallas TPU kernels: LUT-based quantized matmul (paper Sec. 3.5, TPU-adapted).

``lutmul`` (one-hot/bitplane contraction, the default): the table lookup
re-expressed as a tensor contraction so it runs on the MXU instead of scalar
gathers — the LUT-GEMM / T-MAC move.  For codes ``a[m,k]`` (4-bit
activations) and ``w[k,n]`` (4-bit weights) the accumulator is

    acc[m,n] = sum_k T[w[k,n], a[m,k]]
             = sum_{k,b} bit_b(a[m,k]) * TW[(k,b), n]            (b = 0..3)
    TW[(k,b), n] = sum_{w'} onehot(w[k,n]==w') * T[w', 2^b]

i.e. two ``dot_general`` calls per block: one-hot weight codes select their
four power-of-two partial products ``T[w, 2^b]`` from the product table (a
[bk*bn, 16] x [16, 4] dot — the activation-code-8 column carries the top
bit's sign, so signed vs unsigned activations is purely a table-layout
choice), then bitplaned activation nibbles select-and-reduce over K (a
[bm, bk*4] x [bk*4, bn] dot with int32 accumulation).  Multiplication is
still performed by *selection from the product table* — the faithful LUT
semantics — but the selection is a contraction the MXU executes natively:
on TPU both dots are int8 (every operand value fits int8).  The MAC count is
4x an int8 matmul (the price of selection); the serial per-row gather loop
it replaces is ~5-8x slower even in interpret mode and far worse on real
hardware.

``lutmul_tmac``: the second formulation — T-MAC/BitNet-style *weight-plane*
decomposition against *activation-group* partial-sum tables.  Weights are
stored as P binary bitplanes with static integer coefficients
(``core.lut.plane_decomposition``: ``w = sum_b coeff_b * plane_b + const``),
activations are grouped into g-element chunks along K, and each block
precomputes the partial-sum table

    T[m, kg, c] = sum_{i<g} bit_i(c) * a[m, kg*g + i]       (c = 0..2^g-1)

(the T-MAC ``LUT[n, k, Abits]`` table, built in-VMEM per block with one
tiny [bm*K/g, g] x [g, 2^g] dot — N-independent).  Each weight plane's
g-bit group codes then *select* from T via a one-hot contraction and the
coefficients fold into the one-hot operand, so the whole thing is ONE
``[bm, P * K/g * 2^g] x [P * K/g * 2^g, bn]`` MXU dot:

    acc[m,n] = sum_{b,kg} coeff_b * T[m, kg, gcode_b(kg, n)]  (+ const * sum_k a[m,k])

MAC cost per output is ``P * (2^g / g) * K`` — **linear in the weight bit
count P** where the one-hot kernel above is flat at ``4K`` regardless of
weight bits: w2 does half the MXU work of w4, ternary (2 planes) matches
w2, and binary w1 halves it again.  ``g=1`` degenerates the table to the
activation vector itself ({0, a}), so the kernel skips materializing T and
contracts the coefficient-scaled planes directly (inner dim ``P * K`` — the
cheapest MXU realization; ``g>=2`` trades more inner dim for the faithful
wide-input-LUT shape, PolyLUT-Add style).  On TPU both operands fit int8
for a4 activations and g <= 4 (|T| <= 8g <= 32, |coeff| <= 8); a8
activations require g=1 (ops.py clamps).

``lutmul_gather``: the previous faithful-but-serial adaptation — a per-k
``jnp.take`` loop over the 256-entry table — retained as the A/B baseline
for ``benchmarks/kernel_bench.py``.

``lutmul_fused`` / ``int_matmul_fused``: the same kernels with the dequant
epilogue fused in — per-token activation scale [bm, 1] and per-channel weight
scale [1, bn] applied to the int32 accumulator at the last K step, writing
``out_dtype`` directly so callers never materialize a separate fp32 [M, N]
intermediate.

``int_matmul``: the "DSP packing" baseline — int8 x int8 MXU dot with int32
accumulation under identical tiling, so the bench comparison isolates the
multiplication mechanism.

Block shapes are MXU/VPU aligned: (bm, bk, bn) multiples of (8, 128, 128);
the defaults keep the per-block VMEM footprint under ~2 MB:
  a tile      bm*bk            (uint8)
  a one-hot   bm*bk*16         (int8)
  w tile      bk*bn/2          (uint8, packed)
  TW tile     bk*16*bn         (int8)
  acc tile    bm*bn*4          (int32)
  table       16*16 int8/int32
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _unpack_codes(wp: jax.Array) -> jax.Array:
    """[bk//2, bn] packed nibbles -> [bk, bn] int32 codes (k-major)."""
    w_lo = wp & 0xF
    w_hi = (wp >> 4) & 0xF
    return jnp.stack([w_lo, w_hi], axis=1).reshape(-1, wp.shape[1])


def _onehot_contract(a: jax.Array, wp: jax.Array, t2: jax.Array,
                     contract_dtype=jnp.float32) -> jax.Array:
    """One block of the one-hot/bitplane LUT contraction (module docstring).

    a: [bm, bk] int32 codes; wp: [bk//2, bn] packed codes; t2: [16, 16] int32
    product table (row = weight code, col = activation code).  Returns the
    int32 [bm, bn] partial accumulator.

    ``contract_dtype``: int8 on the TPU path (both dots are MXU-native int8
    with int32 accumulation — every value involved fits int8); float32 in
    interpret mode, where XLA:CPU has no fast int8 GEMM.  f32 accumulation is
    exact here: per-block partial sums are bounded by bk * 64 << 2^24.
    """
    bm, bk = a.shape
    w = _unpack_codes(wp.astype(jnp.int32))                    # [bk, bn]
    bn = w.shape[1]
    # selection stage: one-hot weight codes pick their 4 power-of-two partial
    # products T[w, 2^b] from the product table (T[w, 8] carries the sign of
    # the activation top bit: -8w for signed codes, +8w for unsigned — the
    # table layout, not the kernel, decides the signedness)
    cols = jnp.stack([t2[:, 1], t2[:, 2], t2[:, 4], t2[:, 8]],
                     axis=1).astype(contract_dtype)            # [16, 4]
    codes = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 16), 2)
    w_oh = (w[:, :, None] == codes).reshape(bk * bn, 16).astype(contract_dtype)
    tw = jax.lax.dot_general(
        w_oh, cols, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32
        if contract_dtype == jnp.float32 else jnp.int32)       # [bk*bn, 4]
    tw = tw.astype(contract_dtype).reshape(
        bk, bn, 4).transpose(0, 2, 1).reshape(bk * 4, bn)
    # accumulation stage: bitplane the activation nibbles and contract —
    # the MXU only ever selects and sums table entries, never multiplies
    shifts = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 4), 2)
    a_bits = ((a[:, :, None] >> shifts) & 1).reshape(
        bm, bk * 4).astype(contract_dtype)
    acc = jax.lax.dot_general(
        a_bits, tw, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32
        if contract_dtype == jnp.float32 else jnp.int32)       # [bm, bn]
    return acc.astype(jnp.int32)


def _lutmul_onehot_body(a_ref, w_ref, t_ref, out_ref, *,
                        contract_dtype=jnp.float32):
    """Grid: (M/bm, N/bn, K/bk); K is the innermost ('arbitrary') dimension."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += _onehot_contract(a_ref[...].astype(jnp.int32),
                                     w_ref[...], t_ref[...], contract_dtype)


def _lutmul_gather_body(a_ref, w_ref, t_ref, out_ref, *, unroll: int = 8):
    """The retained serial baseline: per-k row gathers from the flat table."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...].astype(jnp.int32)                 # [bm, bk] 4-bit codes
    w = _unpack_codes(w_ref[...].astype(jnp.int32))  # [bk, bn]
    table = t_ref[...].reshape(-1)                   # [256] int32

    bk = a.shape[1]

    def body(i, acc):
        # the LUT6 analogue, literally: product via table gather per row
        idx = (w[i, :][None, :] << 4) | a[:, i][:, None]          # [bm, bn]
        return acc + jnp.take(table, idx, axis=0)

    acc = jax.lax.fori_loop(0, bk, body,
                            jnp.zeros(out_ref.shape, jnp.int32),
                            unroll=unroll)
    out_ref[...] += acc


def lutmul_pallas(a_codes: jax.Array, w_packed: jax.Array, table: jax.Array,
                  *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                  bk: int = DEFAULT_BK, impl: str = "onehot",
                  interpret: bool = True) -> jax.Array:
    """a_codes: [M, K] uint8; w_packed: [K//2, N] uint8; table: [16, 16] int32.

    Shapes must be pre-padded to block multiples (ops.py handles padding).
    ``impl``: "onehot" (MXU contraction) | "gather" (serial A/B baseline).
    """
    M, K = a_codes.shape
    N = w_packed.shape[1]
    grid = (M // bm, N // bn, K // bk)
    cd = jnp.float32 if interpret else jnp.int8
    body = (functools.partial(_lutmul_onehot_body, contract_dtype=cd)
            if impl == "onehot" else _lutmul_gather_body)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((16, 16), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        interpret=interpret,
    )(a_codes, w_packed, table)


# ---------------------------------------------------------------------------
# T-MAC formulation: weight bitplanes x activation-group partial-sum tables
# (module docstring) — kernel cost linear in the weight bit count
# ---------------------------------------------------------------------------


def _tmac_contract(a: jax.Array, wp: jax.Array, coeffs: tuple[int, ...],
                   g: int, contract_dtype=jnp.float32) -> jax.Array:
    """One block of the tmac contraction (WITHOUT the const correction).

    a: [bm, bk] int32 signed activation codes; wp: [P, bk//8, bn] packed
    bitplanes; coeffs: static per-plane integer coefficients.  Returns the
    int32 [bm, bn] partial accumulator ``sum_b coeff_b * (a . plane_b)``.
    """
    n_planes = wp.shape[0]
    bm, bk = a.shape
    bn = wp.shape[-1]
    # unpack bitplanes: [P, bk//8, bn] bytes -> [P, bk, bn] {0, 1}
    shifts = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 8, 1), 2)
    w = ((wp.astype(jnp.int32)[:, :, None, :] >> shifts) & 1) \
        .reshape(n_planes, bk, bn)
    pref = jnp.float32 if contract_dtype == jnp.float32 else jnp.int32
    if g == 1:
        # degenerate table T[m, k, {0,1}] = {0, a}: contract the
        # coefficient-scaled planes directly (inner dim P * bk)
        ws = jnp.concatenate(
            [w[p] * coeffs[p] for p in range(n_planes)],
            axis=0).astype(contract_dtype)                      # [P*bk, bn]
        at = jnp.concatenate([a] * n_planes,
                             axis=1).astype(contract_dtype)     # [bm, P*bk]
        acc = jax.lax.dot_general(at, ws, (((1,), (0,)), ((), ())),
                                  preferred_element_type=pref)
        return acc.astype(jnp.int32)
    kg, c = bk // g, 1 << g
    # table stage: T[m, kg, c] = sum_i bit_i(c) * a[m, kg*g+i] — one tiny
    # N-independent dot builds every group's 2^g partial sums
    bitsel = ((jax.lax.broadcasted_iota(jnp.int32, (g, c), 1)
               >> jax.lax.broadcasted_iota(jnp.int32, (g, c), 0)) & 1)
    table = jax.lax.dot_general(
        a.reshape(bm * kg, g).astype(contract_dtype),
        bitsel.astype(contract_dtype), (((1,), (0,)), ((), ())),
        preferred_element_type=pref)                            # [bm*kg, c]
    table = table.astype(contract_dtype).reshape(bm, kg * c)
    # selection stage: per-plane g-bit group codes one-hot against the
    # table, coefficients folded into the one-hot operand -> ONE dot
    gsh = jax.lax.broadcasted_iota(jnp.int32, (1, 1, g, 1), 2)
    gcodes = jnp.sum(w.reshape(n_planes, kg, g, bn) << gsh,
                     axis=2)                                    # [P, kg, bn]
    codes = jax.lax.broadcasted_iota(jnp.int32, (1, c, 1), 1)
    sel = jnp.concatenate(
        [(gcodes[p][:, None, :] == codes).astype(jnp.int32) * coeffs[p]
         for p in range(n_planes)],
        axis=0).astype(contract_dtype).reshape(n_planes * kg * c, bn)
    at = jnp.concatenate([table] * n_planes, axis=1)            # plane-major
    acc = jax.lax.dot_general(at, sel, (((1,), (0,)), ((), ())),
                              preferred_element_type=pref)
    return acc.astype(jnp.int32)


def _tmac_block(a_ref, w_ref, *, coeffs, const, g, contract_dtype):
    """Shared block body: tmac contraction + the binary-coding const
    correction (``const * sum_k a[m, k]``, exact per K block since padded
    activation codes are zero)."""
    a = a_ref[...].astype(jnp.int32)
    acc = _tmac_contract(a, w_ref[...], coeffs, g, contract_dtype)
    if const:
        acc = acc + const * jnp.sum(a, axis=1, keepdims=True)
    return acc


def _lutmul_tmac_body(a_ref, w_ref, out_ref, *, coeffs, const, g,
                      contract_dtype=jnp.float32):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += _tmac_block(a_ref, w_ref, coeffs=coeffs, const=const,
                                g=g, contract_dtype=contract_dtype)


def lutmul_tmac_pallas(a_q: jax.Array, w_planes: jax.Array, *,
                       coeffs: tuple[int, ...], const: int = 0, g: int = 2,
                       bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                       bk: int = DEFAULT_BK,
                       interpret: bool = True) -> jax.Array:
    """a_q: [M, K] int8 signed activation codes; w_planes: [P, K//8, N]
    packed bitplanes (core.lut.pack_bitplanes layout).  Shapes pre-padded to
    block multiples (ops.py pads); ``bk % (8 * g) == 0`` required."""
    M, K = a_q.shape
    n_planes, _, N = w_planes.shape
    if bk % (8 * max(g, 1)):
        raise ValueError(f"tmac needs bk % (8*g) == 0, got bk={bk} g={g}")
    grid = (M // bm, N // bn, K // bk)
    cd = jnp.float32 if interpret else jnp.int8
    body = functools.partial(_lutmul_tmac_body, coeffs=tuple(coeffs),
                             const=const, g=g, contract_dtype=cd)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((n_planes, bk // 8, bn), lambda i, j, k: (0, k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        interpret=interpret,
    )(a_q, w_planes)


def _int_matmul_body(a_ref, w_ref, out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...]
    w = w_ref[...]
    out_ref[...] += jax.lax.dot_general(
        a, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def int_matmul_pallas(a: jax.Array, w: jax.Array, *, bm: int = DEFAULT_BM,
                      bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
                      interpret: bool = True) -> jax.Array:
    """a: [M, K] int8; w: [K, N] int8 -> int32 [M, N]."""
    M, K = a.shape
    N = w.shape[1]
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        _int_matmul_body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        interpret=interpret,
    )(a, w)


# ---------------------------------------------------------------------------
# fused dequant epilogue variants: int32 accumulate in VMEM scratch, rescale
# by per-token (a_scale [M, 1]) and per-channel (w_scale [1, N]) factors at
# the last K step, write out_dtype directly — no fp32 [M, N] intermediate
# ---------------------------------------------------------------------------


def _epilogue(acc, as_blk, ws_blk, out_dtype):
    return (acc.astype(jnp.float32) * as_blk * ws_blk).astype(out_dtype)


def _lutmul_fused_body(a_ref, w_ref, t_ref, as_ref, ws_ref, out_ref, acc_ref,
                       *, nk: int, out_dtype, contract_dtype=jnp.float32):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _onehot_contract(a_ref[...].astype(jnp.int32),
                                     w_ref[...], t_ref[...], contract_dtype)

    @pl.when(k == nk - 1)
    def _finish():
        out_ref[...] = _epilogue(acc_ref[...], as_ref[...], ws_ref[...],
                                 out_dtype)


def lutmul_fused_pallas(a_codes: jax.Array, w_packed: jax.Array,
                        table: jax.Array, a_scale: jax.Array,
                        w_scale: jax.Array, *, bm: int = DEFAULT_BM,
                        bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
                        out_dtype=jnp.bfloat16,
                        interpret: bool = True) -> jax.Array:
    """One-hot LUT matmul + fused dequant.  a_scale: [M, 1] f32 per-token,
    w_scale: [1, N] f32 per-channel; returns [M, N] ``out_dtype``."""
    M, K = a_codes.shape
    N = w_packed.shape[1]
    nk = K // bk
    grid = (M // bm, N // bn, nk)
    body = functools.partial(_lutmul_fused_body, nk=nk, out_dtype=out_dtype,
                             contract_dtype=jnp.float32 if interpret
                             else jnp.int8)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((16, 16), lambda i, j, k: (0, 0)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(a_codes, w_packed, table, a_scale, w_scale)


def _lutmul_tmac_fused_body(a_ref, w_ref, as_ref, ws_ref, out_ref, acc_ref,
                            *, nk: int, out_dtype, coeffs, const, g,
                            contract_dtype=jnp.float32):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _tmac_block(a_ref, w_ref, coeffs=coeffs, const=const,
                                g=g, contract_dtype=contract_dtype)

    @pl.when(k == nk - 1)
    def _finish():
        out_ref[...] = _epilogue(acc_ref[...], as_ref[...], ws_ref[...],
                                 out_dtype)


def lutmul_tmac_fused_pallas(a_q: jax.Array, w_planes: jax.Array,
                             a_scale: jax.Array, w_scale: jax.Array, *,
                             coeffs: tuple[int, ...], const: int = 0,
                             g: int = 2, bm: int = DEFAULT_BM,
                             bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
                             out_dtype=jnp.bfloat16,
                             interpret: bool = True) -> jax.Array:
    """T-MAC LUT matmul + fused dequant epilogue (see lutmul_tmac_pallas)."""
    M, K = a_q.shape
    n_planes, _, N = w_planes.shape
    if bk % (8 * max(g, 1)):
        raise ValueError(f"tmac needs bk % (8*g) == 0, got bk={bk} g={g}")
    nk = K // bk
    grid = (M // bm, N // bn, nk)
    body = functools.partial(_lutmul_tmac_fused_body, nk=nk,
                             out_dtype=out_dtype, coeffs=tuple(coeffs),
                             const=const, g=g,
                             contract_dtype=jnp.float32 if interpret
                             else jnp.int8)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((n_planes, bk // 8, bn), lambda i, j, k: (0, k, j)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(a_q, w_planes, a_scale, w_scale)


def _int_matmul_fused_body(a_ref, w_ref, as_ref, ws_ref, out_ref, acc_ref,
                           *, nk: int, out_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _finish():
        out_ref[...] = _epilogue(acc_ref[...], as_ref[...], ws_ref[...],
                                 out_dtype)


def int_matmul_fused_pallas(a: jax.Array, w: jax.Array, a_scale: jax.Array,
                            w_scale: jax.Array, *, bm: int = DEFAULT_BM,
                            bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
                            out_dtype=jnp.bfloat16,
                            interpret: bool = True) -> jax.Array:
    """int8 matmul + fused dequant (w4a4_mxu / w8a8 serving path)."""
    M, K = a.shape
    N = w.shape[1]
    nk = K // bk
    grid = (M // bm, N // bn, nk)
    body = functools.partial(_int_matmul_fused_body, nk=nk,
                             out_dtype=out_dtype)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(a, w, a_scale, w_scale)
