"""Pure-jnp oracles for the LUT-multiplication kernels.

These define the *semantics* the Pallas kernels must reproduce exactly
(integer math — assert_allclose with atol=0).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.lut import unpack_int4


def decode_codes(codes: jnp.ndarray, bits: int = 4, signed: bool = True
                 ) -> jnp.ndarray:
    """Two's-complement decode of n-bit codes held in uint8/int8."""
    c = codes.astype(jnp.int32) & ((1 << bits) - 1)
    if signed:
        c = jnp.where(c >= (1 << (bits - 1)), c - (1 << bits), c)
    return c


def lutmul_ref(a_codes: jnp.ndarray, w_packed: jnp.ndarray,
               a_signed: bool = True) -> jnp.ndarray:
    """LUT-matmul oracle.

    a_codes: [M, K] uint8 (4-bit codes); w_packed: [K//2, N] uint8 nibble
    pairs (k-major packing: byte k2 holds w[2*k2] in the low nibble).
    Returns int32 [M, N] — exactly what the table-gather kernel accumulates.
    """
    a = decode_codes(a_codes, 4, a_signed)                     # [M, K]
    w = unpack_int4(w_packed.T, signed=True).T.astype(jnp.int32)  # [K, N]
    return a @ w


def int_matmul_ref(a: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """int8 x int8 -> int32 matmul oracle (the 'DSP packing' analogue)."""
    return jnp.matmul(a.astype(jnp.int32), w.astype(jnp.int32))


def scaled_lutmul_ref(a_codes: jnp.ndarray, w_packed: jnp.ndarray,
                      a_scale: jnp.ndarray, w_scale: jnp.ndarray,
                      a_signed: bool = True,
                      out_dtype=jnp.float32) -> jnp.ndarray:
    """Oracle for the fused-dequant kernels: int32 LUT accumulator rescaled
    by per-token ([M, 1]) and per-channel ([1, N]) factors in f32 — the exact
    epilogue order ``kernel._epilogue`` applies, so the fused kernels must
    match this bitwise."""
    acc = lutmul_ref(a_codes, w_packed, a_signed)
    return (acc.astype(jnp.float32) * a_scale.astype(jnp.float32)
            * w_scale.astype(jnp.float32)).astype(out_dtype)
