"""Pure-jnp oracles for the LUT-multiplication kernels.

These define the *semantics* the Pallas kernels must reproduce exactly
(integer math — assert_allclose with atol=0).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.lut import (plane_decomposition, unpack_bitplanes,
                            unpack_int4)


def decode_codes(codes: jnp.ndarray, bits: int = 4, signed: bool = True
                 ) -> jnp.ndarray:
    """Two's-complement decode of n-bit codes held in uint8/int8."""
    c = codes.astype(jnp.int32) & ((1 << bits) - 1)
    if signed:
        c = jnp.where(c >= (1 << (bits - 1)), c - (1 << bits), c)
    return c


def lutmul_ref(a_codes: jnp.ndarray, w_packed: jnp.ndarray,
               a_signed: bool = True) -> jnp.ndarray:
    """LUT-matmul oracle.

    a_codes: [M, K] uint8 (4-bit codes); w_packed: [K//2, N] uint8 nibble
    pairs (k-major packing: byte k2 holds w[2*k2] in the low nibble).
    Returns int32 [M, N] — exactly what the table-gather kernel accumulates.
    """
    a = decode_codes(a_codes, 4, a_signed)                     # [M, K]
    w = unpack_int4(w_packed.T, signed=True).T.astype(jnp.int32)  # [K, N]
    return a @ w


def lutmul_tmac_ref(a_q: jnp.ndarray, w_planes: jnp.ndarray, wbits,
                    g: int = 2) -> jnp.ndarray:
    """T-MAC formulation oracle — the *faithful* group-table semantics.

    a_q: [M, K] int8 signed activation codes; w_planes: [P, K//8, N] packed
    bitplanes (``core.lut.pack_bitplanes``); wbits: spec from
    ``core.lut.WEIGHT_BITS_SPECS``.  Builds the per-group partial-sum table
    ``T[m, kg, c] = sum_i bit_i(c) * a[m, kg*g+i]`` and gathers it with each
    weight plane's g-bit group codes, exactly the contraction
    ``kernel._tmac_contract`` realizes on the MXU.  Returns int32 [M, N].
    """
    n_planes, coeffs, const = plane_decomposition(wbits)
    a = jnp.asarray(a_q).astype(jnp.int32)                     # [M, K]
    w = unpack_bitplanes(w_planes).astype(jnp.int32)           # [P, K, N]
    M, K = a.shape
    if K % g:
        raise ValueError(f"tmac ref needs K % g == 0, got K={K} g={g}")
    kg, c = K // g, 1 << g
    # T[m, kg, c]: every 2^g partial sum of each activation group
    bitsel = ((jnp.arange(c)[None, :] >> jnp.arange(g)[:, None]) & 1)
    table = a.reshape(M, kg, g) @ bitsel                       # [M, kg, c]
    # per-plane group codes, then gather-and-sum with static coefficients
    gsh = jnp.arange(g, dtype=jnp.int32).reshape(1, 1, g, 1)
    gcodes = jnp.sum(w.reshape(n_planes, kg, g, -1) << gsh,
                     axis=2)                                   # [P, kg, N]
    acc = jnp.zeros((M, w.shape[-1]), jnp.int32)
    for p in range(n_planes):
        # LUT[m, kg, gcode_p(kg, n)] summed over groups
        looked = jnp.take_along_axis(table, gcodes[p][None, :, :],
                                     axis=2)                   # [M, kg, N]
        acc = acc + coeffs[p] * jnp.sum(looked, axis=1)
    if const:
        acc = acc + const * jnp.sum(a, axis=1, keepdims=True)
    return acc


def int_matmul_ref(a: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """int8 x int8 -> int32 matmul oracle (the 'DSP packing' analogue)."""
    return jnp.matmul(a.astype(jnp.int32), w.astype(jnp.int32))


def scaled_lutmul_ref(a_codes: jnp.ndarray, w_packed: jnp.ndarray,
                      a_scale: jnp.ndarray, w_scale: jnp.ndarray,
                      a_signed: bool = True,
                      out_dtype=jnp.float32) -> jnp.ndarray:
    """Oracle for the fused-dequant kernels: int32 LUT accumulator rescaled
    by per-token ([M, 1]) and per-channel ([1, N]) factors in f32 — the exact
    epilogue order ``kernel._epilogue`` applies, so the fused kernels must
    match this bitwise."""
    acc = lutmul_ref(a_codes, w_packed, a_signed)
    return (acc.astype(jnp.float32) * a_scale.astype(jnp.float32)
            * w_scale.astype(jnp.float32)).astype(out_dtype)
