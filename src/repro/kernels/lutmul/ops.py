"""jit'd wrappers around the LUT-multiplication kernels + the high-level
``quantized_matmul`` every model projection calls.

Backend selection:
  * "pallas"    — real TPU lowering (target hardware)
  * "interpret" — Pallas interpret mode (CPU correctness runs / tests)
  * "ref"       — pure-jnp oracle math (dry-run lowering on the CPU backend;
                  identical FLOP/byte structure at the roofline level)
Default: "ref" on CPU, "pallas" on TPU; override with
``repro.kernels.lutmul.ops.set_backend(...)`` or REPRO_KERNEL_BACKEND.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.lut import flat_product_table, pack_int4
from repro.kernels.lutmul import kernel, ref

_BACKEND: Optional[str] = None


def set_backend(name: Optional[str]) -> None:
    global _BACKEND
    _BACKEND = name


def get_backend() -> str:
    if _BACKEND is not None:
        return _BACKEND
    env = os.environ.get("REPRO_KERNEL_BACKEND")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _pad_to(x: jax.Array, m0: int, m1: int, value=0) -> jax.Array:
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)), constant_values=value)
    return x


_TABLE_SS = jnp.asarray(flat_product_table(a_signed=True), jnp.int32)
_TABLE_SU = jnp.asarray(flat_product_table(a_signed=False), jnp.int32)


def lutmul(a_codes: jax.Array, w_packed: jax.Array, *, a_signed: bool = True,
           backend: Optional[str] = None) -> jax.Array:
    """LUT-based matmul on 4-bit codes. a_codes: [M,K] u8; w_packed: [K//2,N] u8."""
    be = backend or get_backend()
    M, K = a_codes.shape
    N = w_packed.shape[1]
    if be == "ref":
        return ref.lutmul_ref(a_codes, w_packed, a_signed)
    table = _TABLE_SS if a_signed else _TABLE_SU
    bm, bn, bk = kernel.DEFAULT_BM, kernel.DEFAULT_BN, kernel.DEFAULT_BK
    bm = min(bm, max(8, 8 * (-(-M // 8))))
    a_p = _pad_to(a_codes, bm, bk)
    w_p = _pad_to(w_packed, bk // 2, bn)
    out = kernel.lutmul_pallas(a_p, w_p, table, bm=bm, bn=bn, bk=bk,
                               interpret=(be != "pallas"))
    return out[:M, :N]


def int_matmul(a: jax.Array, w: jax.Array,
               backend: Optional[str] = None) -> jax.Array:
    """int8 x int8 -> int32 under the same tiling (DSP-packing analogue)."""
    be = backend or get_backend()
    if be == "ref":
        return ref.int_matmul_ref(a, w)
    M, K = a.shape
    N = w.shape[1]
    bm, bn, bk = kernel.DEFAULT_BM, kernel.DEFAULT_BN, kernel.DEFAULT_BK
    bm = min(bm, max(8, 8 * (-(-M // 8))))
    a_p = _pad_to(a, bm, bk)
    w_p = _pad_to(w, bk, bn)
    out = kernel.int_matmul_pallas(a_p, w_p, bm=bm, bn=bn, bk=bk,
                                   interpret=(be != "pallas"))
    return out[:M, :N]


# ---------------------------------------------------------------------------
# pre-quantized (serving) matmul: weights already integer codes on HBM
# ---------------------------------------------------------------------------

def prequant_matmul(x: jax.Array, w_q: jax.Array, w_scale: jax.Array,
                    mode: str = "", compute_dtype=jnp.bfloat16,
                    backend: Optional[str] = None) -> jax.Array:
    """x: [..., K] float; w_q: packed-int4 uint8 [K//2, N] or int8 [K, N].

    Weight bytes on HBM are the integer codes (4x/2x smaller than bf16) —
    the serving embodiment of the paper's weights-live-in-LUTs idea.
    """
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w_q.shape[-1]
    packed = w_q.dtype == jnp.uint8
    bits = 4 if packed else 8
    qmax = 2 ** (bits - 1) - 1
    x2 = x.reshape(-1, K).astype(jnp.float32)
    a_scale = jnp.maximum(jnp.max(jnp.abs(x2), axis=1, keepdims=True), 1e-8) \
        / qmax
    a_q = jnp.clip(jnp.round(x2 / a_scale), -qmax - 1, qmax).astype(jnp.int8)
    if packed and mode == "w4a4_lut":
        acc = lutmul((a_q.astype(jnp.uint8)) & 0xF, w_q, a_signed=True,
                     backend=backend)
    else:
        if packed:
            from repro.core.lut import unpack_int4
            w_int = jnp.swapaxes(
                unpack_int4(jnp.swapaxes(w_q, -1, -2), signed=True), -1, -2)
        else:
            w_int = w_q
        acc = int_matmul(a_q, w_int, backend=backend)
    y = acc.astype(jnp.float32) * a_scale * w_scale.reshape(1, N)
    return y.reshape(*lead, N).astype(compute_dtype)


# ---------------------------------------------------------------------------
# high-level quantized projection used by models/layers.linear
# ---------------------------------------------------------------------------

def quantized_matmul(x: jax.Array, w: jax.Array, mode: str = "w4a4_mxu",
                     compute_dtype=jnp.bfloat16,
                     backend: Optional[str] = None) -> jax.Array:
    """Dynamic-activation-quant matmul: x [..., K] fp, w [K, N] fp.

    Weights: symmetric per-output-channel int4 (or int8); activations:
    symmetric per-token int4/int8 (transformer hidden states are signed — the
    unsigned-uint4+threshold path of the paper applies to post-ReLU CNNs and
    is exercised by the MobileNetV2 model).
    """
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[-1]
    x2 = x.reshape(-1, K).astype(jnp.float32)
    wf = w.astype(jnp.float32)

    bits = 4 if mode.startswith("w4") else 8
    qmax = 2 ** (bits - 1) - 1
    w_scale = jnp.max(jnp.abs(wf), axis=0, keepdims=True) / qmax   # [1,N]
    w_q = jnp.clip(jnp.round(wf / w_scale), -qmax - 1, qmax).astype(jnp.int8)
    a_scale = jnp.max(jnp.abs(x2), axis=1, keepdims=True) / qmax   # [M,1]
    a_scale = jnp.maximum(a_scale, 1e-8)
    a_q = jnp.clip(jnp.round(x2 / a_scale), -qmax - 1, qmax).astype(jnp.int8)

    if mode == "w4a4_lut":
        a_codes = (a_q.astype(jnp.uint8)) & 0xF
        w_packed = pack_int4(w_q.T).T                  # pack along K
        acc = lutmul(a_codes, w_packed, a_signed=True, backend=backend)
    else:  # w4a4_mxu / w8a8 — integer dot (MXU path)
        acc = int_matmul(a_q, w_q, backend=backend)
    y = acc.astype(jnp.float32) * a_scale * w_scale
    return y.reshape(*lead, N).astype(compute_dtype)
