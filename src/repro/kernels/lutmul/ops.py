"""jit'd wrappers around the LUT-multiplication kernels + the high-level
``quantized_matmul`` every model projection calls.

Backend selection:
  * "pallas"    — real TPU lowering (target hardware)
  * "interpret" — Pallas interpret mode (CPU correctness runs / tests)
  * "ref"       — pure-jnp oracle math (dry-run lowering on the CPU backend;
                  identical FLOP/byte structure at the roofline level)
Default: "ref" on CPU, "pallas" on TPU; override with
``repro.kernels.lutmul.ops.set_backend(...)`` or REPRO_KERNEL_BACKEND.

Kernel implementation selection (``impl``): "onehot" (MXU contraction,
default) or "gather" (the serial per-row table-gather baseline, kept for
A/B benchmarking — see kernel.py).

Block sizes come from :func:`pick_blocks`: a per-(op, M, K, N, backend)
cached choice.  The default is the aligned heuristic; with autotuning
enabled (``set_autotune(True)`` or REPRO_LUTMUL_AUTOTUNE=1) the first call
per shape times a small candidate sweep and caches the winner — intended
for the TPU backend (ROADMAP: hardware validation pending).
"""
from __future__ import annotations

import functools
import os
import re
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.lut import (contraction_table, decode_planes, pack_bitplanes,
                            pack_int4, plane_decomposition, planes_from_codes,
                            truncate_plane_spec, validate_weight_bits,
                            weight_bits)
from repro.kernels.lutmul import kernel, ref

_BACKEND: Optional[str] = None

# incremented on every *weight* quantization/packing event (the thing a
# cached QuantizedLinear must do once, not per forward call — tested)
WEIGHT_QUANT_COUNT = 0


def set_backend(name: Optional[str]) -> None:
    global _BACKEND
    _BACKEND = name


def get_backend() -> str:
    if _BACKEND is not None:
        return _BACKEND
    env = os.environ.get("REPRO_KERNEL_BACKEND")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _pad_to(x: jax.Array, m0: int, m1: int, value=0) -> jax.Array:
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)), constant_values=value)
    return x


# tables are lazily built + device-transferred on first kernel use (module
# import used to eagerly push both tables to device — satellite fix)
_TABLE_CACHE: dict[bool, jax.Array] = {}


def _get_table(a_signed: bool) -> jax.Array:
    """[16, 16] int32 product table (row = weight code, col = act code)."""
    t = _TABLE_CACHE.get(a_signed)
    if t is None:
        t = jnp.asarray(contraction_table(a_signed=a_signed), jnp.int32)
        # under a jit trace the constant is a tracer — never cache those
        if not isinstance(t, jax.core.Tracer):
            _TABLE_CACHE[a_signed] = t
    return t


# ---------------------------------------------------------------------------
# block-size selection (+ optional autotune sweep)
# ---------------------------------------------------------------------------

_AUTOTUNE: Optional[bool] = None
_BLOCK_CACHE: dict[tuple, tuple[int, int, int]] = {}

# (bm, bn, bk) candidates, all (8, 128, 128)-aligned; the first entry is the
# heuristic default so a disabled autotuner is a zero-cost lookup
_CANDIDATES = ((128, 128, 128), (256, 256, 256), (256, 128, 128),
               (128, 256, 128), (64, 128, 128))


def set_autotune(enabled: Optional[bool]) -> None:
    global _AUTOTUNE
    _AUTOTUNE = enabled


def autotune_enabled() -> bool:
    if _AUTOTUNE is not None:
        return _AUTOTUNE
    return os.environ.get("REPRO_LUTMUL_AUTOTUNE", "0") == "1"


def _clip_blocks(M: int, K: int, N: int, bm: int, bn: int,
                 bk: int) -> tuple[int, int, int]:
    """Shrink blocks to the (padded) problem so tiny shapes don't over-pad."""
    bm = min(bm, max(8, 8 * (-(-M // 8))))
    bn = min(bn, max(128, 128 * (-(-N // 128))))
    bk = min(bk, max(128, 128 * (-(-K // 128))))
    return bm, bn, bk


def pick_blocks(op: str, M: int, K: int, N: int, backend: str,
                bench_fn=None) -> tuple[int, int, int]:
    """Cached (bm, bn, bk) per shape; times a candidate sweep when autotuning
    is on and a ``bench_fn(bm, bn, bk) -> callable`` is supplied."""
    key = (op, M, K, N, backend)
    hit = _BLOCK_CACHE.get(key)
    if hit is not None:
        return hit
    default = _clip_blocks(M, K, N, *_CANDIDATES[0])
    if not autotune_enabled():
        _BLOCK_CACHE[key] = default
        return default
    if bench_fn is None:      # tracing: can't time; don't poison the cache
        return default
    best, best_t = default, float("inf")
    seen = set()
    for cand in _CANDIDATES:
        blocks = _clip_blocks(M, K, N, *cand)
        if blocks in seen:
            continue
        seen.add(blocks)
        try:
            run = bench_fn(*blocks)
            run()                                   # compile
            run()                                   # warm caches / frequency
            reps = []
            for _ in range(5):
                t0 = time.perf_counter()
                run()
                reps.append(time.perf_counter() - t0)
            dt = sorted(reps)[len(reps) // 2]       # median
        except Exception:                           # infeasible candidate
            continue
        if dt < best_t:
            best, best_t = blocks, dt
    _BLOCK_CACHE[key] = best
    return best


# ---------------------------------------------------------------------------
# quant-mode grammar + shape validation
# ---------------------------------------------------------------------------

_TMAC_MODE = re.compile(r"^(?:w(\d+)|(ternary))_?a(\d+)(_tmac)?$")


def parse_mode(mode: str) -> tuple[str, object, int]:
    """Parse a quant-mode string -> (formulation, wbits_spec, abits).

    Legacy modes: "w4a4_mxu"/"" -> ("int", 4, 4); "w8a8" -> ("int", 8, 8);
    "w4a4_lut" -> ("onehot", 4, 4).  T-MAC family: "w{1,2,3,4}a{4,8}_tmac"
    and "ternary_a{4,8}_tmac" -> ("tmac", spec, abits).  Suffix-free
    sub-4-bit modes ("w2a4", "ternary_a4") -> ("auto", spec, abits): the
    formulation is chosen per (bits, shape) by :func:`pick_formulation`.
    """
    if mode in ("", "none", "w4a4_mxu"):
        return ("int", 4, 4)
    if mode == "w8a8":
        return ("int", 8, 8)
    if mode == "w4a4_lut":
        return ("onehot", 4, 4)
    m = _TMAC_MODE.match(mode)
    if m:
        spec = "ternary" if m.group(2) else int(m.group(1))
        validate_weight_bits(spec)
        abits = int(m.group(3))
        if abits not in (4, 8):
            raise ValueError(
                f"unsupported activation bit width a{abits} in {mode!r}: "
                "the quantizers support a4 and a8")
        return ("tmac" if m.group(4) else "auto", spec, abits)
    raise ValueError(
        f"unknown quant mode {mode!r}: expected one of w4a4_mxu | w4a4_lut | "
        "w8a8 | w{{1,2,3,4}}a{{4,8}}[_tmac] | ternary_a{{4,8}}[_tmac]")


def tmac_group_size(abits: int) -> int:
    """Activation-group width g.  a4 uses g=2 (real partial-sum tables, int8
    table entries bounded by 8g <= 32 on TPU); a8 clamps to g=1 (the
    degenerate direct-contraction path) so table entries stay in int8."""
    return 1 if abits >= 8 else 2


def _check_lut_shapes(a_codes: jax.Array, w_packed: jax.Array,
                      table: Optional[jax.Array] = None) -> None:
    K = a_codes.shape[1]
    if K % 2:
        raise ValueError(
            f"lutmul requires even K for nibble-packed weights, got K={K}; "
            "pad the contraction dim to a multiple of 2 (models do this by "
            "construction)")
    if w_packed.ndim != 2:
        raise ValueError(
            f"w_packed must be 2D [K//2, N], got shape {w_packed.shape}; "
            "3D [P, K//8, N] bitplane leaves belong to the tmac formulation "
            "(use lutmul_tmac)")
    if w_packed.shape[0] * 2 != K:
        raise ValueError(
            f"w_packed rows ({w_packed.shape[0]}) must be K//2 = {K // 2} "
            f"for activation K={K}: the weight was packed for "
            f"K={w_packed.shape[0] * 2} (mismatched quantize/packing?)")
    if table is not None and tuple(table.shape) != (16, 16):
        raise ValueError(
            f"product table must be [16, 16] (4-bit x 4-bit codes), got "
            f"{tuple(table.shape)}")


def _check_tmac_shapes(a_q: jax.Array, w_planes: jax.Array, wbits) -> None:
    validate_weight_bits(wbits)
    n_planes = plane_decomposition(wbits)[0]
    K = a_q.shape[1]
    if w_planes.ndim != 3:
        raise ValueError(
            f"tmac weights must be 3D [P, K//8, N] packed bitplanes, got "
            f"shape {w_planes.shape} (2D leaves belong to the one-hot/int "
            "formulations)")
    if w_planes.shape[0] != n_planes:
        raise ValueError(
            f"tmac weight has {w_planes.shape[0]} bitplanes but wbits="
            f"{wbits!r} decomposes into {n_planes} planes (was the leaf "
            "quantized at a different width?)")
    if K % 8:
        raise ValueError(
            f"tmac requires K % 8 == 0 for byte-packed bitplanes, got K={K}")
    if w_planes.shape[1] * 8 != K:
        raise ValueError(
            f"tmac w_planes rows ({w_planes.shape[1]}) must be K//8 = "
            f"{K // 8} for activation K={K}: the weight was packed for "
            f"K={w_planes.shape[1] * 8}")


def truncate_planes(w_planes: jax.Array, wbits, keep: int
                    ) -> tuple[jax.Array, int, int]:
    """Top-``keep`` plane suffix of a packed w{wbits} tmac stack.

    ``w_planes`` is a packed bitplane stack with the plane axis at -3
    (``[P, K//8, N]`` or stacked ``[G, P, K//8, N]``).  Returns
    ``(draft_planes, draft_wbits, scale_mult)``: the suffix slice is a
    *valid* ``w{keep}`` tmac stack (``truncate_plane_spec`` proves the
    coefficient algebra), and ``scale_mult = 2^(wbits-keep)`` must be folded
    into the leaf's ``w_scale`` so the drafter dequantizes on the target's
    code grid.  Pure slicing — the draft view shares the target's packed
    bytes, zero extra weight memory.
    """
    kept, mult = truncate_plane_spec(wbits, keep)
    n_planes = plane_decomposition(wbits)[0]
    if w_planes.ndim < 3 or w_planes.shape[-3] != n_planes:
        raise ValueError(
            f"cannot truncate: leaf has plane axis {w_planes.shape} but "
            f"wbits={wbits!r} decomposes into {n_planes} planes")
    return w_planes[..., n_planes - kept:, :, :], kept, mult


# ---------------------------------------------------------------------------
# raw integer matmuls (int32 out, no scales)
# ---------------------------------------------------------------------------

def lutmul(a_codes: jax.Array, w_packed: jax.Array, *, a_signed: bool = True,
           backend: Optional[str] = None, impl: str = "onehot") -> jax.Array:
    """LUT-based matmul on 4-bit codes. a_codes: [M,K] u8; w_packed: [K//2,N] u8."""
    _check_lut_shapes(a_codes, w_packed)
    be = backend or get_backend()
    M, K = a_codes.shape
    N = w_packed.shape[1]
    if be == "ref":
        return ref.lutmul_ref(a_codes, w_packed, a_signed)
    table = _get_table(a_signed)
    interpret = be != "pallas"

    def bench(bm, bn, bk):
        a_p = _pad_to(a_codes, bm, bk)
        w_p = _pad_to(w_packed, bk // 2, bn)
        f = jax.jit(functools.partial(
            kernel.lutmul_pallas, a_p, w_p, table, bm=bm, bn=bn, bk=bk,
            impl=impl, interpret=interpret))
        return lambda: f().block_until_ready()

    # a sweep can only time concrete arrays — under a jit trace fall back to
    # the cache (populated by a prior eager call) or the heuristic
    if isinstance(a_codes, jax.core.Tracer):
        bench = None
    bm, bn, bk = pick_blocks(f"lutmul_{impl}", M, K, N, be, bench)
    a_p = _pad_to(a_codes, bm, bk)
    w_p = _pad_to(w_packed, bk // 2, bn)
    out = kernel.lutmul_pallas(a_p, w_p, table, bm=bm, bn=bn, bk=bk,
                               impl=impl, interpret=interpret)
    return out[:M, :N]


def lutmul_gather(a_codes: jax.Array, w_packed: jax.Array, *,
                  a_signed: bool = True,
                  backend: Optional[str] = None) -> jax.Array:
    """The retained serial-gather kernel (A/B baseline for the benches)."""
    return lutmul(a_codes, w_packed, a_signed=a_signed, backend=backend,
                  impl="gather")


def int_matmul(a: jax.Array, w: jax.Array,
               backend: Optional[str] = None) -> jax.Array:
    """int8 x int8 -> int32 under the same tiling (DSP-packing analogue)."""
    be = backend or get_backend()
    if be == "ref":
        return ref.int_matmul_ref(a, w)
    M, K = a.shape
    N = w.shape[1]
    interpret = be != "pallas"

    def bench(bm, bn, bk):
        a_p = _pad_to(a, bm, bk)
        w_p = _pad_to(w, bk, bn)
        f = jax.jit(functools.partial(
            kernel.int_matmul_pallas, a_p, w_p, bm=bm, bn=bn, bk=bk,
            interpret=interpret))
        return lambda: f().block_until_ready()

    if isinstance(a, jax.core.Tracer):
        bench = None
    bm, bn, bk = pick_blocks("int_matmul", M, K, N, be, bench)
    a_p = _pad_to(a, bm, bk)
    w_p = _pad_to(w, bk, bn)
    out = kernel.int_matmul_pallas(a_p, w_p, bm=bm, bn=bn, bk=bk,
                                   interpret=interpret)
    return out[:M, :N]


def _pad_planes(w_planes: jax.Array, bk: int, bn: int) -> jax.Array:
    """Pad [P, K//8, N] packed bitplanes to (bk//8, bn) multiples.  Zero
    plane bytes select table entry 0 (= 0) so padding is exact."""
    p1 = (-w_planes.shape[1]) % (bk // 8)
    p2 = (-w_planes.shape[2]) % bn
    if p1 or p2:
        w_planes = jnp.pad(w_planes, ((0, 0), (0, p1), (0, p2)))
    return w_planes


def lutmul_tmac(a_q: jax.Array, w_planes: jax.Array, wbits, *,
                g: Optional[int] = None, abits: int = 4,
                backend: Optional[str] = None) -> jax.Array:
    """T-MAC matmul: int8 activation codes x packed weight bitplanes -> int32.

    a_q: [M, K] int8 signed codes; w_planes: [P, K//8, N] uint8 (the
    ``quantize_weights_planes`` format); wbits: spec from
    ``core.lut.WEIGHT_BITS_SPECS``.  Kernel cost is linear in the plane
    count P (module docstring of kernel.py).
    """
    _check_tmac_shapes(a_q, w_planes, wbits)
    n_planes, coeffs, const = plane_decomposition(wbits)
    if g is None:
        g = tmac_group_size(abits)
    be = backend or get_backend()
    M, K = a_q.shape
    N = w_planes.shape[-1]
    if be == "ref":
        # decoded-plane contraction: exact integer math, identical result to
        # the faithful group-table gather (ref.lutmul_tmac_ref — the fuzz
        # suite pins all three against each other)
        from repro.core.lut import unpack_bitplanes
        w = decode_planes(unpack_bitplanes(w_planes), wbits)
        return a_q.astype(jnp.int32) @ w
    interpret = be != "pallas"

    def bench(bm, bn, bk):
        a_p = _pad_to(a_q, bm, bk)
        w_p = _pad_planes(w_planes, bk, bn)
        f = jax.jit(functools.partial(
            kernel.lutmul_tmac_pallas, a_p, w_p, coeffs=coeffs, const=const,
            g=g, bm=bm, bn=bn, bk=bk, interpret=interpret))
        return lambda: f().block_until_ready()

    if isinstance(a_q, jax.core.Tracer):
        bench = None
    bm, bn, bk = pick_blocks(f"lutmul_tmac{g}_p{n_planes}", M, K, N, be,
                             bench)
    a_p = _pad_to(a_q, bm, bk)
    w_p = _pad_planes(w_planes, bk, bn)
    out = kernel.lutmul_tmac_pallas(a_p, w_p, coeffs=coeffs, const=const,
                                    g=g, bm=bm, bn=bn, bk=bk,
                                    interpret=interpret)
    return out[:M, :N]


# ---------------------------------------------------------------------------
# fused-epilogue dispatch (kernel backends): int32 accumulate + in-kernel
# rescale, so no fp32 [M, N] intermediate is materialized
# ---------------------------------------------------------------------------

def _fused_lut(a_codes, w_packed, a_scale, w_scale, *, a_signed: bool,
               be: str, out_dtype) -> jax.Array:
    _check_lut_shapes(a_codes, w_packed)
    M, K = a_codes.shape
    N = w_packed.shape[1]
    table = _get_table(a_signed)
    interpret = be != "pallas"
    bm, bn, bk = pick_blocks("lutmul_fused", M, K, N, be)
    a_p = _pad_to(a_codes, bm, bk)
    w_p = _pad_to(w_packed, bk // 2, bn)
    as_p = _pad_to(a_scale.astype(jnp.float32), bm, 1)
    ws_p = _pad_to(w_scale.astype(jnp.float32), 1, bn)
    out = kernel.lutmul_fused_pallas(a_p, w_p, table, as_p, ws_p, bm=bm,
                                     bn=bn, bk=bk, out_dtype=out_dtype,
                                     interpret=interpret)
    return out[:M, :N]


def _fused_int(a_q, w_int, a_scale, w_scale, *, be: str,
               out_dtype) -> jax.Array:
    M, K = a_q.shape
    N = w_int.shape[1]
    interpret = be != "pallas"
    bm, bn, bk = pick_blocks("int_matmul_fused", M, K, N, be)
    a_p = _pad_to(a_q, bm, bk)
    w_p = _pad_to(w_int, bk, bn)
    as_p = _pad_to(a_scale.astype(jnp.float32), bm, 1)
    ws_p = _pad_to(w_scale.astype(jnp.float32), 1, bn)
    out = kernel.int_matmul_fused_pallas(a_p, w_p, as_p, ws_p, bm=bm, bn=bn,
                                         bk=bk, out_dtype=out_dtype,
                                         interpret=interpret)
    return out[:M, :N]


def _fused_tmac(a_q, w_planes, a_scale, w_scale, *, wbits, g: int, be: str,
                out_dtype) -> jax.Array:
    _check_tmac_shapes(a_q, w_planes, wbits)
    _, coeffs, const = plane_decomposition(wbits)
    M, K = a_q.shape
    N = w_planes.shape[-1]
    n_planes = w_planes.shape[0]
    interpret = be != "pallas"
    bm, bn, bk = pick_blocks(f"lutmul_tmac{g}_p{n_planes}_fused", M, K, N, be)
    a_p = _pad_to(a_q, bm, bk)
    w_p = _pad_planes(w_planes, bk, bn)
    as_p = _pad_to(a_scale.astype(jnp.float32), bm, 1)
    ws_p = _pad_to(w_scale.astype(jnp.float32), 1, bn)
    out = kernel.lutmul_tmac_fused_pallas(a_p, w_p, as_p, ws_p, coeffs=coeffs,
                                          const=const, g=g, bm=bm, bn=bn,
                                          bk=bk, out_dtype=out_dtype,
                                          interpret=interpret)
    return out[:M, :N]


# ---------------------------------------------------------------------------
# epilogue-variant selection (fused vs unfused dequant) — satellite fix for
# the fused-dequant regression: interpret mode pays more for the VMEM
# scratch + per-block epilogue machinery than the fusion saves (measured:
# 7.8 ms fused vs 5.2 ms unfused at 256^3), so dispatch defaults to the
# unfused epilogue there and to fused on real hardware; with autotuning on,
# a timed A/B per (op, shape) decides and the bench records the winner.
# ---------------------------------------------------------------------------

_VARIANT_CACHE: dict[tuple, str] = {}


def pick_variant(op: str, M: int, K: int, N: int, backend: str,
                 bench_fns=None) -> str:
    """Cached "fused" | "unfused" dequant-epilogue choice per (op, shape).

    ``bench_fns``: optional {"fused": fn, "unfused": fn} of nullary timed
    callables; only consulted when autotuning is enabled (the bench supplies
    them so the committed BENCH rows record which variant won).
    """
    key = (op, M, K, N, backend)
    hit = _VARIANT_CACHE.get(key)
    if hit is not None:
        return hit
    default = "fused" if backend == "pallas" else "unfused"
    if not autotune_enabled():
        _VARIANT_CACHE[key] = default
        return default
    if not bench_fns:
        return default
    best, best_t = default, float("inf")
    for name, run in bench_fns.items():
        try:
            run()
            run()
            reps = []
            for _ in range(5):
                t0 = time.perf_counter()
                run()
                reps.append(time.perf_counter() - t0)
            dt = sorted(reps)[len(reps) // 2]
        except Exception:
            continue
        if dt < best_t:
            best, best_t = name, dt
    _VARIANT_CACHE[key] = best
    return best


# ---------------------------------------------------------------------------
# quantizers
# ---------------------------------------------------------------------------

def _quantize_with_scale(x2: jax.Array, a_scale: jax.Array,
                         qmax: int) -> jax.Array:
    """Symmetric round/clip to int8 codes under a precomputed scale — THE
    one copy of the formula both the full-K and the head-sharded (pmax
    scale) paths share, so they can never drift apart."""
    return jnp.clip(jnp.round(x2 / a_scale), -qmax - 1, qmax).astype(jnp.int8)


def quantize_activations(x2: jax.Array, bits: int):
    """Per-token symmetric quant: [M, K] f32 -> (int8 codes, [M, 1] scale)."""
    if bits not in (4, 8):
        raise ValueError(
            f"unsupported activation bit width {bits!r}: activations "
            "quantize to a4 or a8 (sub-4-bit widths apply to *weights* — "
            "see quantize_weights_planes)")
    qmax = 2 ** (bits - 1) - 1
    a_scale = jnp.maximum(jnp.max(jnp.abs(x2), axis=1, keepdims=True),
                          1e-8) / qmax
    return _quantize_with_scale(x2, a_scale, qmax), a_scale


def quantize_weights(wf: jax.Array, bits: int, pack: bool = False):
    """Per-output-channel symmetric quant: [K, N] f32 -> (codes, [1, N] scale).

    ``bits`` must be 4 or 8 here — the nibble/int8 storage formats.  Sub-4
    widths (1, 2, 3, ternary) use the bitplane format via
    :func:`quantize_weights_planes`.  Counted by ``WEIGHT_QUANT_COUNT`` —
    cached layers must hit this once at load, never per forward call.
    """
    if bits not in (4, 8):
        raise ValueError(
            f"unsupported weight bit width {bits!r} for the nibble/int8 "
            "format: use 4 or 8, or quantize_weights_planes for the tmac "
            "bitplane family (1, 2, 3, 4, 'ternary')")
    if pack and bits != 4:
        raise ValueError("nibble packing (pack=True) is a 4-bit format; "
                         f"got bits={bits}")
    global WEIGHT_QUANT_COUNT
    WEIGHT_QUANT_COUNT += 1
    qmax = 2 ** (bits - 1) - 1
    w_scale = jnp.max(jnp.abs(wf), axis=0, keepdims=True) / qmax   # [1, N]
    w_scale = jnp.maximum(w_scale, 1e-8)
    w_q = jnp.clip(jnp.round(wf / w_scale), -qmax - 1, qmax).astype(jnp.int8)
    if pack:
        if wf.shape[0] % 2:
            raise ValueError(
                f"nibble packing needs even K, got K={wf.shape[0]}")
        w_q = pack_int4(w_q.T).T                                   # pack K
    return w_q, w_scale


def quantize_weights_planes(wf: jax.Array, wbits):
    """Per-output-channel quant to the tmac bitplane format.

    [..., K, N] f32 -> ([..., P, K//8, N] uint8 packed bitplanes,
    [..., 1, N] f32 scale) — leading stack dims (the scanned per-group
    block axis) pass through.

    Integer widths use the same absmax/round/clip formula as
    :func:`quantize_weights` (so w4 planes decode to EXACTLY the w4 nibble
    codes — the basis of the cross-formulation bit-exactness tests).
    Ternary and binary follow BitNet-b1.58: per-channel mean-|w| scale,
    codes in {-1, 0, +1} (ternary) / sign in {-1, +1} (w1).
    """
    validate_weight_bits(wbits)
    if wf.shape[-2] % 8:
        raise ValueError(
            f"tmac bitplane packing needs K % 8 == 0, got K={wf.shape[-2]}; "
            "pad the contraction dim before quantizing")
    global WEIGHT_QUANT_COUNT
    WEIGHT_QUANT_COUNT += 1
    wf = wf.astype(jnp.float32)
    if wbits in ("ternary", 1):
        w_scale = jnp.maximum(jnp.mean(jnp.abs(wf), axis=-2, keepdims=True),
                              1e-8)                             # [..., 1, N]
        if wbits == "ternary":
            codes = jnp.clip(jnp.round(wf / w_scale), -1, 1)
        else:
            codes = jnp.where(wf >= 0, 1, -1)
    else:
        b = int(wbits)
        qmax = 2 ** (b - 1) - 1
        w_scale = jnp.maximum(
            jnp.max(jnp.abs(wf), axis=-2, keepdims=True) / qmax, 1e-8)
        codes = jnp.clip(jnp.round(wf / w_scale), -qmax - 1, qmax)
    planes = planes_from_codes(codes.astype(jnp.int32), wbits)
    return pack_bitplanes(planes), w_scale


# ---------------------------------------------------------------------------
# formulation selection: tmac vs one-hot per (bits, shape) — the serving
# quantizer consults this at load time, so the stored leaf format IS the
# formulation choice and the forward pass just follows the leaf's shape
# ---------------------------------------------------------------------------

_FORMULATION_CACHE: dict[tuple, str] = {}


def pick_formulation(wbits, abits: int, K: int, N: int,
                     backend: Optional[str] = None) -> str:
    """Cached "tmac" | "onehot" choice per (wbits, abits, K, N, backend).

    Heuristic default: tmac below 4 weight bits (its MAC count is linear in
    the plane count; one-hot is flat at 4K), one-hot at w4.  With autotuning
    enabled, the first call per shape times both dispatches on synthetic
    codes at a probe M and caches the winner.  a8 activations always take
    tmac (the one-hot product table is 4-bit x 4-bit).
    """
    validate_weight_bits(wbits)
    be = backend or get_backend()
    key = (wbits, abits, K, N, be)
    hit = _FORMULATION_CACHE.get(key)
    if hit is not None:
        return hit
    if abits >= 8:
        _FORMULATION_CACHE[key] = "tmac"
        return "tmac"
    default = "tmac" if weight_bits(wbits) < 4 else "onehot"
    if be == "ref" or not autotune_enabled():
        _FORMULATION_CACHE[key] = default
        return default
    import numpy as np
    rng = np.random.default_rng(0)
    M = 256
    a_q = jnp.asarray(rng.integers(-8, 8, size=(M, K)), jnp.int8)
    n_planes = plane_decomposition(wbits)[0]
    planes = jnp.asarray(
        rng.integers(0, 256, size=(n_planes, K // 8, N)), jnp.uint8)
    # sub-4-bit codes are valid 4-bit codes, so one-hot runs them unchanged
    # (at its flat 4K cost) — decode the planes and nibble-pack
    from repro.core.lut import unpack_bitplanes
    codes = decode_planes(unpack_bitplanes(planes), wbits).astype(jnp.int8)
    nib = pack_int4(codes.T).T
    timings = {}
    for name, fn in (
            ("tmac", jax.jit(functools.partial(
                lutmul_tmac, a_q, planes, wbits, abits=abits, backend=be))),
            ("onehot", jax.jit(functools.partial(
                lutmul, (a_q.astype(jnp.uint8)) & 0xF, nib, a_signed=True,
                backend=be)))):
        try:
            jax.block_until_ready(fn())
            reps = []
            for _ in range(3):
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                reps.append(time.perf_counter() - t0)
            timings[name] = sorted(reps)[len(reps) // 2]
        except Exception:
            continue
    best = min(timings, key=timings.get) if timings else default
    _FORMULATION_CACHE[key] = best
    return best


# ---------------------------------------------------------------------------
# pre-quantized (serving) matmul: weights already integer codes on HBM
# ---------------------------------------------------------------------------

def _unpack_w(w_q: jax.Array) -> jax.Array:
    """Packed-int4 uint8 [..., K//2, N] -> int8 [..., K, N]."""
    from repro.core.lut import unpack_int4
    return jnp.swapaxes(
        unpack_int4(jnp.swapaxes(w_q, -1, -2), signed=True), -1, -2)


def _row_parallel_prequant(x, w_q, w_scale, mode, compute_dtype, be,
                           axis: str, size: int) -> jax.Array:
    """Row-parallel (K-sharded) pre-quantized matmul under ``shard_map``.

    ``w_q`` is this device's K slice of the codes.  ``x`` is either the full
    replicated activation (classic Megatron row-parallel) or — when attention
    runs head-sharded — already this shard's K slice (the head-local
    attention output feeding ``wo``), distinguished statically by its K
    extent.  Either way the activation scale is the FULL-K per-token scale
    (identical to the single-device scale): taken directly on the replicated
    input, or recovered exactly from the local slice via a ``pmax`` of the
    per-shard maxima — max is associative and exact, so both routes yield
    the same fp32 scale bit for bit.  Each shard contracts its slice into an
    int32 partial, and ``psum`` adds the partials — int32 addition is exact,
    so the dequant epilogue sees bit-identical accumulators to the unsharded
    kernel.  The epilogue is deliberately unfused here: fusion would rescale
    *partial* sums per shard and break that exactness.
    """
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w_q.shape[-1]
    tmac = w_q.ndim == 3
    packed = w_q.dtype == jnp.uint8 and not tmac
    if tmac:
        _, wspec, bits = parse_mode(mode)
    else:
        bits = 4 if packed else 8
    rows = w_q.shape[-2]
    Kl = 8 * rows if tmac else (2 * rows if packed else rows)
    qmax = 2 ** (bits - 1) - 1
    if K == Kl * size:
        # replicated input: quantize full-K, contract the local slice
        x2 = x.reshape(-1, K).astype(jnp.float32)
        a_q, a_scale = quantize_activations(x2, bits)
        a_l = jax.lax.dynamic_slice_in_dim(
            a_q, jax.lax.axis_index(axis) * Kl, Kl, axis=1)
    elif K == Kl:
        # head-sharded input: x IS the local K slice; the full-K per-token
        # max is the max of the per-shard maxima (exact)
        x2 = x.reshape(-1, K).astype(jnp.float32)
        local_max = jnp.max(jnp.abs(x2), axis=1, keepdims=True)
        a_scale = jnp.maximum(jax.lax.pmax(local_max, axis), 1e-8) / qmax
        a_l = _quantize_with_scale(x2, a_scale, qmax)
    else:
        raise ValueError(
            f"row-parallel activation K ({K}) matches neither the full "
            f"extent ({Kl * size}) nor this shard's slice ({Kl})")
    if tmac:
        acc = lutmul_tmac(a_l, w_q, wspec, abits=bits, backend=be)
    elif packed and mode == "w4a4_lut":
        acc = lutmul(a_l.astype(jnp.uint8) & 0xF, w_q, a_signed=True,
                     backend=be)
    else:
        acc = int_matmul(a_l, _unpack_w(w_q) if packed else w_q, backend=be)
    acc = jax.lax.psum(acc, axis)
    y = acc.astype(jnp.float32) * a_scale * w_scale.reshape(1, N)
    return y.reshape(*lead, N).astype(compute_dtype)


def prequant_matmul(x: jax.Array, w_q: jax.Array, w_scale: jax.Array,
                    mode: str = "", compute_dtype=jnp.bfloat16,
                    backend: Optional[str] = None,
                    tp: Optional[str] = None) -> jax.Array:
    """x: [..., K] float; w_q: packed-int4 uint8 [K//2, N] or int8 [K, N].

    Weight bytes on HBM are the integer codes (4x/2x smaller than bf16) —
    the serving embodiment of the paper's weights-live-in-LUTs idea.  On the
    kernel backends the dequant epilogue is fused: the int32 accumulator is
    rescaled in-kernel and written as ``compute_dtype`` directly.

    ``tp`` ("col" | "head" | "row" | None) is the tensor-parallel layout of
    ``w_q`` when tracing inside an active ``dist.tp.tp_context`` (the
    sharded serving engine): column-parallel computes the local N columns
    with the unsharded math and all-gathers; head-parallel is
    column-parallel *without* the gather (QKV projections whose local
    columns are whole attention heads — the caller keeps working on local
    heads); row-parallel contracts a K slice and psums the exact int32
    accumulator (see ``_row_parallel_prequant``).  Outside the context
    ``tp`` is ignored.
    """
    from repro.dist import tp as tp_lib
    axis = tp_lib.model_axis() if tp else None
    if axis is not None and tp == "row":
        return _row_parallel_prequant(x, w_q, w_scale, mode, compute_dtype,
                                      backend or get_backend(), axis,
                                      tp_lib.model_size())
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w_q.shape[-1]
    tmac = w_q.ndim == 3                     # bitplane leaf -> tmac kernel
    packed = w_q.dtype == jnp.uint8 and not tmac
    x2 = x.reshape(-1, K).astype(jnp.float32)
    if tmac:
        _, wspec, bits = parse_mode(mode)
        g = tmac_group_size(bits)
        _check_tmac_shapes(x2, w_q, wspec)
        op = f"lutmul_tmac{g}"
    else:
        if packed:             # both fused and unfused dispatch need this
            _check_lut_shapes(x2, w_q)
        bits = 4 if packed else 8
        op = "lutmul" if (packed and mode == "w4a4_lut") else "int_matmul"
    a_q, a_scale = quantize_activations(x2, bits)
    be = backend or get_backend()
    ws_row = w_scale.reshape(1, N)
    fused = (be != "ref"
             and pick_variant(op, x2.shape[0], K, N, be) == "fused")
    if fused:
        if tmac:
            y = _fused_tmac(a_q, w_q, a_scale, ws_row, wbits=wspec, g=g,
                            be=be, out_dtype=compute_dtype)
        elif packed and mode == "w4a4_lut":
            y = _fused_lut(a_q.astype(jnp.uint8) & 0xF, w_q, a_scale, ws_row,
                           a_signed=True, be=be, out_dtype=compute_dtype)
        else:
            y = _fused_int(a_q, _unpack_w(w_q) if packed else w_q, a_scale,
                           ws_row, be=be, out_dtype=compute_dtype)
        y = y.reshape(*lead, N)
    else:
        if tmac:
            acc = lutmul_tmac(a_q, w_q, wspec, g=g, abits=bits, backend=be)
        elif packed and mode == "w4a4_lut":
            acc = lutmul((a_q.astype(jnp.uint8)) & 0xF, w_q, a_signed=True,
                         backend=be)
        else:
            acc = int_matmul(a_q, _unpack_w(w_q) if packed else w_q,
                             backend=be)
        y = (acc.astype(jnp.float32) * a_scale * ws_row) \
            .reshape(*lead, N).astype(compute_dtype)
    if axis is not None and tp == "col":     # column-parallel: N is local
        y = jax.lax.all_gather(y, axis, axis=-1, tiled=True)
    return y                                 # "head": stays head-local


# ---------------------------------------------------------------------------
# high-level quantized projection used by models/layers.linear
# ---------------------------------------------------------------------------

def quantized_matmul(x: jax.Array, w: jax.Array, mode: str = "w4a4_mxu",
                     compute_dtype=jnp.bfloat16,
                     backend: Optional[str] = None) -> jax.Array:
    """Dynamic-activation-quant matmul: x [..., K] fp, w [K, N] fp.

    Weights: symmetric per-output-channel int4 (or int8); activations:
    symmetric per-token int4/int8 (transformer hidden states are signed — the
    unsigned-uint4+threshold path of the paper applies to post-ReLU CNNs and
    is exercised by the MobileNetV2 model).

    NOTE: this path re-quantizes ``w`` on every call — models that own their
    weights should quantize once via ``models.layers.QuantizedLinear`` (or
    ``serve.quantize``) and go through :func:`prequant_matmul`.
    """
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[-1]
    x2 = x.reshape(-1, K).astype(jnp.float32)
    wf = w.astype(jnp.float32)

    form, wspec, abits = parse_mode(mode)
    if form in ("tmac", "auto") and weight_bits(wspec) < 4:
        form = "tmac"          # sub-4 bit auto: tmac is the only exact fit
    if form == "tmac":
        w_planes, w_scale = quantize_weights_planes(wf, wspec)
        a_q, a_scale = quantize_activations(x2, abits)
        be = backend or get_backend()
        g = tmac_group_size(abits)
        fused = (be != "ref" and pick_variant(
            f"lutmul_tmac{g}", x2.shape[0], K, N, be) == "fused")
        if fused:
            y = _fused_tmac(a_q, w_planes, a_scale, w_scale, wbits=wspec,
                            g=g, be=be, out_dtype=compute_dtype)
            return y.reshape(*lead, N)
        acc = lutmul_tmac(a_q, w_planes, wspec, g=g, abits=abits, backend=be)
        y = acc.astype(jnp.float32) * a_scale * w_scale
        return y.reshape(*lead, N).astype(compute_dtype)

    bits = 4 if mode.startswith("w4") else 8
    a_q, a_scale = quantize_activations(x2, bits)
    w_q, w_scale = quantize_weights(wf, bits, pack=(mode == "w4a4_lut"))
    be = backend or get_backend()

    op = "lutmul" if mode == "w4a4_lut" else "int_matmul"
    if be != "ref" and pick_variant(op, x2.shape[0], K, N, be) == "fused":
        if mode == "w4a4_lut":
            y = _fused_lut(a_q.astype(jnp.uint8) & 0xF, w_q, a_scale, w_scale,
                           a_signed=True, be=be, out_dtype=compute_dtype)
        else:
            y = _fused_int(a_q, w_q, a_scale, w_scale, be=be,
                           out_dtype=compute_dtype)
        return y.reshape(*lead, N)
    if mode == "w4a4_lut":
        acc = lutmul((a_q.astype(jnp.uint8)) & 0xF, w_q, a_signed=True,
                     backend=be)
    else:  # w4a4_mxu / w8a8 — integer dot (MXU path)
        acc = int_matmul(a_q, w_q, backend=be)
    y = acc.astype(jnp.float32) * a_scale * w_scale
    return y.reshape(*lead, N).astype(compute_dtype)
