from repro.kernels.lutmul import kernel, ops, ref  # noqa: F401
