"""Pallas TPU kernel: fused multi-threshold activation epilogue.

The FPGA streams accumulator values through a comparator bank; the TPU
analogue holds the per-channel threshold bank [bn, K] in VMEM and emits uint
codes with a vectorized compare-and-sum — fused onto the lutmul accumulator
tile so the int32 accs never round-trip to HBM on the real target.

Block shapes align to (8, 128) int32 tiles; K (levels-1) is small (15 for
uint4) and lives entirely in registers after one VMEM load.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 256
DEFAULT_BN = 128


def _threshold_body(acc_ref, thr_ref, sign_ref, out_ref):
    acc = acc_ref[...].astype(jnp.float32)          # [bm, bn]
    thr = thr_ref[...]                              # [bn, K]
    sign = sign_ref[...]                            # [bn]
    a = acc * sign[None, :]
    # compare against every threshold level and popcount
    ge = a[:, :, None] >= thr[None, :, :]
    out_ref[...] = jnp.sum(ge.astype(jnp.int32), axis=-1)


def threshold_pallas(acc: jax.Array, thresholds: jax.Array, sign: jax.Array,
                     *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                     interpret: bool = True) -> jax.Array:
    """acc: [M, N] int32; thresholds: [N, K] f32; sign: [N] f32 -> int32 codes.

    M, N must be pre-padded to block multiples (ops.py handles it).
    """
    M, N = acc.shape
    K = thresholds.shape[1]
    grid = (M // bm, N // bn)
    return pl.pallas_call(
        _threshold_body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn, K), lambda i, j: (j, 0)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        interpret=interpret,
    )(acc, thresholds, sign)
