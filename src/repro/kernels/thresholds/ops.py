"""jit'd wrapper for the multi-threshold kernel (padding + backend dispatch),
and the fused integer stage: lutmul accumulate -> threshold emit."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.lutmul.ops import get_backend, lutmul
from repro.kernels.thresholds import kernel, ref


def threshold(acc: jax.Array, thresholds: jax.Array, sign: jax.Array,
              backend: Optional[str] = None) -> jax.Array:
    be = backend or get_backend()
    if be == "ref":
        return ref.threshold_ref(acc, thresholds, sign)
    M, N = acc.shape
    bm = min(kernel.DEFAULT_BM, max(8, 8 * (-(-M // 8))))
    bn = min(kernel.DEFAULT_BN, max(8, 8 * (-(-N // 8))))
    pm, pn = (-M) % bm, (-N) % bn
    acc_p = jnp.pad(acc, ((0, pm), (0, pn)))
    thr_p = jnp.pad(thresholds, ((0, pn), (0, 0)), constant_values=jnp.inf)
    sign_p = jnp.pad(sign, (0, pn), constant_values=1.0)
    out = kernel.threshold_pallas(acc_p, thr_p, sign_p, bm=bm, bn=bn,
                                  interpret=(be != "pallas"))
    return out[:M, :N]


def lutmul_threshold_stage(a_codes: jax.Array, w_packed: jax.Array,
                           thresholds: jax.Array, sign: jax.Array,
                           a_signed: bool = False,
                           backend: Optional[str] = None) -> jax.Array:
    """The paper's full integer stage: LUT multiply-accumulate then the
    threshold unit, end to end in integer arithmetic."""
    acc = lutmul(a_codes, w_packed, a_signed=a_signed, backend=backend)
    return threshold(acc, thresholds, sign, backend=backend)
