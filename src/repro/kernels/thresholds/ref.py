"""Oracle for the multi-threshold activation kernel: popcount of
``acc >= T[c,k]`` (paper Sec. 3.2's threshold unit), pure jnp."""
from __future__ import annotations

import jax.numpy as jnp


def threshold_ref(acc: jnp.ndarray, thresholds: jnp.ndarray,
                  sign: jnp.ndarray) -> jnp.ndarray:
    """acc: [M, N] int32; thresholds: [N, K] f32; sign: [N] f32 (+/-1).

    Returns uint codes [M, N] int32 in [0, K].
    """
    a = acc.astype(jnp.float32) * sign[None, :]
    return jnp.sum(a[:, :, None] >= thresholds[None, :, :],
                   axis=-1).astype(jnp.int32)
