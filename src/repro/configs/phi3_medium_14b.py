"""Phi-3-medium 14B [arXiv:2404.14219]: 40L d=5120, 40H (GQA kv=10,
head_dim 128), SwiGLU d_ff=17920, RoPE, vocab 100352."""
from repro.models.transformer import BlockSpec, ModelConfig

ARCH_ID = "phi3-medium-14b"


def config(quant: str = "none") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=40, d_model=5120, n_heads=40, n_kv=10, head_dim=128,
        d_ff=17920, vocab=100352,
        pattern=(BlockSpec(kind="attn", mlp="swiglu"),),
        rope_theta=10000.0, quant=quant,
        long_context_ok=False,
    )


def smoke_config(quant: str = "none") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=512,
        pattern=(BlockSpec(kind="attn", mlp="swiglu"),),
        rope_theta=10000.0, quant=quant, remat="none",
    )
