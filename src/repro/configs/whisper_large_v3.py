"""Whisper large-v3 [arXiv:2212.04356]: enc-dec, 32+32L d=1280, 20H
(head_dim 64), GELU d_ff=5120, vocab 51866, LayerNorm, sinusoidal positions.
Conv/mel frontend is a STUB: input_specs provides precomputed frame
embeddings [B, 1500, 1280]."""
from repro.models.transformer import BlockSpec, ModelConfig

ARCH_ID = "whisper-large-v3"


def config(quant: str = "none") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="audio",
        n_layers=32, d_model=1280, n_heads=20, n_kv=20, head_dim=64,
        d_ff=5120, vocab=51866,
        pattern=(BlockSpec(kind="attn", mlp="gelu"),),
        norm="layernorm", rope_mode="none", qkv_bias=True,
        enc_dec=True, n_enc_layers=32, enc_seq=1500, frontend="audio",
        tie_embeddings=True, quant=quant,
        long_context_ok=False,
    )


def smoke_config(quant: str = "none") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv=4, head_dim=16,
        d_ff=128, vocab=512,
        pattern=(BlockSpec(kind="attn", mlp="gelu"),),
        norm="layernorm", rope_mode="none", qkv_bias=True,
        enc_dec=True, n_enc_layers=2, enc_seq=32, frontend="audio",
        tie_embeddings=True, quant=quant, remat="none",
    )
