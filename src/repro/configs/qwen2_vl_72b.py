"""Qwen2-VL 72B [arXiv:2409.12191]: 80L d=8192, 64H (GQA kv=8, head_dim 128),
SwiGLU d_ff=29568, vocab 152064, M-RoPE (sections t/h/w = 16/24/24 over
head_dim/2), QKV bias.  Vision patch frontend is a STUB: input_specs provides
precomputed patch/text embeddings [B, S, d] + 3D m-rope position ids."""
from repro.models.transformer import BlockSpec, ModelConfig

ARCH_ID = "qwen2-vl-72b"


def config(quant: str = "none") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv=8, head_dim=128,
        d_ff=29568, vocab=152064, qkv_bias=True,
        pattern=(BlockSpec(kind="attn", mlp="swiglu"),),
        rope_mode="mrope", mrope_sections=(16, 24, 24),
        rope_theta=1_000_000.0, frontend="vision", quant=quant,
        long_context_ok=False,
    )


def smoke_config(quant: str = "none") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=512, qkv_bias=True,
        pattern=(BlockSpec(kind="attn", mlp="swiglu"),),
        rope_mode="mrope", mrope_sections=(2, 3, 3),
        rope_theta=1_000_000.0, frontend="vision", quant=quant, remat="none",
    )
