"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B]: 24L d=2048, 16H (kv=16,
head_dim 128), MoE: 60 routed experts top-4 (expert d_ff=1408) + shared
expert (d_ff 5632, sigmoid gate), vocab 151936, QKV bias."""
from repro.models.moe import MoEConfig
from repro.models.transformer import BlockSpec, ModelConfig

ARCH_ID = "qwen2-moe-a2.7b"


def config(quant: str = "none") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        n_layers=24, d_model=2048, n_heads=16, n_kv=16, head_dim=128,
        d_ff=1408, vocab=151936, qkv_bias=True,
        pattern=(BlockSpec(kind="attn", mlp="moe"),),
        moe=MoEConfig(n_experts=60, top_k=4, d_ff=1408, shared_ff=5632,
                      norm_topk=False, dispatch="global"),
        rope_theta=1_000_000.0, quant=quant,
        long_context_ok=False,
    )


def smoke_config(quant: str = "none") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv=4, head_dim=16,
        d_ff=32, vocab=512, qkv_bias=True,
        pattern=(BlockSpec(kind="attn", mlp="moe"),),
        # capacity 2.0 = E/top_k: drop-free (exact prefill/decode agreement);
        # the full config keeps the GShard 1.25 (drops under adversarial load)
        moe=MoEConfig(n_experts=8, top_k=4, d_ff=32, shared_ff=64,
                      norm_topk=False, capacity_factor=2.0),
        rope_theta=1_000_000.0, quant=quant, remat="none",
    )
