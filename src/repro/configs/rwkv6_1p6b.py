"""RWKV6 "Finch" 1.6B [arXiv:2404.05892]: 24L d=2048, attention-free,
data-dependent decay, channel-mix d_ff=7168, vocab 65536."""
from repro.models.transformer import BlockSpec, ModelConfig

ARCH_ID = "rwkv6-1.6b"


def config(quant: str = "none") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="ssm",
        n_layers=24, d_model=2048, n_heads=32, n_kv=32, head_dim=64,
        d_ff=7168, vocab=65536,
        pattern=(BlockSpec(kind="rwkv6", mlp="rwkv_cm"),),
        rwkv_heads=32, rope_mode="none", norm="layernorm",
        tie_embeddings=False, quant=quant,
        long_context_ok=True,
    )


def smoke_config(quant: str = "none") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=4, n_kv=4, head_dim=16,
        d_ff=128, vocab=512,
        pattern=(BlockSpec(kind="rwkv6", mlp="rwkv_cm"),),
        rwkv_heads=4, rope_mode="none", norm="layernorm",
        tie_embeddings=False, quant=quant, remat="none",
        long_context_ok=True,
    )
