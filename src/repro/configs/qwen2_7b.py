"""Qwen2-7B [arXiv:2407.10671]: 28L d=3584, 28H (GQA kv=4, head_dim 128),
SwiGLU d_ff=18944, QKV bias, vocab 152064, rope theta 1e6."""
from repro.models.transformer import BlockSpec, ModelConfig

ARCH_ID = "qwen2-7b"


def config(quant: str = "none") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=28, d_model=3584, n_heads=28, n_kv=4, head_dim=128,
        d_ff=18944, vocab=152064, qkv_bias=True,
        pattern=(BlockSpec(kind="attn", mlp="swiglu"),),
        rope_theta=1_000_000.0, quant=quant,
        long_context_ok=False,
    )


def smoke_config(quant: str = "none") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=512, qkv_bias=True,
        pattern=(BlockSpec(kind="attn", mlp="swiglu"),),
        rope_theta=1_000_000.0, quant=quant, remat="none",
    )
