"""Architecture registry + assigned input shapes.

Every assigned arch ships ``config()`` (the exact published configuration) and
``smoke_config()`` (a reduced same-family config for CPU tests).  The shape
pool is fixed by the assignment; applicability of ``long_500k``/decode shapes
is a property of the architecture (see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
import importlib

ARCH_IDS = [
    "rwkv6_1p6b",
    "zamba2_2p7b",
    "gemma2_2b",
    "phi3_medium_14b",
    "qwen2_7b",
    "minicpm_2b",
    "whisper_large_v3",
    "qwen2_moe_a2p7b",
    "mixtral_8x22b",
    "qwen2_vl_72b",
    "bitnet_3b",
]

# external ids (CLI --arch) -> module names
ALIASES = {
    "rwkv6-1.6b": "rwkv6_1p6b",
    "zamba2-2.7b": "zamba2_2p7b",
    "gemma2-2b": "gemma2_2b",
    "phi3-medium-14b": "phi3_medium_14b",
    "qwen2-7b": "qwen2_7b",
    "minicpm-2b": "minicpm_2b",
    "whisper-large-v3": "whisper_large_v3",
    "qwen2-moe-a2.7b": "qwen2_moe_a2p7b",
    "mixtral-8x22b": "mixtral_8x22b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "mobilenetv2": "mobilenetv2",
    "bitnet-3b": "bitnet_3b",
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_module(arch: str):
    name = ALIASES.get(arch, arch)
    return importlib.import_module(f"repro.configs.{name}")


def get_config(arch: str, smoke: bool = False, **kw):
    mod = get_module(arch)
    return mod.smoke_config(**kw) if smoke else mod.config(**kw)


def shape_applicable(cfg, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    if shape.name == "long_500k" and not getattr(cfg, "long_context_ok", False):
        return False, ("pure full-attention architecture: 500k decode KV is "
                       "quadratic-history; skipped per assignment "
                       "(see DESIGN.md §Arch-applicability)")
    return True, ""
