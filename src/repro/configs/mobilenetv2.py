"""MobileNetV2 [arXiv:1801.04381] — the paper's own evaluation network
(W4A4 channel-wise QAT, 8-bit first/last layers; Table 2)."""
from repro.models.mobilenet import MobileNetConfig

ARCH_ID = "mobilenetv2"


def config(quant: str = "qat") -> MobileNetConfig:
    return MobileNetConfig(name=ARCH_ID, width=1.0, resolution=224,
                           n_classes=1000, quant=quant)


def smoke_config(quant: str = "qat") -> MobileNetConfig:
    return MobileNetConfig(name=ARCH_ID + "-smoke", width=0.25, resolution=32,
                           n_classes=10, quant=quant)
