"""Mixtral 8x22B [arXiv:2401.04088]: 56L d=6144, 48H (GQA kv=8, head_dim 128),
8 experts top-2 (expert d_ff=16384), sliding-window attention (4096, rolling
cache), vocab 32768."""
from repro.models.moe import MoEConfig
from repro.models.transformer import BlockSpec, ModelConfig

ARCH_ID = "mixtral-8x22b"


def config(quant: str = "none") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        n_layers=56, d_model=6144, n_heads=48, n_kv=8, head_dim=128,
        d_ff=16384, vocab=32768,
        pattern=(BlockSpec(kind="attn", attn_type="local", mlp="moe"),),
        window=4096,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=16384, norm_topk=True),
        rope_theta=1_000_000.0, quant=quant,
        long_context_ok=True,    # SWA: rolling 4096 cache bounds decode state
    )


def smoke_config(quant: str = "none") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=64, vocab=512,
        pattern=(BlockSpec(kind="attn", attn_type="local", mlp="moe"),),
        window=8,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=64, norm_topk=True,
                      capacity_factor=2.0),
        rope_theta=1_000_000.0, quant=quant, remat="none",
        long_context_ok=True,
    )
