"""Gemma-2 2B [arXiv:2408.00118]: 26L d=2304, 8H (GQA kv=4, head_dim 256),
GeGLU d_ff=9216, vocab 256000, alternating local(4096)/global attention,
attn softcap 50 / final softcap 30, tied embeddings, pre+post RMSNorm."""
from repro.models.transformer import BlockSpec, ModelConfig

ARCH_ID = "gemma2-2b"


def config(quant: str = "none") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=26, d_model=2304, n_heads=8, n_kv=4, head_dim=256,
        d_ff=9216, vocab=256000,
        pattern=(BlockSpec(kind="attn", attn_type="local", mlp="geglu"),
                 BlockSpec(kind="attn", attn_type="global", mlp="geglu")),
        window=4096, attn_softcap=50.0, final_softcap=30.0,
        gemma_norms=True, tie_embeddings=True, embed_scale=True,
        rope_theta=10000.0, quant=quant,
        long_context_ok=True,   # local layers bounded; global layers B=1 full KV
    )


def smoke_config(quant: str = "none") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=512,
        pattern=(BlockSpec(kind="attn", attn_type="local", mlp="geglu"),
                 BlockSpec(kind="attn", attn_type="global", mlp="geglu")),
        window=8, attn_softcap=50.0, final_softcap=30.0,
        gemma_norms=True, tie_embeddings=True, embed_scale=True,
        rope_theta=10000.0, quant=quant, remat="none",
        long_context_ok=True,
    )
