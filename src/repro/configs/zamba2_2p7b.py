"""Zamba2 2.7B [arXiv:2411.15242]: 54 Mamba2 blocks d=2560 (state 64) with a
shared attention(+MLP d_ff=10240) block applied every 6 blocks, 32H kv=32,
vocab 32000."""
from repro.models.transformer import BlockSpec, ModelConfig

ARCH_ID = "zamba2-2.7b"


def config(quant: str = "none") -> ModelConfig:
    mamba = BlockSpec(kind="mamba2", mlp="none")
    return ModelConfig(
        name=ARCH_ID, family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv=32, head_dim=80,
        d_ff=10240, vocab=32000,
        pattern=(BlockSpec(kind="mamba2", mlp="none", shared_attn=True),
                 mamba, mamba, mamba, mamba, mamba),
        d_inner=5120, d_state=64, ssm_heads=80,
        rope_theta=10000.0, quant=quant,
        long_context_ok=True,
    )


def smoke_config(quant: str = "none") -> ModelConfig:
    mamba = BlockSpec(kind="mamba2", mlp="none")
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv=4, head_dim=16,
        d_ff=128, vocab=512,
        pattern=(BlockSpec(kind="mamba2", mlp="none", shared_attn=True),
                 mamba),
        d_inner=128, d_state=16, ssm_heads=4,
        rope_theta=10000.0, quant=quant, remat="none",
        long_context_ok=True,
    )
