"""BitNet-b1.58 3B [arXiv:2402.17764]: LLaMA-shaped ternary-weight LM —
26L d=3200, 32H (MHA, head_dim 100), SwiGLU d_ff=8640, vocab 32000.

Weights are {-1, 0, +1} at ~1.58 bits with per-channel mean-|w| scales
(``ops.quantize_weights_planes``), activations int8 per-token — the
``ternary_a8_tmac`` serving mode.  The tmac kernel contracts 2 bitplanes,
so decode weight traffic is ~10x smaller than bf16 and the kernel does
half the MXU work of the w4 one-hot path (SNIPPETS.md carries the BitNet
CPU reference numbers: tl2 3B ~60-75 tok/s on 8 cores — the cost-vs-bits
curve in BENCH_kernels.json is our MXU analogue).
"""
from repro.models.transformer import BlockSpec, ModelConfig

ARCH_ID = "bitnet-3b"


def config(quant: str = "ternary_a8_tmac") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=26, d_model=3200, n_heads=32, n_kv=32, head_dim=100,
        d_ff=8640, vocab=32000,
        pattern=(BlockSpec(kind="attn", attn_type="global", mlp="swiglu"),),
        rope_theta=10000.0, quant=quant,
    )


def smoke_config(quant: str = "ternary_a8_tmac") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv=4, head_dim=16,
        d_ff=128, vocab=512,
        pattern=(BlockSpec(kind="attn", attn_type="global", mlp="swiglu"),),
        rope_theta=10000.0, quant=quant, remat="none",
    )
