"""MiniCPM 2B [arXiv:2404.06395]: 40L d=2304, 36H (kv=36, head_dim 64),
SwiGLU d_ff=5760, vocab 122753, tied embeddings, trained with the WSD
schedule (implemented in optim/schedules.py and selected by this config)."""
from repro.models.transformer import BlockSpec, ModelConfig

ARCH_ID = "minicpm-2b"
TRAIN_SCHEDULE = "wsd"


def config(quant: str = "none") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=40, d_model=2304, n_heads=36, n_kv=36, head_dim=64,
        d_ff=5760, vocab=122753, tie_embeddings=True,
        pattern=(BlockSpec(kind="attn", mlp="swiglu"),),
        rope_theta=10000.0, quant=quant,
        long_context_ok=False,
    )


def smoke_config(quant: str = "none") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv=4, head_dim=16,
        d_ff=128, vocab=512, tie_embeddings=True,
        pattern=(BlockSpec(kind="attn", mlp="swiglu"),),
        rope_theta=10000.0, quant=quant, remat="none",
    )
