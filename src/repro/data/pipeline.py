"""Deterministic, shard-aware synthetic data pipeline.

Every host generates exactly its shard of the global batch from
(seed, step, shard_index) — no host-to-host coordination, which is the
property that makes elastic restarts and straggler exclusion cheap: a host
that takes over another's shard produces bit-identical data.

Synthetic task: next-token prediction over a mixture of periodic integer
sequences (learnable — losses drop fast, used by the QAT/convergence tests)
plus uniform noise tokens.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab: int = 1024
    seq_len: int = 128
    global_batch: int = 8
    n_shards: int = 1
    shard: int = 0
    noise_frac: float = 0.1


def _batch_rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.shard]))


def lm_batch(cfg: DataConfig, step: int) -> dict:
    """Returns {"tokens": [b, S], "labels": [b, S]} for this shard."""
    b = cfg.global_batch // cfg.n_shards
    rng = _batch_rng(cfg, step)
    period = rng.integers(2, 17, size=(b, 1))
    phase = rng.integers(0, cfg.vocab, size=(b, 1))
    stride = rng.integers(1, 7, size=(b, 1))
    t = np.arange(cfg.seq_len + 1)[None, :]
    seq = (phase + stride * (t % period)) % cfg.vocab
    noise = rng.random(size=seq.shape) < cfg.noise_frac
    seq = np.where(noise, rng.integers(0, cfg.vocab, size=seq.shape), seq)
    return {"tokens": seq[:, :-1].astype(np.int32),
            "labels": seq[:, 1:].astype(np.int32)}


def image_batch(cfg: DataConfig, step: int, resolution: int = 32,
                n_classes: int = 10) -> dict:
    """Class-conditional gaussian-blob images (QAT accuracy benches)."""
    b = cfg.global_batch // cfg.n_shards
    rng = _batch_rng(cfg, step)
    labels = rng.integers(0, n_classes, size=(b,))
    base = rng.standard_normal((n_classes, resolution, resolution, 3)) * 0.0
    # deterministic per-class pattern
    cls_rng = np.random.default_rng(cfg.seed + 1234)
    patterns = cls_rng.standard_normal((n_classes, resolution, resolution, 3))
    imgs = patterns[labels] + 0.3 * rng.standard_normal(
        (b, resolution, resolution, 3))
    return {"images": imgs.astype(np.float32),
            "labels": labels.astype(np.int32)}


def iterate(cfg: DataConfig, start_step: int = 0,
            kind: str = "lm", **kw) -> Iterator[dict]:
    step = start_step
    while True:
        yield (lm_batch(cfg, step) if kind == "lm"
               else image_batch(cfg, step, **kw))
        step += 1
