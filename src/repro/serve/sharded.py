"""Multi-device serving engine: tensor-parallel LUT matmuls over a ``model``
mesh axis x a data-parallel slot pool over a ``data`` axis.

The LUTMUL scale-out argument — beat the roofline by fanning multiplication
across many cheap units instead of speeding one up — applied at the device
level: every quantized projection's integer codes are split across the
``model`` axis (column-parallel N split with an all-gather, row-parallel K
split with an exact int32 psum; see ``dist.tp``), while the serving state
(decode slots, per-slot positions, KV/ring caches, sampling vectors, RNG
streams) is split across the ``data`` axis so each data shard runs an
independent slot pool under ONE host-side ``serve.scheduler.Scheduler``.

``ShardedEngine`` reuses ``Engine``'s admission/decode *implementations*
unchanged — it only overrides how they are compiled: the bodies run under
``shard_map`` with an active ``tp_context``, so the same model code that is
the single-device engine becomes the per-shard program.  Because every
sharded reduction is either exact (int32 psum) or a reordering-free gather,
temperature-0 output is bit-identical to the single-device engine.

Runs anywhere ``jax.devices()`` offers enough devices — including CPU via
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI recipe).
"""
from __future__ import annotations

import dataclasses

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist import tp as tp_lib
from repro.launch.specs import serving_cache_specs, serving_chunk_specs
from repro.serve import engine as engine_lib
from repro.serve.engine import Engine, ServeConfig
from repro.serve.quantize import quantize_params_for_serving


class ShardedEngine(Engine):
    """Drop-in ``Engine`` for the scheduler, executing on a (data, model)
    mesh.  ``slots`` handed to ``Scheduler``/``init_cache`` must be divisible
    by the data-axis size; quantized serving codes are required (only
    integer-code matmuls shard bit-exactly — see ``dist.tp``)."""

    def __init__(self, cfg, params, scfg: ServeConfig = ServeConfig(), *,
                 mesh: Mesh, data_axis: str = "data",
                 model_axis: str = "model"):
        if getattr(cfg, "enc_dec", False):
            raise NotImplementedError(
                "sharded serving covers decoder-only LMs")
        if not scfg.quant:
            raise ValueError(
                "ShardedEngine requires ServeConfig(quant=...): only integer "
                "weight codes shard bit-exactly (int32 psum is associative; "
                "a float row-parallel reduction would drift)")
        self.mesh = mesh
        self.data_axis, self.model_axis = data_axis, model_axis
        self.n_data = mesh.shape[data_axis]
        self.n_model = mesh.shape[model_axis]
        # quantize + mark BEFORE Engine.__init__: _build_admit_fn (called by
        # the base ctor) closes over the param/cache specs.  head_dim lets
        # the marker go head-parallel on attention groups (QKV stay local,
        # attention runs on n_heads/tp heads per shard) when the head counts
        # divide the model axis; the KV cache layout below keys off whether
        # that actually happened.
        params = quantize_params_for_serving(params, mode=scfg.quant,
                                             bits_plan=scfg.bits_plan)
        params, self._param_specs, self.n_tp_leaves = tp_lib.mark_tp_params(
            params, self.n_model, model_axis, head_dim=cfg.head_dim)
        n_attn, n_head_marked = tp_lib.attn_group_counts(params)
        if n_head_marked not in (0, n_attn):
            # the KV-cache layout below is one global choice: a tree where
            # only SOME attention groups went head-parallel (heterogeneous
            # per-layer head counts) cannot be cached consistently
            raise ValueError(
                f"head marking must be all-or-nothing across attention "
                f"groups, got {n_head_marked}/{n_attn}")
        self.head_sharded = n_head_marked > 0
        # canonical specs (no trailing Nones, size-1 axes elided) — exactly
        # the form XLA hands back on computation outputs, so round-tripped
        # slot state / caches never change the executors' cache signature
        self._dspec = P(data_axis) if self.n_data > 1 else P()
        # the struct covers BOTH layouts: dense [G, slots, T, H, D] rows and
        # paged [G, pages, page_size, H, D] pools put their data-split axis
        # (slots / pages) at dim 1 and their head axis at dim 3, so one spec
        # tree serves either
        self._cache_specs = serving_cache_specs(
            engine_lib.cache_struct(cfg, scfg, self.n_data, self.n_data),
            data_axis if self.n_data > 1 else None,
            model_axis if self.head_sharded else None)
        # paged serving: the pool page axis splits over the data axis —
        # each data shard runs an independent allocator + prefix registry
        # over shard-local page ids
        super().__init__(cfg, params,
                         dataclasses.replace(scfg, quant=None),
                         n_page_shards=self.n_data)
        self.scfg = scfg                     # keep the quant label visible
        self.params = jax.device_put(
            self.params, jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), self._param_specs))

    # -- shard_map-compiled executors ---------------------------------------

    def _shard_jit(self, impl, in_specs, out_specs):
        def body(*args):
            with tp_lib.tp_context(self.model_axis, self.n_model,
                                   self.data_axis):
                return impl(*args)
        # explicit in_shardings keep argument placement out of the jit cache
        # key: committed outputs fed back next round (whose specs XLA may
        # have normalized, e.g. P("data") -> P() on a size-1 axis) reshard
        # instead of retracing — the no-retrace-after-warmup invariant
        return jax.jit(
            shard_map(body, mesh=self.mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=False),
            in_shardings=jax.tree_util.tree_map(
                lambda s: NamedSharding(self.mesh, s), in_specs),
            donate_argnums=1)

    def _build_admit_fn(self):
        d = self._dspec
        in_specs = (self._param_specs, self._cache_specs,
                    d,                              # prompts [run, exact len]
                    d, d, d,                        # lengths, mask, budget_one
                    d, d, d, d,                     # eos, temp, top_k, top_p
                    d, d, d,                        # tok, pos, done
                    P(), P())                       # key, step0
        if self.scfg.paged:
            # page tables + start_tok split with the slots they describe
            # (table VALUES are shard-local page ids)
            in_specs += (d, d, d)
        out_specs = (self._cache_specs, d, d, d, d, d,
                     d)                              # ok0 finite-logits guard
        return self._shard_jit(self._admit_impl, in_specs, out_specs)

    def _build_step_fn(self, C: int, chunk: int, greedy: bool,
                       spec: bool = False):
        d = self._dspec
        in_specs = (self._param_specs, self._cache_specs,
                    *serving_chunk_specs(),         # slot, tok, pos, first, b1
                    d, d, d,                        # tok, pos, done
                    d, d, d, d,                     # eos, temp, top_k, top_p
                    P(), P())                       # key, step0
        if self.scfg.paged:
            in_specs += (d, d)                      # full + ring page tables
        out_specs = (self._cache_specs, d, d, d,
                     d, d,                # first tokens/dones [slots]
                     d, d,                # decode tokens/dones [slots, W]
                     d,                   # ok finite-logits guard
                     d)                   # n_valid accepted-width [slots]
        return self._shard_jit(self._make_step_impl(C, chunk, greedy, spec),
                               in_specs, out_specs)

    # -- scheduler-facing API ------------------------------------------------

    def init_cache(self, batch: int):
        if batch % self.n_data:
            raise ValueError(
                f"slots ({batch}) must be divisible by the data-axis size "
                f"({self.n_data}) — each data shard runs batch/{self.n_data} "
                "independent decode lanes")
        return jax.device_put(
            super().init_cache(batch), jax.tree_util.tree_map(
                lambda s: NamedSharding(self.mesh, s), self._cache_specs))

    def place_slot_state(self, x):
        return jax.device_put(x, NamedSharding(self.mesh, self._dspec))

    def place_cache(self, cache):
        """Re-pin a host-restored cache tree onto the canonical cache
        shardings (restores never change the executors' input signature)."""
        return jax.device_put(cache, jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s), self._cache_specs))

    def serving_state_shardings(self):
        dsh = NamedSharding(self.mesh, self._dspec)
        return {"cache": jax.tree_util.tree_map(
                    lambda s: NamedSharding(self.mesh, s), self._cache_specs),
                "tok": dsh, "pos": dsh, "done": dsh}

    def kv_cache_bytes(self, batch: int) -> int:
        """PER-SHARD bytes of the attention KV leaves: the data axis splits
        the ``batch`` slots and — when head-sharded — the model axis splits
        the KV heads, so the figure shrinks by ``n_data * n_model`` on
        divisible configs (vs ``n_data`` alone with replicated heads).

        Paged engines report per-shard *allocated residency* instead: the
        busiest shard's peak in-use pages times the per-shard page
        footprint (pages hold ``n_kv / n_model`` local heads when
        head-sharded)."""
        from repro.launch.specs import (KV_CACHE_LEAVES, KV_SCALE_LEAVES,
                                        _leaf_key)
        if self.paged and self.pool is not None:
            per_page = self.page_bytes(batch)
            if self.head_sharded:
                per_page //= self.n_model
            return self.pool.peak_pages_per_shard * per_page
        names = KV_CACHE_LEAVES | KV_SCALE_LEAVES
        sds = self._cache_sds(batch)
        # the engine's live specs are batch-independent (same leaf names and
        # ranks for any slot count) — reusing them keeps this report and the
        # actual executor sharding from ever diverging
        specs = self._cache_specs
        total = 0
        for (path, leaf), spec in zip(
                jax.tree_util.tree_flatten_with_path(sds)[0],
                jax.tree_util.tree_leaves(
                    specs, is_leaf=lambda x: isinstance(x, P))):
            if _leaf_key(path) not in names:
                continue
            div = 1
            for entry in spec:
                if entry is None:
                    continue
                for ax in (entry if isinstance(entry, tuple) else (entry,)):
                    div *= self.mesh.shape[ax]
            total += leaf.size * leaf.dtype.itemsize // div
        return total

    def generate(self, *a, **kw):
        raise NotImplementedError(
            "ShardedEngine serves through serve.scheduler.Scheduler "
            "(the unified step / admit_monolithic); use the single-device "
            "Engine for the static-batch generate() oracle")
