"""Serving subsystem: continuous batching over static-shape decode buffers.

Architecture (one compiled graph per box, arrows are host-side control)::

    Request ──▶ Scheduler (FIFO queue, slot map) ──▶ Engine (batch executor)
                   │  admit: admit_batch = ONE dispatch — batched
                   │         [slots, bucket] prefill + masked cache-stitch
                   │         + first-token sampling + slot-state merge
                   └─ rounds: decode_chunk (lax.scan over `chunk` tokens,
                              on-device sampling, per-sequence positions)

Static-shape invariants:
  * live caches are allocated once at ``[G, slots, max_len, ...]``; admission
    and decode never reshape them — the stitch writes the masked slot rows
    with traced true prompt lengths, and local/SWA layers' window rings are
    arranged at stitch time from the true length (padded prompt buckets
    never leak junk into ring slots; SSM/RWKV models, whose recurrent states
    are not pad-invariant, admit at exact length in equal-length groups);
  * decode positions are per-sequence ``pos: [slots]`` int32 — every slot at
    its own depth; a negative position is the free-slot sentinel (all keys of
    that row stay masked, its writes land inside its own row);
  * after warmup there is NO ``jax.jit`` retrace: prefill/stitch compile once
    per prompt bucket and ``decode_chunk`` exactly once — slot index, length,
    token/position/done vectors, EOS ids, and sampling parameters are all
    traced values.

``Engine.generate`` keeps the static-batch path (all sequences in lock-step)
as the bit-exactness oracle: at temperature 0 the scheduler emits the same
tokens per request as one-shot static batching.

``serve.sharded.ShardedEngine`` is the multi-device drop-in: the same
admission/decode bodies compiled under ``shard_map`` over a (data, model)
mesh — tensor-parallel integer-code matmuls along ``model``, an independent
slot-pool shard per ``data`` index — with temperature-0 output bit-identical
to the single-device engine.

``ServeConfig(paged=True)`` swaps the dense per-slot KV buffers for the
paged pool (``serve.paged``): shared per-layer page stores + fixed-shape
per-slot page tables, prefix reuse via hash-chained page identity, and
block-granular admission with deterministic preempt-and-requeue when the
pool exhausts — still bit-identical at temperature 0, still retrace-free
(tables change values, never shapes).

Fault tolerance (``serve.faults`` + scheduler hooks): requests carry
logical-time ``deadline``/``priority``; the scheduler expires, sheds, and
preempts deterministically from the caller's ``now=`` clock; a seeded
``FaultPlan`` injects NaN/page-table/dispatch/stall faults at the two engine
dispatch sites, and detection (finite-logits + cache-finiteness + pool
audits) plus rolling host snapshots give token-identical replay recovery.
"""
from repro.serve.engine import Engine, ServeConfig, sample_logits
from repro.serve.faults import (CacheCorruption, EngineFault, Fault,
                                FaultPlan, InjectedFault)
from repro.serve.paged import PagedLayout, PagePool
from repro.serve.request import Request, RequestStatus
from repro.serve.scheduler import Scheduler
from repro.serve.sharded import ShardedEngine

__all__ = ["Engine", "ServeConfig", "Request", "RequestStatus", "Scheduler",
           "ShardedEngine", "PagePool", "PagedLayout", "sample_logits",
           "FaultPlan", "Fault", "EngineFault", "InjectedFault",
           "CacheCorruption"]
