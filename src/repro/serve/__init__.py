"""Serving subsystem: continuous batching over static-shape decode buffers.

Architecture (one compiled graph per round, arrows are host-side control)::

    Request ──▶ Scheduler (FIFO queue, slot map) ──▶ Engine (batch executor)
                   │  round: step = ONE dispatch — a [prefill_chunk] lane of
                   │         masked single-token prefill iterations (each
                   │         targeting one slot, sampling the first token
                   │         when its prompt completes) followed by a
                   │         lax.scan over `chunk` full-batch decode tokens
                   └─ fallback: admit_monolithic — exact-length batched
                               prefill + cache-stitch for models whose
                               chunked state cannot be rebuilt per-token
                               (enc-dec, SSM/RWKV recurrent state, int8 KV,
                               MoE capacity, prompts past an SWA window)

Chunked prefill: prompts are split into page-aligned chunks and interleaved
with decode inside one fixed-shape step, so a long prompt never stalls the
decode lanes of other slots (no bimodal latency) and the chunk lane is
always full under backlog (padding waste ~1.0).  Size the lane with
``ServeConfig.prefill_chunk``; chunk-ineligible requests fall back to
monolithic admission in equal-length groups.

Static-shape invariants:
  * live caches are allocated once at ``[G, slots, max_len, ...]``; steps
    never reshape them — chunk iterations write one (token, position) per
    slot behind a masked target row, and monolithic stitches write masked
    slot rows with traced true prompt lengths;
  * decode positions are per-sequence ``pos: [slots]`` int32 — every slot at
    its own depth; a negative position is the free-slot sentinel (all keys of
    that row stay masked, its writes land inside its own row); a mid-prefill
    slot parks with ``done=True`` at its next unprocessed (token, position)
    so decode-lane re-runs are idempotent same-bit rewrites;
  * after warmup there is NO ``jax.jit`` retrace: the unified ``step``
    compiles once per (prefill_chunk, chunk, greedy) signature — slot ids,
    tokens, positions, done flags, EOS ids, and sampling parameters are all
    traced values (monolithic fallback admissions compile per exact length).

``Engine.generate`` keeps the static-batch path (all sequences in lock-step)
as the bit-exactness oracle: at temperature 0 the scheduler emits the same
tokens per request as one-shot static batching.

``serve.sharded.ShardedEngine`` is the multi-device drop-in: the same step /
admission bodies compiled under ``shard_map`` over a (data, model) mesh —
tensor-parallel integer-code matmuls along ``model``, an independent
slot-pool shard per ``data`` index — with temperature-0 output bit-identical
to the single-device engine.  ``make_engine`` picks the class from whether a
mesh is supplied.

``ServeConfig(paged=True)`` swaps the dense per-slot KV buffers for the
paged pool (``serve.paged``): shared per-layer page stores + fixed-shape
per-slot page tables, prefix reuse via hash-chained page identity (gated on
pages whose content is actually written), and block-granular admission with
deterministic preempt-and-requeue when the pool exhausts — still
bit-identical at temperature 0, still retrace-free (tables change values,
never shapes).

Fault tolerance (``serve.faults`` + scheduler hooks): requests carry
logical-time ``deadline``/``priority``; the scheduler expires, sheds, and
preempts deterministically from the caller's ``now=`` clock; a seeded
``FaultPlan`` injects NaN/page-table/dispatch/stall faults at the two engine
dispatch sites, and detection (finite-logits + cache-finiteness + pool
audits) plus rolling host snapshots give token-identical replay recovery —
snapshots carry mid-prefill chunk progress, so replay resumes partially
prefilled prompts exactly.
"""
from repro.serve.engine import Engine, ServeConfig, sample_logits
from repro.serve.faults import (CacheCorruption, EngineFault, Fault,
                                FaultPlan, InjectedFault)
from repro.serve.paged import PagedLayout, PagePool
from repro.serve.request import Request, RequestStatus
from repro.serve.scheduler import Scheduler
from repro.serve.sharded import ShardedEngine


def make_engine(params, cfg, scfg: ServeConfig = ServeConfig(), *,
                mesh=None, data_axis: str = "data",
                model_axis: str = "model"):
    """Build the right engine for the deployment: a single-device ``Engine``
    when ``mesh`` is None, else a ``ShardedEngine`` over the (data, model)
    mesh.  Both are drop-in executors for ``Scheduler``; callers pick the
    topology in one place instead of branching on the class."""
    if mesh is None:
        return Engine(cfg, params, scfg)
    return ShardedEngine(cfg, params, scfg, mesh=mesh,
                         data_axis=data_axis, model_axis=model_axis)


__all__ = ["Engine", "ServeConfig", "Request", "RequestStatus", "Scheduler",
           "ShardedEngine", "make_engine", "PagePool", "PagedLayout",
           "sample_logits", "FaultPlan", "Fault", "EngineFault",
           "InjectedFault", "CacheCorruption"]
