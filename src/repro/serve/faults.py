"""Deterministic fault injection for the serving stack.

A ``FaultPlan`` is a seeded, replayable list of faults the engine applies at
its two dispatch sites (``admit`` / ``decode``), keyed on a MONOTONE
per-site dispatch counter.  The counter never rewinds — after a recovery
restores an earlier snapshot, the replayed dispatches run at *higher*
indices, so a consumed fault does not re-fire.  That is the transient-fault
model: each injected fault happens exactly once, and the differential the
tests assert is that transcripts with faults + recovery are token-identical
to the fault-free run.

Fault categories (the ``kind`` field):

  * ``"nan_logits"`` — poison the live KV cache of one ACTIVE slot with a
    NaN (the float K row at position 0 for float caches, the ``k_scale``
    plane for int8-KV, the mapped pool page for paged engines).  The real
    compiled decode/admit path then produces non-finite logits for that
    row, which the engine's finite-logits guard surfaces to the scheduler.
  * ``"page_table"`` — corrupt one row of the host page table with an
    out-of-range page id; ``PagePool.validate()`` catches it before the
    poisoned table is snapshotted to device.  Skipped (marked fired) on
    dense engines.
  * ``"dispatch"`` — raise :class:`InjectedFault` BEFORE the compiled call
    (the lost-accelerator-call category).  Engine and scheduler state are
    untouched, so a retry round simply re-dispatches.
  * ``"stall"`` — ``time.sleep`` at the dispatch boundary (slow host).
    Logical time does not observe it, so transcripts are unaffected; it
    exists to exercise wall-clock-independent behaviour and the chaos CI
    job's pytest timeout.

Everything here is host-side and pure-Python deterministic: a plan built
from the same seed injects the same faults at the same dispatch indices.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np


class EngineFault(RuntimeError):
    """Base of every recoverable serving fault the scheduler handles."""


class InjectedFault(EngineFault):
    """A fault-plan dispatch failure (raised before the compiled call)."""


class CacheCorruption(EngineFault):
    """A guard detected corrupted serving state (non-finite logits, page
    table / allocator audit failure).  The scheduler restores its last
    snapshot and retries the affected requests."""


KINDS = ("nan_logits", "page_table", "dispatch", "stall")
SITES = ("admit", "decode")


@dataclasses.dataclass
class Fault:
    site: str                 # "admit" | "decode"
    index: int                # per-site dispatch index at which to fire
    kind: str                 # one of KINDS
    slot: int = 0             # preferred victim slot (mod active slots)
    duration: float = 0.01    # stall seconds
    fired: bool = False
    skipped: bool = False     # fired but not applicable (e.g. dense engine)

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}")
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")


class FaultPlan:
    """An ordered set of :class:`Fault`\\ s plus the per-site dispatch
    counters.  Hand one to ``Engine.set_fault_plan``; the engine calls
    :meth:`apply` at every dispatch."""

    def __init__(self, faults: Sequence[Fault]):
        self.faults: List[Fault] = list(faults)
        self.counters = {site: 0 for site in SITES}

    @classmethod
    def random(cls, seed: int, n: int = 3, kinds: Sequence[str] = KINDS,
               sites: Sequence[str] = SITES, max_index: int = 10,
               slots: int = 4, duration: float = 0.01) -> "FaultPlan":
        """A seeded plan: ``n`` faults at distinct (site, index) dispatch
        points drawn from ``[0, max_index)`` — same seed, same plan."""
        rng = random.Random(seed)
        points = [(s, i) for s in sites for i in range(max_index)]
        rng.shuffle(points)
        return cls([Fault(site=s, index=i, kind=rng.choice(list(kinds)),
                          slot=rng.randrange(slots), duration=duration)
                    for s, i in points[:n]])

    @property
    def pending(self) -> List[Fault]:
        return [f for f in self.faults if not f.fired]

    # -- the engine-facing hook ---------------------------------------------

    def apply(self, site: str, engine, cache, pos):
        """Fire every due fault for this dispatch; returns the (possibly
        poisoned) cache.  ``pos`` is the host ``[slots]`` position vector —
        negative entries are free slots, which NaN poisoning must avoid
        (their keys are masked, so the fault would be silent)."""
        idx = self.counters[site]
        self.counters[site] = idx + 1
        for f in self.faults:
            if f.fired or f.site != site or f.index != idx:
                continue
            f.fired = True
            if f.kind == "dispatch":
                raise InjectedFault(
                    f"injected dispatch failure at {site}[{idx}]")
            if f.kind == "stall":
                time.sleep(f.duration)
            elif f.kind == "page_table":
                if engine.pool is None:
                    f.skipped = True
                else:
                    pool = engine.pool
                    slot = f.slot % pool.slots
                    pool.table[slot, 0] = pool.pages_per_shard + 3
            elif f.kind == "nan_logits":
                cache = self._poison_nan(engine, cache, np.asarray(pos),
                                         f)
        return cache

    @staticmethod
    def _poison_nan(engine, cache, pos, fault: Fault):
        """NaN one active slot's attended K (or k_scale) at position 0 —
        the poison flows through the REAL compiled attention + head into
        that row's logits."""
        active = np.flatnonzero(pos >= 0)
        if active.size == 0:
            fault.skipped = True
            return cache
        slot = int(active[fault.slot % active.size])
        pool = engine.pool
        out = []
        for spec, c in zip(engine.cfg.pattern, cache):
            c = dict(c)
            if spec.kind == "attn":
                # int8 K codes can't hold a NaN — poison the float scale
                key = "k_scale" if "k_scale" in c else "k"
                if pool is None:
                    c[key] = c[key].at[:, slot, 0].set(jnp.nan)
                else:
                    is_local = (spec.attn_type == "local"
                                and bool(engine.cfg.window))
                    table, n = ((pool.ring, pool.n_ring[slot]) if is_local
                                else (pool.table, pool.n_full[slot]))
                    pid = int(table[slot, 0])
                    # never poison the reserved null page (page 0): every
                    # slot's masked writes route there by design.  Table
                    # values are shard-local — the device pool lays shards
                    # out page-major, so offset into the owning shard.
                    if n > 0 and pid > 0:
                        gpid = (pool.shard_of(slot) * pool.pages_per_shard
                                + pid)
                        c[key] = c[key].at[:, gpid, 0].set(jnp.nan)
            out.append(c)
        return tuple(out)


__all__ = ["EngineFault", "InjectedFault", "CacheCorruption", "Fault",
           "FaultPlan", "KINDS", "SITES"]
