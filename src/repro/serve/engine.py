"""Batched serving engine: the unified batch-step executor under the
scheduler.

Two entry paths share the same compiled decode graph:

  * ``generate`` — static batch: all sequences prefill together and advance
    in lock-step (the legacy demo path, kept as the bit-exactness oracle for
    the scheduler).
  * the continuous-batching path driven by ``serve.scheduler.Scheduler`` —
    ONE compiled ``step`` per round that carries ``prefill_chunk`` prompt
    tokens (a scan of masked single-token iterations targeting the slots
    being admitted, sampling a request's first output token the moment its
    last prompt token lands) followed by ``chunk`` decode iterations over
    every slot.  Prefill and decode share the round, so admission never
    stalls decoding and padding waste stays ~1.0.  Models whose prompt
    state cannot be built a token at a time (recurrent layers, MoE routing,
    int8-KV, SWA prompts longer than the window) fall back to
    ``admit_monolithic`` — a batched full-KV prefill stitched into the
    masked slots of the live buffers — and then take pure-decode ``step``
    rounds.

Positions are per-sequence (``pos: [B]`` int32) everywhere in decode; a
negative position is the free-slot sentinel — the attention mask drops every
key of that row, and its cache writes land inside its own (free) row.
Mid-prefill rows park with ``done=True`` holding their next unprocessed
(token, position): every iteration that does not target them re-runs that
write, which is idempotent (same inputs, same bits).  Sampling is on-device
with per-slot temperature / top-k / top-p and a fold-in PRNG (key folded
with the global step index), so a round of tokens needs exactly one host
round-trip.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import encdec, transformer
from repro.serve.faults import CacheCorruption

NEG_INF = -1e30


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 512
    temperature: float = 0.0
    top_k: int = 0                # 0 disables top-k filtering
    top_p: float = 1.0            # 1.0 disables nucleus filtering
    seed: int = 0
    quant: Optional[str] = None   # convert weights to serving codes at load
    # optional per-leaf mixed bit widths: {param path -> mode string}, the
    # output of roofline.analysis.plan_mixed_bits (keys match the
    # serve.quantize walk paths); leaves not in the plan follow `quant`
    bits_plan: Optional[dict] = None
    # paged KV cache (serve.paged): per-layer page pools + per-slot page
    # tables instead of dense [slots, max_len] buffers
    paged: bool = False
    page_size: int = 4            # tokens per page; must divide max_len
                                  # (and the SWA ring length)
    num_pages: int = 0            # total pool pages incl. per-shard null
                                  # pages; 0 = worst-case auto-size
    prefix_reuse: bool = True     # share identical prompt-prefix pages
    # prompt tokens processed per unified round (the chunked-prefill
    # budget); must be a multiple of page_size on paged engines so chunk
    # boundaries align with page boundaries.  None = auto (2 pages when
    # paged, 8 tokens dense)
    prefill_chunk: Optional[int] = None
    # invariant guards (serve.faults): audit the page pool before every
    # dispatch and have the scheduler act on the finite-logits flags the
    # compiled executors always report (the flags cost one cheap on-device
    # reduction either way; this gates the host-side checks/raises)
    guards: bool = True
    # bitplane-truncated self-speculative decoding: draft ``draft_k`` tokens
    # per round with the top-``draft_planes``-plane view of the tmac weight
    # codes (zero extra weight memory — the draft shares the target's packed
    # planes), verify them in ONE batched (draft_k+1)-token target forward,
    # accept the longest matching prefix.  Transcripts are bit-identical to
    # the non-speculative engine at temperature 0; at temperature > 0 every
    # emitted token is still sampled from the exact target conditional.
    spec_decode: bool = False
    draft_planes: int = 2         # top planes the drafter keeps (>= 2)
    draft_k: int = 3              # tokens drafted per verify round

    def __post_init__(self):
        """Validate serving invariants at construction — a bad geometry
        should fail here with an actionable message, not deep inside the
        first compiled dispatch."""
        if self.max_len < 1:
            raise ValueError(f"max_len must be >= 1, got {self.max_len}")
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.paged and self.max_len % self.page_size:
            raise ValueError(
                f"page_size ({self.page_size}) must divide max_len "
                f"({self.max_len}) — pick a power-of-two page size or pad "
                f"max_len up to a multiple")
        if self.num_pages < 0:
            raise ValueError(f"num_pages must be >= 0 (0 = auto-size), got "
                             f"{self.num_pages}")
        if self.prefill_chunk is not None:
            if self.prefill_chunk < 1:
                raise ValueError(f"prefill_chunk must be >= 1, got "
                                 f"{self.prefill_chunk}")
            if self.prefill_chunk > self.max_len:
                raise ValueError(
                    f"prefill_chunk ({self.prefill_chunk}) cannot exceed "
                    f"max_len ({self.max_len}) — no prompt is longer")
            if self.paged and self.prefill_chunk % self.page_size:
                raise ValueError(
                    f"prefill_chunk ({self.prefill_chunk}) must be a "
                    f"multiple of page_size ({self.page_size}) so chunk "
                    f"boundaries align with page boundaries")
        if self.spec_decode:
            if self.draft_k < 1:
                raise ValueError(
                    f"draft_k must be >= 1, got {self.draft_k}")
            if self.draft_planes < 2:
                raise ValueError(
                    f"draft_planes must be >= 2 (the drafter keeps the sign "
                    f"plane plus at least one magnitude plane), got "
                    f"{self.draft_planes}")
            if self.draft_k + 1 > self.max_len:
                raise ValueError(
                    f"draft_k ({self.draft_k}) needs max_len >= draft_k + 1 "
                    f"({self.draft_k + 1}), got {self.max_len}")

    @property
    def chunk_tokens(self) -> int:
        """The resolved prefill chunk budget (auto when unset)."""
        if self.prefill_chunk is not None:
            return self.prefill_chunk
        return 2 * self.page_size if self.paged else 8


def sample_logits(logits: jax.Array, key, temperature: jax.Array,
                  top_k: jax.Array, top_p: jax.Array) -> jax.Array:
    """Per-row sampling: argmax where temperature <= 0 (exact greedy),
    otherwise temperature softmax restricted by top-k and/or top-p.

    logits: [B, V] float; temperature/top_k/top_p: scalars or [B].  Python
    scalars short-circuit: all-greedy skips everything but the argmax, and
    unfiltered sampling skips the vocab sort — the general (traced-vector)
    path computes both and selects per row.
    """
    logits = logits.astype(jnp.float32)
    B, V = logits.shape
    static = all(isinstance(x, (int, float))
                 for x in (temperature, top_k, top_p))
    if static and temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if static and top_k == 0 and top_p >= 1.0:
        return jax.random.categorical(
            key, logits / max(temperature, 1e-6), axis=-1).astype(jnp.int32)
    temperature = jnp.broadcast_to(
        jnp.atleast_1d(jnp.asarray(temperature, jnp.float32)), (B,))
    top_k = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(top_k, jnp.int32)),
                             (B,))
    top_p = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(top_p, jnp.float32)),
                             (B,))
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    sorted_l = -jnp.sort(-logits, axis=-1)               # descending
    kth = jnp.take_along_axis(sorted_l, (jnp.clip(top_k, 1, V) - 1)[:, None],
                              axis=-1)
    keep = jnp.where((top_k > 0)[:, None], logits >= kth, True)
    t = jnp.maximum(temperature, 1e-6)[:, None]
    probs = jax.nn.softmax(sorted_l / t, axis=-1)
    csum = jnp.cumsum(probs, axis=-1)
    # nucleus: smallest prefix whose mass reaches top_p (first token always in)
    n_keep = jnp.maximum(jnp.sum((csum - probs) < top_p[:, None], axis=-1), 1)
    cutoff = jnp.take_along_axis(sorted_l, (n_keep - 1)[:, None], axis=-1)
    keep &= jnp.where((top_p < 1.0)[:, None], logits >= cutoff, True)
    sampled = jax.random.categorical(
        key, jnp.where(keep, logits, NEG_INF) / t, axis=-1).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy, sampled)


def _write_rows(live: jax.Array, part: jax.Array,
                mask: jax.Array) -> jax.Array:
    """Masked multi-slot write: replace batch rows where ``mask`` is set.

    live: [G, B, ...]; part: [G, B, ...] with a possibly shorter time axis
    (axis 2, P <= M) — only the leading P time slots of masked rows are
    written (the tail stays masked by the position sentinel until decode
    overwrites it).  mask: [B] bool.  One static-shape op for the whole
    admission round, regardless of how many slots fill.
    """
    m = mask.reshape((1, -1) + (1,) * (live.ndim - 2))
    if live.ndim >= 3 and part.shape[2] < live.shape[2]:
        P = part.shape[2]
        head = jnp.where(m, part.astype(live.dtype), live[:, :, :P])
        return live.at[:, :, :P].set(head)
    return jnp.where(m, part.astype(live.dtype), live)


def _ring_positions(lengths: jax.Array, T: int) -> jax.Array:
    """[B, T] absolute position held by each ring slot after stitching a
    ``lengths``-token prompt (negative = slot empty) — the addressing
    ``_ring_from_full`` and the paged ring scatter share."""
    i = jnp.arange(T)[None]                       # [1, T]
    L = lengths[:, None]                          # [B, 1]
    return (L - 1) - ((L - 1 - i) % T)            # [B, T]


def _ring_from_full(kv_full: jax.Array, lengths: jax.Array,
                    T: int) -> jax.Array:
    """Arrange full-length K/V [G, B, P, H, D] into per-row T-slot rings
    where slot i holds the token with the largest position p < lengths[b],
    p % T == i — exactly ``decode_attention``'s rolling addressing.  Slots
    with no valid token (length < T) are zeroed; their positions stay
    masked."""
    P = kv_full.shape[2]
    p = _ring_positions(lengths, T)               # [B, T]
    vals = jnp.take_along_axis(
        kv_full, jnp.clip(p, 0, P - 1)[None, :, :, None, None], axis=2)
    return jnp.where((p >= 0)[None, :, :, None, None], vals,
                     jnp.zeros((), kv_full.dtype))


def _scatter_pages(pool: jax.Array, table: jax.Array, piece: jax.Array,
                   valid: jax.Array) -> jax.Array:
    """Stitch-time page scatter: write token rows of ``piece`` into the
    pages their table rows name.

    pool: [G, P, ps, ...]; table: [B, E]; piece: [G, B, L, ...] (L <= E*ps);
    valid: [B, L] bool.  Invalid entries (unadmitted slots, pad tokens,
    prefix-shared tokens) are routed to the reserved null page 0, so one
    static-shape scatter covers the whole admission round; valid entries
    target exclusively-owned pages, so duplicate indices only ever land on
    the null page.
    """
    ps = pool.shape[2]
    B, L = valid.shape
    t = jnp.arange(L)
    page = jnp.where(valid, table[:, t // ps], 0)          # [B, L]
    off = jnp.broadcast_to(t % ps, (B, L))
    vals = piece.reshape((piece.shape[0], B * L) + piece.shape[3:])
    return pool.at[:, page.reshape(-1), off.reshape(-1)].set(
        vals.astype(pool.dtype))


_FLOAT_KV_KEYS = ("k", "v", "shared_k", "shared_v", "k_scale", "v_scale")


def _cache_finite(cache) -> jax.Array:
    """Scalar AND of ``isfinite`` over every floating-dtype attention cache
    leaf.  The finite-logits guard alone cannot see KV corruption on
    integer-code matmul paths (casting a NaN activation to int codes yields
    finite garbage), so decode also audits the cache itself once per chunk.
    Int leaves (quantized KV codes, page tables) are finite by construction
    and skipped."""
    layers = cache if isinstance(cache, (list, tuple)) else [cache]
    ok = jnp.bool_(True)
    for layer in layers:
        if not isinstance(layer, dict):
            continue
        for key in _FLOAT_KV_KEYS:
            leaf = layer.get(key)
            if leaf is not None and jnp.issubdtype(leaf.dtype, jnp.floating):
                ok = ok & jnp.isfinite(leaf).all()
    return ok


def paged_layout(cfg, scfg: ServeConfig):
    """The engine's page geometry (validated against cfg/scfg)."""
    from repro.serve.paged import PagedLayout
    return PagedLayout.build(cfg, scfg.max_len, scfg.page_size)


def resolve_pages_per_shard(cfg, scfg: ServeConfig, batch: int,
                            n_shards: int) -> int:
    """Pool pages per data shard: ``scfg.num_pages / n_shards`` when set
    (must divide), else the exhaustion-free worst case for ``batch`` slots."""
    lay = paged_layout(cfg, scfg)
    if scfg.num_pages:
        if scfg.num_pages % n_shards:
            raise ValueError(f"num_pages ({scfg.num_pages}) must divide "
                             f"over the data axis ({n_shards})")
        return scfg.num_pages // n_shards
    if batch % n_shards:
        raise ValueError(f"slots ({batch}) must divide over the data axis "
                         f"({n_shards})")
    return lay.auto_pages_per_shard(batch // n_shards)


def cache_struct(cfg, scfg: ServeConfig, batch: int, n_shards: int = 1):
    """ShapeDtypeStructs of the decode cache — dense per-slot buffers, or
    page pools + dense recurrent state when ``scfg.paged``."""
    from repro.models import encdec as _encdec
    from repro.models import transformer as _transformer
    mod = _encdec if getattr(cfg, "enc_dec", False) else _transformer
    if not scfg.paged:
        return jax.eval_shape(
            lambda: mod.init_cache(cfg, batch, scfg.max_len))
    total = resolve_pages_per_shard(cfg, scfg, batch, n_shards) * n_shards
    return jax.eval_shape(
        lambda: mod.init_paged_cache(cfg, batch, scfg.max_len, total,
                                     scfg.page_size))


class Engine:
    def __init__(self, cfg, params, scfg: ServeConfig = ServeConfig(), *,
                 n_page_shards: int = 1):
        self.cfg = cfg
        if scfg.quant:
            # quantize + pack weight codes ONCE at engine construction (the
            # weight-code cache); every decode step then reads integer codes
            from repro.serve.quantize import quantize_params_for_serving
            params = quantize_params_for_serving(params, mode=scfg.quant,
                                                 bits_plan=scfg.bits_plan)
        self.params = params
        self.scfg = scfg
        self.is_encdec = getattr(cfg, "enc_dec", False)
        # paged serving state (serve.paged): geometry validated up front,
        # the PagePool itself is created by init_cache (it needs the slot
        # count).  n_page_shards = 1 single-device; the sharded engine
        # passes its data-axis size to split the pool page axis (and the
        # slots) over the data mesh axis.
        self.pool = None
        self.n_page_shards = n_page_shards
        if scfg.paged:
            if self.is_encdec:
                raise NotImplementedError(
                    "paged serving drives decoder-only LMs through the "
                    "scheduler; enc-dec decode supports page tables at the "
                    "encdec.decode_step level only")
            paged_layout(cfg, scfg)          # raises on bad page geometry
            if scfg.num_pages and scfg.num_pages // n_page_shards < 2:
                raise ValueError(
                    f"num_pages ({scfg.num_pages}) leaves no usable pages: "
                    f"each of the {n_page_shards} shard(s) reserves page 0 "
                    f"as the null page — give every shard at least 2 pages")
        mod = encdec if self.is_encdec else transformer
        self._mod = mod
        self._prefill = jax.jit(lambda p, *a: mod.prefill(p, cfg, *a))
        # donate the cache: decode updates it in place (halves residency)
        self._decode = jax.jit(lambda p, t, c, pos: mod.decode_step(
            p, cfg, t, c, pos), donate_argnums=2)
        self._admit_fn = self._build_admit_fn()
        self._step_fns: dict[tuple, callable] = {}
        # fault injection (serve.faults): a FaultPlan applied at the two
        # dispatch sites; None in production
        self.faults = None
        # attention KV tolerates right-padded prompt buckets (pad keys stay
        # position-masked until decode overwrites them); SSM/RWKV recurrent
        # states do NOT — the recurrence integrates pad embeddings — so the
        # scheduler must prefill those models at exact prompt length
        self.has_recurrent_state = (not self.is_encdec and any(
            spec.kind != "attn" for spec in cfg.pattern))
        # speculative decoding eligibility: the draft/verify round needs
        # token-at-a-time state (same precondition as the chunk lane), no
        # SWA rings (a K+1-token block write would wrap them), and tmac
        # leaves wide enough to truncate.  Fail at construction, not inside
        # the first compiled spec round.
        self.n_draftable_leaves = 0
        if scfg.spec_decode:
            if self.requires_monolithic_admission:
                raise ValueError(
                    "spec_decode needs prompt/decode state that builds one "
                    "token at a time — recurrent layers, MoE routing, "
                    "int8-KV and enc-dec models cannot run draft/verify "
                    "rounds")
            if self.chunk_window_limit is not None:
                raise ValueError(
                    "spec_decode does not support sliding-window attention: "
                    "a draft_k+1-token speculative block would wrap the "
                    "window ring before the verify pass could roll it back")
            if any(getattr(spec, "shared_attn", False)
                   for spec in getattr(cfg, "pattern", ())):
                raise ValueError(
                    "spec_decode does not support shared-attention patterns")
            from repro.serve.quantize import count_draftable_leaves
            self.n_draftable_leaves = count_draftable_leaves(
                self.params, scfg.draft_planes)
            if self.n_draftable_leaves == 0:
                raise ValueError(
                    f"spec_decode found no draftable weight leaves: the "
                    f"drafter truncates tmac bitplane stacks wider than "
                    f"draft_planes={scfg.draft_planes} — quantize with a "
                    f"w3/w4 tmac mode (e.g. quant='w4a4_tmac')")

    # -- compiled-executor construction (ShardedEngine overrides these with
    #    shard_map-wrapped variants; the impls themselves are shared) --------

    def _build_admit_fn(self):
        return jax.jit(self._admit_impl, donate_argnums=1)

    def _build_step_fn(self, C: int, chunk: int, greedy: bool,
                       spec: bool = False):
        return jax.jit(self._make_step_impl(C, chunk, greedy, spec),
                       donate_argnums=1)

    # -- scheduler-facing API ------------------------------------------------

    @property
    def paged(self) -> bool:
        return bool(self.scfg.paged)

    @property
    def prefill_chunk(self) -> int:
        """Prompt tokens carried by the chunk lane of one unified round."""
        return self.scfg.chunk_tokens

    @property
    def requires_monolithic_admission(self) -> bool:
        """True when prompt state cannot be built one token at a time and
        the scheduler must admit through the batched-prefill fallback:

        * recurrent layers (SSM/RWKV) — the recurrence must integrate the
          exact prompt, and prefill's associative scan does not decompose
          into per-token decode steps bit-identically;
        * MoE routing — grouped dispatch capacity is a function of the
          batched prompt length, so chunked routing takes different
          drop/keep decisions than the prefill the oracle uses;
        * int8-KV — prefill quantizes K/V per prompt tile; requantizing a
          token at a time would change the stored codes.
        """
        if self.is_encdec or self.has_recurrent_state:
            return True
        if getattr(self.cfg, "kv_quant", "none") == "int8":
            return True
        return any(getattr(spec, "mlp", None) == "moe"
                   for spec in getattr(self.cfg, "pattern", ()))

    @property
    def chunk_window_limit(self) -> Optional[int]:
        """Longest sequence the chunk lane may admit on SWA models (the
        window): a ring-buffered prompt longer than the window reads its
        keys in ring order during chunked admission but in chronological
        order during the oracle's prefill, and the float reduction order
        differs at the last ulp.  None = no local-attention layers."""
        pattern = getattr(self.cfg, "pattern", ())
        if getattr(self.cfg, "window", 0) and any(
                spec.kind == "attn" and spec.attn_type == "local"
                for spec in pattern):
            return int(self.cfg.window)
        return None

    def chunk_eligible(self, seq_len: int) -> bool:
        """Can a ``seq_len``-token prompt be admitted through the chunk
        lane (vs the monolithic fallback)?"""
        if self.requires_monolithic_admission:
            return False
        limit = self.chunk_window_limit
        return limit is None or seq_len <= limit

    def init_cache(self, batch: int):
        """Zero decode buffers for ``batch`` slots (static shapes).  Paged:
        page pools + dense recurrent state, plus a fresh host-side
        ``PagePool`` (allocator + page tables) under ``self.pool``."""
        if not self.paged:
            return self._mod.init_cache(self.cfg, batch, self.scfg.max_len)
        from repro.serve.paged import PagePool
        per_shard = resolve_pages_per_shard(self.cfg, self.scfg, batch,
                                            self.n_page_shards)
        self.pool = PagePool(batch, paged_layout(self.cfg, self.scfg),
                             pages_per_shard=per_shard,
                             n_shards=self.n_page_shards,
                             prefix_reuse=self.scfg.prefix_reuse)
        return self._mod.init_paged_cache(
            self.cfg, batch, self.scfg.max_len,
            per_shard * self.n_page_shards, self.scfg.page_size)

    def _cache_sds(self, batch: int):
        """ShapeDtypeStructs of the decode cache (no device allocation)."""
        return cache_struct(self.cfg, self.scfg, batch, self.n_page_shards)

    def _paged_admit_args(self):
        """Device snapshots of (full table, ring table, start_tok)."""
        place = self.place_slot_state
        return (place(jnp.asarray(self.pool.table)),
                place(jnp.asarray(self.pool.ring)),
                place(jnp.asarray(self.pool.start)))

    def _paged_decode_args(self):
        place = self.place_slot_state
        return (place(jnp.asarray(self.pool.table)),
                place(jnp.asarray(self.pool.ring)))

    def _kv_leaf_bytes(self, batch: int) -> int:
        from repro.launch.specs import (KV_CACHE_LEAVES, KV_SCALE_LEAVES,
                                        _leaf_key)
        names = KV_CACHE_LEAVES | KV_SCALE_LEAVES
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                self._cache_sds(batch))[0]:
            if _leaf_key(path) in names:
                total += leaf.size * leaf.dtype.itemsize
        return total

    def page_bytes(self, batch: int = 1) -> int:
        """Bytes ONE page occupies summed across every KV pool leaf (all
        groups and pattern positions)."""
        if not self.paged:
            raise ValueError("page_bytes is a paged-engine figure")
        per_shard = resolve_pages_per_shard(self.cfg, self.scfg, batch,
                                            self.n_page_shards)
        return self._kv_leaf_bytes(batch) // (per_shard * self.n_page_shards)

    def kv_cache_bytes(self, batch: int) -> int:
        """KV memory figure for the serving bench: bytes of the attention
        KV leaves (K/V + int8-KV scales + shared-attention K/V).

        Dense engines report ``max_len`` *capacity* — every slot owns a
        worst-case buffer.  Paged engines report *allocated residency*: the
        peak number of in-use pool pages times the page footprint (the pool
        backing store is larger, but untouched pages are reclaimable — the
        number that scales with the workload is the allocated one).  The
        sharded engine overrides this with the per-shard figure."""
        if self.paged and self.pool is not None:
            return self.pool.peak_pages * self.page_bytes(batch)
        return self._kv_leaf_bytes(batch)

    def place_slot_state(self, x: jax.Array) -> jax.Array:
        """Device placement for per-slot ``[slots]`` vectors (identity here;
        the sharded engine pins them to the data axis so the compiled
        executors see one stable input sharding from round one)."""
        return x

    def place_cache(self, cache):
        """Device placement for a (host-restored) decode cache tree
        (identity here; the sharded engine re-pins the canonical cache
        shardings so restored state never changes executor signatures)."""
        return jax.tree_util.tree_map(jnp.asarray, cache)

    def serving_state_shardings(self):
        """Shardings for the {"cache", "tok", "pos", "done"} serving-state
        tree a disk restore re-places (None = default placement; the
        sharded engine returns its canonical NamedSharding tree)."""
        return None

    # -- fault injection + invariant guards (serve.faults) -------------------

    def set_fault_plan(self, plan) -> None:
        """Install a ``FaultPlan`` applied at every dispatch (None clears)."""
        self.faults = plan

    def _fault_site(self, site: str, cache, pos):
        """Apply due injected faults, then audit the page pool so corrupted
        tables are caught host-side BEFORE they are snapshotted to device
        (where the scatter/gather would silently clamp them)."""
        if self.faults is not None:
            cache = self.faults.apply(site, self, cache, pos)
        if self.paged and self.scfg.guards and self.pool is not None:
            errs = self.pool.validate()
            if errs:
                raise CacheCorruption(
                    "page pool audit failed: " + "; ".join(errs[:3]))
        return cache

    def _stitch_impl(self, cache, pcache, lengths, mask, paged=()):
        """Cache-stitch-at-slot: write freshly prefilled rows into the masked
        batch slots of the live buffers.  pcache rows are slot-aligned: row b
        fills slot b where ``mask[b]``; other rows are untouched.  Static
        shapes throughout (lengths and mask are traced vectors).

        ``paged`` = (full_table, ring_table, start_tok): KV rows scatter
        into pool pages instead — full-length layers write tokens
        [start_tok, length) of masked rows through the full table (tokens
        below start_tok live in prefix-shared pages another admission
        already filled), SWA rings arrange the window from the true length
        and scatter through their exclusively-owned ring table.  Recurrent
        state stays a dense masked row write either way.
        """
        cfg = self.cfg
        table = ring_t = start = None
        if paged:
            table, ring_t, start = paged
        out = []
        for spec, live, part in zip(cfg.pattern, cache, pcache):
            c = dict(live)
            if spec.kind == "attn":
                is_local = spec.attn_type == "local" and bool(cfg.window)
                if paged:
                    Pb = part["k"].shape[2]
                    t = jnp.arange(Pb)[None]
                    if is_local:
                        Tr = ring_t.shape[1] * self.scfg.page_size
                        rv = mask[:, None] & (
                            _ring_positions(lengths, Tr) >= 0)
                    else:
                        valid = (mask[:, None] & (t >= start[:, None])
                                 & (t < lengths[:, None]))
                    for key in ("k", "v"):
                        piece = part[key]
                        if is_local:
                            piece = _ring_from_full(piece, lengths, Tr)
                            c[key] = _scatter_pages(live[key], ring_t,
                                                    piece, rv)
                        elif "k_scale" in live:      # int8 KV pool
                            q, s = attn_lib.quantize_kv(piece)
                            c[key] = _scatter_pages(live[key], table, q,
                                                    valid)
                            c[key + "_scale"] = _scatter_pages(
                                live[key + "_scale"], table, s, valid)
                        else:
                            c[key] = _scatter_pages(live[key], table,
                                                    piece, valid)
                else:
                    T = live["k"].shape[2]
                    for key in ("k", "v"):
                        piece = part[key]
                        if is_local:
                            piece = _ring_from_full(piece, lengths, T)
                        if "k_scale" in live:        # int8 KV live buffers
                            q, s = attn_lib.quantize_kv(piece)
                            c[key] = _write_rows(live[key], q, mask)
                            c[key + "_scale"] = _write_rows(
                                live[key + "_scale"], s, mask)
                        else:
                            c[key] = _write_rows(live[key], piece, mask)
            elif spec.kind == "mamba2":
                c["h"] = _write_rows(live["h"], part["h"], mask)
                c["conv"] = _write_rows(live["conv"], part["conv"], mask)
            elif spec.kind == "rwkv6":
                for key in ("S", "xt"):
                    c[key] = _write_rows(live[key], part[key], mask)
                if "xc" in live:
                    # prefill tracks the channel-mix state under "xc" only for
                    # rwkv_cm patterns; default to zeros otherwise
                    c["xc"] = _write_rows(live["xc"],
                                          part.get("xc",
                                                   jnp.zeros_like(live["xc"])),
                                          mask)
            for key in ("shared_k", "shared_v"):
                if key in live:
                    if paged:
                        valid = (mask[:, None]
                                 & (jnp.arange(part[key].shape[2])[None]
                                    >= start[:, None])
                                 & (jnp.arange(part[key].shape[2])[None]
                                    < lengths[:, None]))
                        c[key] = _scatter_pages(live[key], table, part[key],
                                                valid)
                    else:
                        c[key] = _write_rows(live[key], part[key], mask)
            out.append(c)
        return tuple(out)

    def admit_monolithic(self, cache, prompts, lengths, mask, budget_one,
                         eos, temperature, top_k, top_p, tok, pos, done,
                         step0: int):
        """Fallback admission as ONE dispatch: batched prefill of the
        admitted prompts, cache-stitch into the masked slots, first-token
        sampling, and the slot-state merge.  Used for models/requests
        ``chunk_eligible`` rejects (recurrent state, MoE routing, int8-KV,
        SWA prompts past the window); everything else admits through the
        chunk lane of :meth:`step`.

        prompts: [slots, P] int32 right-padded to the dispatch width (dummy
        rows for slots that stay empty); lengths/mask/budget_one: per-slot
        vectors (budget_one marks requests whose whole budget is the first
        token).  Returns (cache, tok, pos, done, tok0, done0, ok0) —
        tok0/done0 are the per-slot first tokens and immediately-finished
        flags the scheduler reads back for bookkeeping; ok0 is the per-slot
        finite-logits guard (False = the sampled row's logits were
        non-finite, i.e. poisoned state).  Compiles once per prompt width.

        Paged engines additionally thread the page tables + per-slot
        start_tok (snapshotted from ``self.pool``, which the scheduler's
        block accounting updated before this call).
        """
        if self.is_encdec:
            raise NotImplementedError(
                "continuous batching serves decoder-only LMs; enc-dec uses "
                "Engine.generate")
        cache = self._fault_site("admit", cache, pos)
        key = jax.random.PRNGKey(self.scfg.seed)
        extra = self._paged_admit_args() if self.paged else ()
        return self._admit_fn(
            self.params, cache, jnp.asarray(prompts, jnp.int32),
            jnp.asarray(lengths, jnp.int32), jnp.asarray(mask, bool),
            jnp.asarray(budget_one, bool), eos, temperature, top_k, top_p,
            tok, pos, done, key, jnp.int32(step0), *extra)

    def _admit_impl(self, params, cache, prompts, lengths, mask, budget_one,
                    eos, temperature, top_k, top_p, tok, pos, done, key,
                    step0, *paged):
        from repro.dist import tp as tp_lib
        logits, pcache = self._mod.prefill(params, self.cfg, prompts,
                                           full_kv=True, length=lengths)
        cache = self._stitch_impl(cache, pcache, lengths, mask, paged)
        key = tp_lib.fold_in_data(key)   # per-data-shard sampling stream
        tok0 = sample_logits(logits, jax.random.fold_in(key, step0),
                             temperature, top_k, top_p)
        # finite-logits guard on the sampled rows (free rows report healthy)
        ok0 = jnp.isfinite(logits).all(axis=-1) | ~mask
        done0 = ((eos >= 0) & (tok0 == eos)) | budget_one
        active = mask & ~done0
        tok = jnp.where(mask, tok0, tok)
        pos = jnp.where(mask, jnp.where(active, lengths, -1), pos)
        done = jnp.where(mask, ~active, done)
        return cache, tok, pos, done, tok0, done0, ok0

    def step(self, cache, entries, tok, pos, done, eos, temperature, top_k,
             top_p, step0: int, chunk: int, greedy: bool = False,
             spec: bool = False):
        """ONE unified serving round in a single dispatch: a chunk lane of
        ``prefill_chunk`` masked prompt-token iterations (absent when
        ``entries`` is None) followed by a decode lane advancing every slot
        ``chunk`` tokens (lax.scan with on-device sampling).

        ``entries`` describes the round's prompt-chunk work as a dict of
        [prefill_chunk] host arrays (padded with slot=-1 no-op entries):

          * ``slot`` — target batch row (GLOBAL slot id under sharding)
          * ``tok`` / ``pos`` — the prompt token and its absolute position
          * ``first`` — True on a prompt's last token: that iteration's
            logits are the request's first-token logits and are sampled
          * ``budget_one`` — with ``first``: the request's whole budget is
            that first token, so the row finishes immediately

        Each chunk iteration runs the full-batch decode graph with the
        target row's (token, position) substituted in; non-target rows
        re-run their held (token, position), whose cache writes are
        idempotent.  When ``first`` fires, the sampled token and position+1
        become the row's decode state and the row joins the decode lane of
        the SAME round.  Finished/free slots (done=True) hold token and
        position throughout.  ``greedy=True`` (every slot at temperature 0,
        no filtering — the caller knows this statically) compiles an
        argmax-only variant that skips the per-token vocab sort; its tokens
        are bit-identical to the general path's.

        ``spec=True`` (requires ``scfg.spec_decode``) swaps the decode lane
        for a draft/verify speculative round: ``draft_k`` sequential
        truncated-plane drafter steps propose tokens, ONE batched
        (draft_k+1)-token target forward verifies them, and the longest
        matching prefix is accepted — up to ``draft_k + 1`` tokens per slot
        per round, bit-identical to the non-speculative transcript at
        temperature 0.  The tokens/dones outputs are then ``[B, draft_k+1]``
        wide and only the first ``n_valid[b]`` columns of row b are real.

        Returns (cache, tok, pos, done, tok0, done0, tokens [B, W],
        dones [B, W], ok [B], n_valid [B]) with W = chunk (or draft_k+1
        under ``spec``) — tok0/done0 are per-slot first tokens /
        immediately-finished flags, meaningful at rows whose ``first``
        entry fired this round; ok is the per-slot finite-logits guard over
        the whole round; n_valid counts the tokens each row actually
        advanced (always W on non-speculative rounds).  Compiles once per
        (has-entries, chunk, greedy, spec).
        """
        if self.is_encdec:
            raise NotImplementedError(
                "continuous batching serves decoder-only LMs; enc-dec uses "
                "Engine.generate")
        if spec and not self.scfg.spec_decode:
            raise ValueError("spec=True requires ServeConfig(spec_decode=True)")
        C = self.prefill_chunk if entries is not None else 0
        fn = self._step_fns.get((C, chunk, greedy, spec))
        if fn is None:
            fn = self._build_step_fn(C, chunk, greedy, spec)
            self._step_fns[(C, chunk, greedy, spec)] = fn
        if entries is not None:
            cache = self._fault_site("admit", cache, pos)
        cache = self._fault_site("decode", cache, pos)
        key = jax.random.PRNGKey(self.scfg.seed)
        if C:
            c_args = (jnp.asarray(entries["slot"], jnp.int32),
                      jnp.asarray(entries["tok"], jnp.int32),
                      jnp.asarray(entries["pos"], jnp.int32),
                      jnp.asarray(entries["first"], bool),
                      jnp.asarray(entries["budget_one"], bool))
        else:
            # dummy [1] no-op arrays keep one signature for both variants
            z = jnp.zeros((1,), jnp.int32)
            f = jnp.zeros((1,), bool)
            c_args = (z - 1, z, z, f, f)
        extra = self._paged_decode_args() if self.paged else ()
        return fn(self.params, cache, *c_args, tok, pos, done, eos,
                  temperature, top_k, top_p, key, jnp.int32(step0), *extra)

    def _make_step_impl(self, C: int, chunk: int, greedy: bool,
                        spec: bool = False):
        mod, cfg = self._mod, self.cfg
        K = self.scfg.draft_k
        draft_planes = self.scfg.draft_planes

        def run(params, cache, c_slot, c_tok, c_pos, c_first, c_b1, tok,
                pos, done, eos, temperature, top_k, top_p, key, step0,
                *paged):
            from repro.dist import tp as tp_lib
            key = tp_lib.fold_in_data(key)   # per-data-shard sampling stream
            tables = paged if paged else None
            ok = jnp.ones(tok.shape, bool)
            tok0, done0 = tok, done

            def sample(logits, key_i):
                if greedy:
                    return sample_logits(logits, key_i, 0.0, 0, 1.0)
                return sample_logits(logits, key_i, temperature, top_k,
                                     top_p)

            if C:
                # chunk-lane rows are GLOBAL slot ids: under a data mesh
                # each shard owns a contiguous block of slots
                rows = jnp.arange(tok.shape[0], dtype=jnp.int32)
                axis = tp_lib.data_axis()
                if axis is not None:
                    rows = rows + jax.lax.axis_index(axis) * tok.shape[0]

                def fill(carry, xs):
                    cache, tok, pos, done, ok, tok0, done0 = carry
                    s, t, p, first, b1, i = xs
                    target = rows == s           # all-False for pad entries
                    tok_in = jnp.where(target, t, tok)
                    pos_in = jnp.where(target, p, pos)
                    logits, cache = mod.decode_step(params, cfg, tok_in,
                                                    cache, pos_in,
                                                    tables=tables)
                    fire = target & first
                    ok = ok & (jnp.isfinite(logits).all(axis=-1) | ~fire)
                    nxt = sample(logits, jax.random.fold_in(key, step0 + i))
                    nd = ((nxt == eos) & (eos >= 0)) | b1
                    # fire: the row becomes a decoder at (sampled, p + 1);
                    # otherwise the target row parks on this entry's (t, p)
                    # — its write next iteration is an idempotent re-run
                    tok = jnp.where(fire, nxt, tok_in)
                    pos = jnp.where(fire, p + 1, pos_in)
                    done = jnp.where(fire, nd, done)
                    tok0 = jnp.where(fire, nxt, tok0)
                    done0 = jnp.where(fire, nd, done0)
                    return (cache, tok, pos, done, ok, tok0, done0), None

                xs = (c_slot, c_tok, c_pos, c_first, c_b1,
                      jnp.arange(C, dtype=jnp.int32))
                (cache, tok, pos, done, ok, tok0, done0), _ = jax.lax.scan(
                    fill, (cache, tok, pos, done, ok, tok0, done0), xs)

            if spec:
                # -- speculative decode lane: draft K / verify 1 -----------
                # Precondition (scheduler-enforced): every non-free slot has
                # pos <= max_len - (K+1), so no block write clamps into live
                # history.  Rows done at round entry (parked mid-prefill /
                # free) hold (tok, pos) throughout; the drafter's writes at
                # their held slot are restored by the verify pass's target-
                # bits rewrite of the same slots.
                from repro.serve.quantize import draft_params_view
                S = K + 1
                # trace-time truncated-plane view: pure slices of the
                # target's packed codes (zero extra weight memory; XLA
                # hoists them as loop-invariant)
                dparams = draft_params_view(params, draft_planes)

                def draft(carry, j):
                    cache, dtok, dpos = carry
                    logits, cache = mod.decode_step(dparams, cfg, dtok,
                                                    cache, dpos,
                                                    tables=tables)
                    nxt = sample(logits,
                                 jax.random.fold_in(key, step0 + C + j))
                    nxt = jnp.where(done, dtok, nxt)
                    dpos = jnp.where(done, dpos, dpos + 1)
                    return (cache, nxt, dpos), nxt

                (cache, _, _), drafts = jax.lax.scan(
                    draft, (cache, tok, pos), jnp.arange(K, dtype=jnp.int32))
                drafts = drafts.T                               # [B, K]
                # ONE batched target forward over [t0, d_1..d_K]: logits[i]
                # conditions on the accepted-so-far prefix exactly like i
                # sequential target steps would (verify_step writes target
                # bits over every speculative slot before attending)
                vtoks = jnp.concatenate([tok[:, None], drafts], axis=1)
                logits, cache = mod.verify_step(params, cfg, vtoks, cache,
                                                pos, tables=tables)
                ok = ok & (jnp.isfinite(logits).all(axis=(-2, -1)) | done)
                v = jnp.stack(
                    [sample(logits[:, i],
                            jax.random.fold_in(key, step0 + C + K + i))
                     for i in range(S)], axis=1)                # [B, S]
                # accept the longest prefix where the target reproduces the
                # draft; v_{m+1} (the first mismatch / bonus token) is free
                match = (v[:, :K] == drafts).astype(jnp.int32)
                m = jnp.sum(jnp.cumprod(match, axis=1), axis=1)   # [B] 0..K
                cols = jnp.arange(S, dtype=jnp.int32)[None]       # [1, S]
                is_eos = (eos[:, None] >= 0) & (v == eos[:, None])
                eos_in = is_eos & (cols <= m[:, None])
                any_eos = eos_in.any(axis=1)
                first_eos = jnp.argmax(eos_in, axis=1).astype(jnp.int32)
                n_valid = jnp.where(any_eos, first_eos + 1, m + 1)
                n_valid = jnp.where(done, 0, n_valid).astype(jnp.int32)
                newtok = jnp.take_along_axis(
                    v, jnp.maximum(n_valid - 1, 0)[:, None], axis=1)[:, 0]
                tok = jnp.where(done, tok, newtok)
                pos = pos + n_valid
                done = done | (any_eos & (n_valid > 0))
                toks, dones = v.T, (is_eos
                                    & (cols < n_valid[:, None])).T
            else:
                def step(carry, j):
                    cache, tok, pos, done, ok = carry
                    logits, cache = mod.decode_step(params, cfg, tok, cache,
                                                    pos, tables=tables)
                    # finite-logits guard: rows already done (or free)
                    # before this step never sampled these logits — ignore
                    ok = ok & (jnp.isfinite(logits).all(axis=-1) | done)
                    nxt = sample(logits,
                                 jax.random.fold_in(key, step0 + C + j))
                    nxt = jnp.where(done, tok, nxt)
                    pos = jnp.where(done, pos, pos + 1)
                    done = done | ((nxt == eos) & (eos >= 0))
                    return (cache, nxt, pos, done, ok), (nxt, done)

                (cache, tok, pos, done, ok), (toks, dones) = jax.lax.scan(
                    step, (cache, tok, pos, done, ok),
                    jnp.arange(chunk, dtype=jnp.int32))
                n_valid = jnp.full(tok.shape, chunk, jnp.int32)
            # cache-finiteness guard: quantized (integer-code) matmul paths
            # launder NaN activations into finite garbage codes, so poisoned
            # KV can yield wrong-but-FINITE logits the guard above never
            # sees.  Sweep the float attention leaves once per round; a
            # non-finite value anywhere fails every slot (recovery replays
            # the whole batch from the snapshot regardless).  Under tensor
            # parallelism each shard holds a head slice, so the verdict must
            # be all-reduced over the model axis — the ok out-spec is
            # model-replicated and an unreduced miss on the clean shards
            # would mask the poisoned one.
            cache_ok = _cache_finite(cache)
            axis = tp_lib.model_axis()
            if axis is not None:
                cache_ok = jax.lax.pmin(
                    cache_ok.astype(jnp.int32), axis).astype(bool)
            ok = ok & cache_ok
            return (cache, tok, pos, done, tok0, done0, toks.T, dones.T, ok,
                    n_valid)

        return run

    # -- cache stitching (static-batch path) ---------------------------------

    def _grow_cache(self, cache, prompt_len: int):
        """Pad prefill caches (sized S or window) into max_len buffers."""
        cfg, S, M = self.cfg, prompt_len, self.scfg.max_len
        if self.is_encdec:
            grown = dict(cache)
            for k in ("k", "v"):
                buf = jnp.zeros(cache[k].shape[:2] + (M,) + cache[k].shape[3:],
                                cache[k].dtype)
                grown[k] = jax.lax.dynamic_update_slice_in_dim(
                    buf, cache[k], 0, axis=2)
            return grown
        out = []
        for spec, c in zip(cfg.pattern, cache):
            c = dict(c)
            for key in ("k", "v", "shared_k", "shared_v"):
                if key not in c:
                    continue
                T = c[key].shape[2]
                # local/SWA k/v buffers are rings of at most `window` slots
                # (decode addresses slot pos % T); everything else grows to
                # max_len.  Prefill emits a window-size ring only when the
                # prompt exceeds the window — a shorter prompt's cache (T=S,
                # slot i == abs pos i == i % target) still needs growing.
                is_local_kv = (key in ("k", "v")
                               and spec.attn_type == "local"
                               and bool(cfg.window))
                target = min(M, cfg.window) if is_local_kv else M
                if T == target:
                    continue
                buf = jnp.zeros(c[key].shape[:2] + (target,)
                                + c[key].shape[3:], c[key].dtype)
                c[key] = jax.lax.dynamic_update_slice_in_dim(
                    buf, c[key], 0, axis=2)
            out.append(c)
        return tuple(out)

    # -- generation ----------------------------------------------------------

    def generate(self, prompts: jax.Array, max_new_tokens: int,
                 frames: Optional[jax.Array] = None,
                 use_scan: bool = True) -> jax.Array:
        """prompts: [B, S] int32 -> [B, S + max_new_tokens].

        ``use_scan=False`` runs the per-token Python loop (the reference the
        scanned decode is tested bit-exact against); both paths draw token i
        with ``fold_in(key, i)``, so they agree at any temperature.

        On a paged engine the scan executors are compiled against page
        pools, so ``generate`` always takes the python loop over a dense
        prefill cache — it stays the dense bit-exactness oracle either way.
        """
        if self.paged:
            use_scan = False
        B, S = prompts.shape
        if self.is_encdec:
            logits, cache = self._prefill(self.params, frames, prompts)
        else:
            logits, cache = self._prefill(self.params, prompts)
        cache = self._grow_cache(cache, S)
        key = jax.random.PRNGKey(self.scfg.seed)
        sc = self.scfg
        greedy = sc.temperature <= 0.0 and sc.top_k == 0 and sc.top_p >= 1.0
        tok = sample_logits(logits, jax.random.fold_in(key, 0),
                            sc.temperature, sc.top_k, sc.top_p)
        pos = jnp.full((B,), S, jnp.int32)
        if max_new_tokens <= 1:
            return jnp.concatenate([prompts, tok[:, None]], axis=1)
        if use_scan:
            done = jnp.zeros((B,), bool)
            eos = jnp.full((B,), -1, jnp.int32)
            temp = jnp.full((B,), sc.temperature, jnp.float32)
            top_k = jnp.full((B,), sc.top_k, jnp.int32)
            top_p = jnp.full((B,), sc.top_p, jnp.float32)
            ys = self.step(cache, None, tok, pos, done, eos, temp,
                           top_k, top_p, 1,
                           max_new_tokens - 1, greedy=greedy)[6]
            out = jnp.concatenate([tok[:, None], ys], axis=1)
        else:
            toks = [tok]
            for i in range(1, max_new_tokens):
                logits, cache = self._decode(self.params, tok, cache, pos)
                tok = sample_logits(logits, jax.random.fold_in(key, i),
                                    sc.temperature, sc.top_k, sc.top_p)
                toks.append(tok)
                pos = pos + 1
            out = jnp.stack(toks, axis=1)
        return jnp.concatenate([prompts, out], axis=1)

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        """Sample one token per row under the engine-wide ServeConfig
        (argmax when temperature <= 0, exactly as before; top-k / top-p via
        :func:`sample_logits`)."""
        sc = self.scfg
        return sample_logits(logits, key, sc.temperature, sc.top_k, sc.top_p)
