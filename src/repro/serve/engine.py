"""Batched serving engine: prefill -> synchronized decode with typed caches.

Static-batch continuous serving (all sequences advance together — the
TPU-friendly schedule); greedy or temperature sampling.  The engine stitches
the prefill cache (sized to the prompt) into max_len decode buffers, matching
``decode_attention``'s addressing, including ring buffers for local/SWA
layers.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import encdec, transformer


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 512
    temperature: float = 0.0
    seed: int = 0
    quant: Optional[str] = None   # convert weights to serving codes at load


class Engine:
    def __init__(self, cfg, params, scfg: ServeConfig = ServeConfig()):
        self.cfg = cfg
        if scfg.quant:
            # quantize + pack weight codes ONCE at engine construction (the
            # weight-code cache); every decode step then reads integer codes
            from repro.serve.quantize import quantize_params_for_serving
            params = quantize_params_for_serving(params, mode=scfg.quant)
        self.params = params
        self.scfg = scfg
        self.is_encdec = getattr(cfg, "enc_dec", False)
        mod = encdec if self.is_encdec else transformer
        self._prefill = jax.jit(lambda p, *a: mod.prefill(p, cfg, *a))
        # donate the cache: decode updates it in place (halves residency)
        self._decode = jax.jit(lambda p, t, c, pos: mod.decode_step(
            p, cfg, t, c, pos), donate_argnums=2)

    # -- cache stitching -----------------------------------------------------

    def _grow_cache(self, cache, prompt_len: int):
        """Pad prefill caches (sized S or window) into max_len buffers."""
        cfg, S, M = self.cfg, prompt_len, self.scfg.max_len
        if self.is_encdec:
            grown = dict(cache)
            for k in ("k", "v"):
                buf = jnp.zeros(cache[k].shape[:2] + (M,) + cache[k].shape[3:],
                                cache[k].dtype)
                grown[k] = jax.lax.dynamic_update_slice_in_dim(
                    buf, cache[k], 0, axis=2)
            return grown
        out = []
        for spec, c in zip(cfg.pattern, cache):
            c = dict(c)
            for key in ("k", "v", "shared_k", "shared_v"):
                if key not in c:
                    continue
                T = c[key].shape[2]
                # local/SWA k/v buffers are rings of at most `window` slots
                # (decode addresses slot pos % T); everything else grows to
                # max_len.  Prefill emits a window-size ring only when the
                # prompt exceeds the window — a shorter prompt's cache (T=S,
                # slot i == abs pos i == i % target) still needs growing.
                is_local_kv = (key in ("k", "v")
                               and spec.attn_type == "local"
                               and bool(cfg.window))
                target = min(M, cfg.window) if is_local_kv else M
                if T == target:
                    continue
                buf = jnp.zeros(c[key].shape[:2] + (target,)
                                + c[key].shape[3:], c[key].dtype)
                c[key] = jax.lax.dynamic_update_slice_in_dim(
                    buf, c[key], 0, axis=2)
            out.append(c)
        return tuple(out)

    # -- generation ----------------------------------------------------------

    def generate(self, prompts: jax.Array, max_new_tokens: int,
                 frames: Optional[jax.Array] = None) -> jax.Array:
        """prompts: [B, S] int32 -> [B, S + max_new_tokens]."""
        B, S = prompts.shape
        if self.is_encdec:
            logits, cache = self._prefill(self.params, frames, prompts)
        else:
            logits, cache = self._prefill(self.params, prompts)
        cache = self._grow_cache(cache, S)
        key = jax.random.PRNGKey(self.scfg.seed)
        toks = [self._sample(logits, key)]
        pos = jnp.int32(S)
        for i in range(max_new_tokens - 1):
            logits, cache = self._decode(self.params, toks[-1], cache, pos)
            key, sub = jax.random.split(key)
            toks.append(self._sample(logits, sub))
            pos = pos + 1
        return jnp.concatenate([prompts, jnp.stack(toks, axis=1)], axis=1)

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.scfg.temperature, axis=-1).astype(jnp.int32)
