"""Continuous-batching scheduler: slot-based request engine.

A fixed pool of ``slots`` decode lanes over one set of live cache buffers
(static shapes, allocated once).  Requests queue FIFO; whenever slots are
free the queue head is admitted in ONE batched prefill dispatch (prompts
padded right to a shared bucket, dummy rows for slots that stay empty), the
fresh caches are stitched into their slots with one masked write, and decode
resumes — sequences at different depths advance together through
per-sequence positions.  Decode runs in ``chunk``-token scan dispatches;
between chunks the scheduler drains emitted tokens, retires finished
sequences (EOS or budget), frees their slots, and admits from the queue.
Batch slots are never idle while work is queued — the request-level
analogue of keeping the LUT fabric saturated.

Static-shape invariants (TPU-friendly, no retrace after warmup):
  * live caches are ``[G, slots, max_len, ...]`` — admission writes slot
    rows via ``Engine.admit_batch`` (traced per-slot lengths + admit mask);
  * admission prefills a fixed ``[slots, bucket]`` batch, so prefill and
    stitch compile once per prompt bucket, not per prompt length or per
    number of admitted requests;
  * the chunked decode compiles exactly once — slot state (token, position,
    done, EOS id, sampling params) are all traced ``[slots]`` vectors; free
    slots carry the negative-position sentinel, which keeps every one of
    their keys masked.

With a paged engine (``ServeConfig(paged=True)``) the scheduler also runs
the block accounting: admission is gated on free pool pages (FIFO, no
skip-ahead), every decode round first maps pages for the chunk ahead, and
when the pool runs dry a slot is deterministically preempted and requeued
at the queue head with its emitted tokens intact — its re-admission
prefills prompt + emitted and continues bit-exactly, so temperature-0
transcripts match an uncontended run.  Page tables are fixed ``[slots,
entries]`` int32 arrays whose VALUES change round to round, so none of the
executors above ever retrace.

Fault tolerance (serve.faults + serve.request):

  * **Logical time only.**  Every robustness decision — deadline expiry,
    shed ordering, preemption slack — reads the ``now=`` values the caller
    threads through ``submit``/``step``/``run``, never wall clock, so a
    transcript replays bit-for-bit.
  * **Deadlines**: requests whose ``deadline`` passed finish ``timed_out``
    (queued or mid-decode) instead of emitting forever.
  * **Load shedding**: when the page pool (or, dense, the slot map)
    saturates past ``shed_watermark`` and more than ``overload_queue``
    requests wait, the excess is shed deterministically — lowest priority
    first, then least deadline slack, then latest submitted.
  * **Preemption ordering**: when the pool exhausts mid-decode and any
    active request carries a deadline, the victim is the MOST-slack slot
    (it can be requeued and still make its deadline); youngest-first is
    the tie-break and the no-deadline fallback.
  * **Detection + recovery**: the engine's finite-logits guard and
    ``PagePool.validate()`` surface corrupted state as
    :class:`~repro.serve.faults.CacheCorruption`; with
    ``snapshot_interval > 0`` the scheduler keeps a host-side rolling
    :meth:`snapshot` and on any :class:`~repro.serve.faults.EngineFault`
    restores it and replays — in-flight requests carry a bounded
    ``retries`` count and are dropped (status ``failed``) past
    ``max_retries``.  Injected dispatch failures roll back locally and
    simply re-dispatch.  Streaming callbacks never observe poisoned
    tokens (detection precedes ``emit``), but a recovery may replay
    tokens already streamed before the snapshot — at-least-once delivery.
  * **Crash recovery**: :meth:`save` / :meth:`load` round-trip the whole
    serving state (caches, slot vectors, queue, page tables, allocator,
    PRNG step) through ``ckpt.checkpoint``, so a fresh process resumes
    mid-stream and continues token-identically.
"""
from __future__ import annotations

import collections
import math
from typing import Deque, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt_lib
from repro.serve.engine import Engine
from repro.serve.faults import CacheCorruption, EngineFault, InjectedFault
from repro.serve.request import Request, RequestStatus


def _bucket_len(L: int, mode) -> int:
    """Pad target for a length-L prompt: "exact", "pow2", or a fixed multiple."""
    if mode == "exact":
        return L
    if mode == "pow2":
        P = 8
        while P < L:
            P *= 2
        return P
    return -(-L // int(mode)) * int(mode)


class Scheduler:
    """FIFO admission over a fixed slot map; ``Engine`` executes the batch."""

    def __init__(self, engine: Engine, slots: int = 4, chunk: int = 8,
                 prompt_bucket="pow2", *, max_retries: int = 2,
                 snapshot_interval: int = 0,
                 shed_watermark: Optional[float] = None,
                 overload_queue: Optional[int] = None):
        if engine.is_encdec:
            raise NotImplementedError(
                "continuous batching serves decoder-only LMs")
        self.engine = engine
        self.n_slots = slots
        self.chunk = chunk
        # recurrent (SSM/RWKV) states are not pad-invariant: the recurrence
        # integrates pad-token embeddings, so those models prefill at exact
        # prompt length and admission groups equal-length requests (trades a
        # prefill retrace per distinct length for correctness)
        if engine.has_recurrent_state:
            prompt_bucket = "exact"
        self.prompt_bucket = prompt_bucket
        # fault tolerance / overload policy
        self.max_retries = max_retries
        self.snapshot_interval = snapshot_interval
        self.shed_watermark = shed_watermark
        self.overload_queue = slots if overload_queue is None else \
            overload_queue
        scfg = engine.scfg
        self.cache = engine.init_cache(slots)
        # per-slot device state ([slots] vectors; free slot: pos=-1, done);
        # placed by the engine (sharded: pinned along the data axis)
        self.tok = engine.place_slot_state(jnp.zeros((slots,), jnp.int32))
        self.pos = engine.place_slot_state(jnp.full((slots,), -1, jnp.int32))
        self.done = engine.place_slot_state(jnp.ones((slots,), bool))
        # per-slot sampling state is mirrored host-side so admission can
        # rebuild the vectors without device reads
        self._eos_h = [-1] * slots
        self._temp_h = [scfg.temperature] * slots
        self._topk_h = [scfg.top_k] * slots
        self._topp_h = [scfg.top_p] * slots
        self._push_sampling_state()
        self._step = 0                      # global token step (PRNG fold-in)
        self.queue: Deque[Request] = collections.deque()
        self.slots: List[Optional[Request]] = [None] * slots
        self.finished: List[Request] = []
        # paged block accounting: admission order per slot (preemption
        # tie-breaks pick the youngest), monotone admission counter
        self._admit_seq = [0] * slots
        self._admit_counter = 0
        # fault-recovery state: rolling snapshot + requests submitted since
        # it was taken (restore re-queues them so no submission is lost)
        self._snap = None
        self._submit_log: List[Request] = []
        self._submit_count = 0
        self._ticks = 0
        self._retries_since_progress = 0
        # serving telemetry (the bench commits these): admission padding
        # waste = prefill_tokens / admitted_tokens (prefill always runs the
        # fixed [slots, bucket] shape), per-round slot occupancy as a
        # running sum (bounded state — a long-running server never grows it)
        self.stats = {"rounds": 0, "admission_rounds": 0,
                      "prefill_tokens": 0, "admitted_tokens": 0,
                      "emitted_tokens": 0, "occupancy_sum": 0.0,
                      "preemptions": 0, "shed": 0, "timed_out": 0,
                      "recoveries": 0, "dispatch_retries": 0, "failed": 0}

    # -- paged helpers -------------------------------------------------------

    @staticmethod
    def _seq(req: Request) -> List[int]:
        """The token sequence a (re-)admission must prefill: the prompt plus
        everything already emitted (non-empty only on a preemption resume)."""
        return list(req.prompt) + [int(t) for t in req.tokens]

    def _free_on_device(self, freed: List[int]) -> None:
        """Mark freed slots done with the negative-position sentinel."""
        fm = np.zeros((self.n_slots,), bool)
        fm[freed] = True
        fm = self.engine.place_slot_state(jnp.asarray(fm))
        self.done = self.done | fm
        self.pos = jnp.where(fm, -1, self.pos)

    def _preempt_victim(self, now_v) -> tuple[int, Request]:
        """Deterministic preemption: evict the slot with the MOST deadline
        slack (it can be requeued and still make its deadline; no-deadline
        requests have infinite slack and go first), tie-broken — and, when
        nothing carries a deadline, replaced — by youngest-first.  The
        victim's pages are released and the request keeps its emitted
        tokens: re-admission prefills prompt + emitted and continues, so
        temperature-0 transcripts match an uncontended run."""
        victim = max((s for s, r in enumerate(self.slots) if r is not None),
                     key=lambda s: (self.slots[s].slack(now_v),
                                    self._admit_seq[s]))
        req = self.slots[victim]
        self.slots[victim] = None
        self.engine.pool.release(victim)
        self._reset_slot_sampling(victim)
        req.status = RequestStatus.QUEUED
        req.slot = None
        self.stats["preemptions"] += 1
        self.engine.pool.preemptions += 1
        return victim, req

    def _ensure_chunk_pages(self, now_v=None) -> None:
        """Grow every active slot's page mapping to cover the next decode
        chunk; when the pool runs dry, preempt-and-requeue (most-slack /
        youngest first) until the remaining slots fit (or one sequence
        alone exhausts the pool, which is a configuration error)."""
        pool = self.engine.pool
        max_len = self.engine.scfg.max_len
        freed, evicted = [], []
        while True:
            active = [(s, r) for s, r in enumerate(self.slots)
                      if r is not None]
            need = [(s, min(len(r.prompt) + len(r.tokens) + self.chunk - 1,
                            max_len)) for s, r in active]
            failed = next((s for s, n in need if not pool.ensure(s, n)),
                          None)
            if failed is None:
                break
            if len(active) == 1:
                raise RuntimeError(
                    "KV page pool exhausted by a single sequence — "
                    "raise ServeConfig.num_pages (or lower max_len)")
            slot, req = self._preempt_victim(now_v)
            evicted.append(req)
            freed.append(slot)
        if evicted:
            # requeue so original FIFO order survives: we evicted in
            # decreasing expendability, so appendleft in eviction order puts
            # the least expendable evictee at the queue head
            for req in evicted:
                self.queue.appendleft(req)
            self._free_on_device(freed)

    # -- admission -----------------------------------------------------------

    def submit(self, request: Request, now=None) -> Request:
        """Validate and queue a request.  ``now`` (here and in ``step``/
        ``run``) may be a timestamp or a zero-arg clock callable — the
        callable is read at the bookkeeping moment, so finish times stamp
        after the decode chunk that produced the final token.  Malformed
        requests are rejected HERE with a clear ``ValueError`` — not as a
        shape error (or a silent hang) deep inside admission."""
        L = len(request.prompt)
        max_len = self.engine.scfg.max_len
        if request.max_new_tokens < 0:
            raise ValueError(
                f"max_new_tokens must be >= 0, got {request.max_new_tokens}")
        if L > max_len:
            raise ValueError(
                f"prompt length ({L}) exceeds max_len ({max_len})")
        if L + request.max_new_tokens > max_len:
            raise ValueError(
                f"prompt ({L}) + max_new_tokens ({request.max_new_tokens}) "
                f"exceeds max_len ({max_len})")
        if request.deadline is not None and (
                not isinstance(request.deadline, (int, float))
                or not math.isfinite(request.deadline)):
            raise ValueError(
                f"deadline must be a finite logical time, got "
                f"{request.deadline!r}")
        if not isinstance(request.priority, (int, float)) or \
                not math.isfinite(request.priority):
            raise ValueError(
                f"priority must be finite, got {request.priority!r}")
        request.arrival_time = now() if callable(now) else now
        request.status = RequestStatus.QUEUED
        self._submit_count += 1
        request._seq = self._submit_count
        if self.snapshot_interval:
            self._submit_log.append(request)
        self.queue.append(request)
        return request

    def _sampling_for(self, req: Request):
        scfg = self.engine.scfg
        temp = scfg.temperature if req.temperature is None else req.temperature
        top_k = scfg.top_k if req.top_k is None else req.top_k
        top_p = scfg.top_p if req.top_p is None else req.top_p
        return float(temp), int(top_k), float(top_p)

    def _reset_slot_sampling(self, slot: int) -> None:
        """Freed slots fall back to the engine defaults so a past sampling
        request doesn't keep the greedy decode fast path disabled."""
        scfg = self.engine.scfg
        self._eos_h[slot] = -1
        (self._temp_h[slot], self._topk_h[slot],
         self._topp_h[slot]) = (scfg.temperature, scfg.top_k, scfg.top_p)

    def _admit(self, now=None) -> int:
        """Fill free slots from the queue head in ONE fused dispatch
        (batched prefill + masked stitch + first-token sampling + slot-state
        merge); returns #admissions.  Paged engines gate admission on free
        pool pages — candidates that don't fit go back to the queue head in
        FIFO order (no skip-ahead, so ordering stays deterministic).  An
        injected dispatch failure rolls the admission back locally (pages
        released, candidates requeued in order) and re-raises for the retry
        path."""
        free = [s for s in range(self.n_slots) if self.slots[s] is None]
        take = [self.queue.popleft()
                for _ in range(min(len(free), len(self.queue)))]
        if self.engine.has_recurrent_state and take:
            # recurrent states must prefill unpadded: admit only the leading
            # run of equal-length requests, requeue the rest (FIFO order)
            L0 = len(self._seq(take[0]))
            for i, r in enumerate(take):
                if len(self._seq(r)) != L0:
                    for r2 in reversed(take[i:]):
                        self.queue.appendleft(r2)
                    take = take[:i]
                    break
        admitted = list(zip(free, take))
        if self.engine.paged and admitted:
            fits = []
            for i, (slot, req) in enumerate(admitted):
                if self.engine.pool.admit(slot, self._seq(req)) is None:
                    if (not fits
                            and not any(r is not None for r in self.slots)
                            and self.engine.pool.allocated_pages == 0):
                        raise RuntimeError(
                            "request needs more KV pages than the whole "
                            "pool holds — raise ServeConfig.num_pages")
                    for _, r in reversed(admitted[i:]):
                        self.queue.appendleft(r)
                    admitted = fits
                    break
                fits.append((slot, req))
        if not admitted:
            return 0
        R = self.n_slots
        # the bucket never exceeds max_len: submit() guarantees every prompt
        # fits, and the live buffers are max_len slots long
        P = min(max(_bucket_len(len(self._seq(r)), self.prompt_bucket)
                    for _, r in admitted), self.engine.scfg.max_len)
        prompts = np.zeros((R, P), np.int32)
        lengths = np.ones((R,), np.int32)
        mask = np.zeros((R,), bool)
        budget_one = np.zeros((R,), bool)
        for slot, req in admitted:
            seq = self._seq(req)
            L = len(seq)
            prompts[slot, :L] = seq
            lengths[slot] = L
            mask[slot] = True
            # <=1: budget-0 requests also finish at admission (their slot is
            # never occupied; the sampled token is simply not emitted).
            # ``remaining`` (not max_new_tokens) so preemption resumes with
            # a partially spent budget admit correctly.
            budget_one[slot] = req.remaining <= 1
            (self._temp_h[slot], self._topk_h[slot],
             self._topp_h[slot]) = self._sampling_for(req)
            self._eos_h[slot] = -1 if req.eos_id is None else int(req.eos_id)
        self._push_sampling_state()
        try:
            (self.cache, self.tok, self.pos, self.done, tok0, done0,
             ok0) = self.engine.admit_batch(
                self.cache, prompts, lengths, mask, budget_one, self.eos,
                self.temperature, self.top_k, self.top_p, self.tok, self.pos,
                self.done, self._step)
        except InjectedFault:
            # the dispatch never ran: release this admission's pages, put
            # the candidates back at the queue head in FIFO order, and let
            # the retry path re-dispatch an identical round
            for slot, _ in admitted:
                if self.engine.paged:
                    self.engine.pool.release(slot)
                self._reset_slot_sampling(slot)
            self._push_sampling_state()
            for _, req in reversed(admitted):
                self.queue.appendleft(req)
            raise
        self._step += 1
        self.stats["admission_rounds"] += 1
        self.stats["prefill_tokens"] += R * P
        self.stats["admitted_tokens"] += int(
            sum(lengths[s] for s, _ in admitted))
        if self.engine.scfg.guards:
            ok0_h = np.asarray(ok0)
            bad = [s for s, _ in admitted if not ok0_h[s]]
            if bad:
                raise CacheCorruption(
                    f"non-finite logits at admission for slots {bad}")
        tok0_h, done0_h = np.asarray(tok0), np.asarray(done0)
        if callable(now):
            now = now()
        for slot, req in admitted:
            req.status = RequestStatus.RUNNING
            req.slot = slot
            self._admit_counter += 1
            self._admit_seq[slot] = self._admit_counter
            if req.remaining >= 1:
                req.emit(int(tok0_h[slot]))
            if done0_h[slot]:
                eos = self._eos_h[slot]
                req.finish("eos" if eos >= 0 and req.tokens
                           and req.tokens[-1] == eos
                           else "length", now)
                self.finished.append(req)
                self._reset_slot_sampling(slot)
                if self.engine.paged:
                    self.engine.pool.release(slot)
            else:
                self.slots[slot] = req
        return len(admitted)

    def _push_sampling_state(self) -> None:
        place = self.engine.place_slot_state
        self.eos = place(jnp.asarray(self._eos_h, jnp.int32))
        self.temperature = place(jnp.asarray(self._temp_h, jnp.float32))
        self.top_k = place(jnp.asarray(self._topk_h, jnp.int32))
        self.top_p = place(jnp.asarray(self._topp_h, jnp.float32))

    # -- deadlines & load shedding (logical time only) ------------------------

    def _retire(self, req: Request, reason: str, now_v) -> None:
        """Terminal bookkeeping shared by expiry/shed/failure paths."""
        slot = req.slot
        req.finish(reason, now_v)
        self.finished.append(req)
        if slot is not None:
            self.slots[slot] = None
            self._reset_slot_sampling(slot)
            if self.engine.paged:
                self.engine.pool.release(slot)

    def _expire_deadlines(self, now_v) -> None:
        """Finish every request whose logical deadline passed — queued ones
        without running, mid-decode ones with their partial transcript —
        with status ``timed_out``.  No-op when the caller runs clockless."""
        if now_v is None:
            return
        expired = [r for r in self.queue
                   if r.deadline is not None and r.deadline <= now_v]
        if expired:
            gone = set(map(id, expired))
            self.queue = collections.deque(
                r for r in self.queue if id(r) not in gone)
        freed = []
        for s, r in enumerate(self.slots):
            if r is not None and r.deadline is not None \
                    and r.deadline <= now_v:
                expired.append(r)
                freed.append(s)
        for r in expired:
            self._retire(r, "timed_out", now_v)
            self.stats["timed_out"] += 1
        if freed:
            self._free_on_device(freed)

    def _shed_overload(self, now_v) -> None:
        """Deterministic admission control: when the page pool (or, dense,
        the slot map) saturates past ``shed_watermark`` and more than
        ``overload_queue`` requests wait, shed the excess — lowest priority
        first, then least deadline slack (it was going to miss anyway),
        then latest submitted.  Same state + same watermark => same shed
        set, replayable bit-for-bit."""
        if self.shed_watermark is None or not self.queue:
            return
        if self.engine.paged:
            saturation = self.engine.pool.saturation
        else:
            saturation = sum(r is not None for r in self.slots) / self.n_slots
        if saturation < self.shed_watermark:
            return
        excess = len(self.queue) - self.overload_queue
        if excess <= 0:
            return
        order = sorted(self.queue,
                       key=lambda r: (r.priority, r.slack(now_v),
                                      -getattr(r, "_seq", 0)))
        victims = set(map(id, order[:excess]))
        self.queue = collections.deque(
            r for r in self.queue if id(r) not in victims)
        for r in order[:excess]:
            self._retire(r, "shed", now_v)
            self.stats["shed"] += 1

    # -- snapshot / restore / crash recovery ----------------------------------

    def snapshot(self) -> dict:
        """Host-side copy of the COMPLETE serving state: decode caches,
        slot vectors, sampling mirrors, PRNG step, queue/slot request
        states, page-pool allocator, telemetry.  Everything a restore needs
        to replay token-identically; per-request ``retries`` deliberately
        stays OUT (it must survive restores, or the retry bound would reset
        with every recovery)."""
        reqs = [r for r in self.queue] + \
               [r for r in self.slots if r is not None]
        return {
            "cache": ckpt_lib.tree_to_host(self.cache),
            "tok": np.asarray(self.tok), "pos": np.asarray(self.pos),
            "done": np.asarray(self.done),
            "eos_h": list(self._eos_h), "temp_h": list(self._temp_h),
            "topk_h": list(self._topk_h), "topp_h": list(self._topp_h),
            "step": self._step,
            "admit_seq": list(self._admit_seq),
            "admit_counter": self._admit_counter,
            "queue": list(self.queue),
            "slots": list(self.slots),
            "finished_len": len(self.finished),
            "req_state": [(r, r.status, list(r.tokens), r.finish_reason,
                           r.finish_time, r.slot) for r in reqs],
            "pool": (self.engine.pool.state_dict()
                     if self.engine.paged else None),
            "stats": dict(self.stats),
        }

    def restore(self, snap: dict) -> None:
        """Reinstate a :meth:`snapshot` — device state re-placed through the
        engine (sharded placements pinned, so executors never retrace),
        request objects mutated back in place, allocator reloaded.
        Requests submitted AFTER the snapshot rejoin the queue tail in
        submit order, so recovery never drops a submission."""
        eng = self.engine
        self.cache = eng.place_cache(snap["cache"])
        self.tok = eng.place_slot_state(jnp.asarray(snap["tok"]))
        self.pos = eng.place_slot_state(jnp.asarray(snap["pos"]))
        self.done = eng.place_slot_state(jnp.asarray(snap["done"]))
        self._eos_h = list(snap["eos_h"])
        self._temp_h = list(snap["temp_h"])
        self._topk_h = list(snap["topk_h"])
        self._topp_h = list(snap["topp_h"])
        self._push_sampling_state()
        self._step = snap["step"]
        self._admit_seq = list(snap["admit_seq"])
        self._admit_counter = snap["admit_counter"]
        self.queue = collections.deque(snap["queue"])
        self.slots = list(snap["slots"])
        del self.finished[snap["finished_len"]:]
        for r, status, toks, reason, ftime, slot in snap["req_state"]:
            r.status = status
            r.tokens = list(toks)
            r.finish_reason = reason
            r.finish_time = ftime
            r.slot = slot
        if snap["pool"] is not None:
            eng.pool.load_state(snap["pool"])
        self.stats = dict(snap["stats"])
        for r in self._submit_log:       # post-snapshot submissions survive
            r.status = RequestStatus.QUEUED
            r.tokens = []
            r.finish_reason = None
            r.finish_time = None
            r.slot = None
            self.queue.append(r)

    def _recover(self, err: EngineFault, now_v) -> None:
        """Bounded-retry fault recovery.  Dispatch failures already rolled
        back locally — count and re-dispatch next round.  Corruption
        restores the rolling snapshot, charges one retry to every
        in-flight request, and drops (status ``failed``) any that crossed
        ``max_retries`` — deterministic, since the charge set and the
        restore are both functions of the replayed state."""
        self._retries_since_progress += 1
        if self._retries_since_progress > self.max_retries:
            raise err
        if isinstance(err, InjectedFault):
            self.stats["recoveries"] += 1
            self.stats["dispatch_retries"] += 1
            return
        if self._snap is None:
            raise RuntimeError(
                "corrupted serving state detected but snapshots are "
                "disabled — construct Scheduler(snapshot_interval=1) to "
                "enable recovery") from err
        affected = [r for r in self.slots if r is not None]
        self.restore(self._snap)     # also rewinds stats to the snapshot
        self.stats["recoveries"] += 1
        for r in affected:
            r.retries += 1
            if r.retries > self.max_retries:
                # Request is a value-eq dataclass: filter by IDENTITY
                if any(q is r for q in self.queue):
                    self.queue = collections.deque(
                        q for q in self.queue if q is not r)
                if r.slot is not None and self.slots[r.slot] is r:
                    self._free_on_device([r.slot])
                self._retire(r, "failed", now_v)
                self.stats["failed"] += 1

    def save(self, ckpt_dir: str, step: Optional[int] = None):
        """Write the whole serving state as a committed ``ckpt.checkpoint``
        (atomic dir, msgpack+zstd arrays, JSON manifest): the crash-
        recovery path.  Streaming callbacks (``on_token``) are process-
        local and are NOT serialized — a restored request streams only
        from its restore point on."""
        tree = {"cache": self.cache, "tok": self.tok, "pos": self.pos,
                "done": self.done}
        recs = {
            "queue": [_req_record(r) for r in self.queue],
            "slots": [None if r is None else _req_record(r)
                      for r in self.slots],
            "finished": [_req_record(r) for r in self.finished],
        }
        extra = {"serving": {
            "step": self._step, "ticks": self._ticks,
            "eos_h": self._eos_h, "temp_h": self._temp_h,
            "topk_h": self._topk_h, "topp_h": self._topp_h,
            "admit_seq": self._admit_seq,
            "admit_counter": self._admit_counter,
            "submit_count": self._submit_count,
            "stats": self.stats,
            "pool": (self.engine.pool.state_dict()
                     if self.engine.paged else None),
            "geometry": {"slots": self.n_slots, "chunk": self.chunk,
                         "max_len": self.engine.scfg.max_len,
                         "paged": self.engine.paged},
            **recs,
        }}
        return ckpt_lib.save(ckpt_dir, self._ticks if step is None
                               else step, tree, extra=extra)

    def load(self, ckpt_dir: str, step: Optional[int] = None) -> None:
        """Restore :meth:`save` state into this (freshly constructed)
        scheduler — same engine config / slot count / chunk.  Requests are
        rebuilt as new ``Request`` objects (find them in ``queue`` /
        ``slots`` / ``finished``); decode then continues token-identically
        to the uninterrupted run."""
        tree = {"cache": self.cache, "tok": self.tok, "pos": self.pos,
                "done": self.done}
        restored, extra = ckpt_lib.restore(
            ckpt_dir, tree, step=step,
            shardings=self.engine.serving_state_shardings())
        s = extra["serving"]
        geo = s["geometry"]
        if (geo["slots"], geo["chunk"], geo["max_len"], geo["paged"]) != \
                (self.n_slots, self.chunk, self.engine.scfg.max_len,
                 self.engine.paged):
            raise ValueError(
                f"serving-checkpoint geometry {geo} does not match this "
                "scheduler/engine")
        self.cache = self.engine.place_cache(restored["cache"])
        self.tok = self.engine.place_slot_state(restored["tok"])
        self.pos = self.engine.place_slot_state(restored["pos"])
        self.done = self.engine.place_slot_state(restored["done"])
        self._eos_h = list(s["eos_h"])
        self._temp_h = list(s["temp_h"])
        self._topk_h = list(s["topk_h"])
        self._topp_h = list(s["topp_h"])
        self._push_sampling_state()
        self._step = s["step"]
        self._ticks = s["ticks"]
        self._admit_seq = list(s["admit_seq"])
        self._admit_counter = s["admit_counter"]
        self._submit_count = s["submit_count"]
        self.stats = dict(s["stats"])
        if s["pool"] is not None:
            self.engine.pool.load_state(s["pool"])
        self.queue = collections.deque(
            _req_from_record(d) for d in s["queue"])
        self.slots = [None if d is None else _req_from_record(d)
                      for d in s["slots"]]
        self.finished = [_req_from_record(d) for d in s["finished"]]

    # -- the scheduling loop -------------------------------------------------

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slots)

    @property
    def padding_waste(self) -> float:
        """prefill_tokens / admitted_tokens across all admission rounds —
        how many padded prefill tokens the fixed [slots, bucket] admission
        shape cost per useful prompt token (1.0 = no waste)."""
        a = self.stats["admitted_tokens"]
        return self.stats["prefill_tokens"] / a if a else 0.0

    @property
    def mean_occupancy(self) -> float:
        """Mean fraction of slots holding live requests per decode round."""
        n = self.stats["rounds"]
        return self.stats["occupancy_sum"] / n if n else 0.0

    def step(self, now=None) -> int:
        """One scheduling round: expire deadlines, shed overload, (maybe)
        snapshot, admit into free slots, decode one chunk, retire finished
        sequences.  Returns the number of useful tokens emitted this round
        (0 on a recovered fault — the retry replays next round)."""
        now_v = now() if callable(now) else now
        self._expire_deadlines(now_v)
        self._shed_overload(now_v)
        if self.snapshot_interval and \
                self._ticks % self.snapshot_interval == 0:
            self._snap = self.snapshot()
            self._submit_log.clear()
        self._ticks += 1
        try:
            emitted = self._step_inner(now, now_v)
        except EngineFault as err:
            self._recover(err, now_v)
            return 0
        self._retries_since_progress = 0
        return emitted

    def _step_inner(self, now, now_v) -> int:
        self._admit(now)
        if not any(r is not None for r in self.slots):
            return 0
        if self.engine.paged:
            # block accounting: map pages for the chunk ahead; preempts
            # most-slack/youngest-first when the pool is exhausted
            self._ensure_chunk_pages(now_v)
            if not any(r is not None for r in self.slots):
                return 0
        # host mirrors let us pick the argmax-only decode variant statically
        greedy = all(t <= 0.0 and k == 0 and p >= 1.0 for t, k, p in
                     zip(self._temp_h, self._topk_h, self._topp_h))
        (self.cache, self.tok, self.pos, self.done, toks,
         dones, ok) = self.engine.decode_chunk(
            self.cache, self.tok, self.pos, self.done, self.eos,
            self.temperature, self.top_k, self.top_p, self._step, self.chunk,
            greedy=greedy)
        self._step += self.chunk
        if self.engine.scfg.guards:
            ok_h = np.asarray(ok)
            if not ok_h.all():
                # poisoned logits never reach a streaming callback:
                # detection precedes every emit below
                raise CacheCorruption(
                    "non-finite logits in decode for slots "
                    f"{np.flatnonzero(~ok_h).tolist()}")
        self.stats["rounds"] += 1
        self.stats["occupancy_sum"] += (
            sum(r is not None for r in self.slots) / self.n_slots)
        toks_h, dones_h = np.asarray(toks), np.asarray(dones)
        if callable(now):      # stamp finish times after the chunk completed
            now = now()
        emitted, freed = 0, []
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            for j in range(self.chunk):
                req.emit(int(toks_h[slot, j]))
                emitted += 1
                if dones_h[slot, j]:
                    req.finish("eos", now)
                    break
                if req.remaining <= 0:
                    req.finish("length", now)
                    break
            if req.done:
                self.finished.append(req)
                self.slots[slot] = None
                self._reset_slot_sampling(slot)
                if self.engine.paged:
                    self.engine.pool.release(slot)
                freed.append(slot)
        if freed:
            self._free_on_device(freed)
        self.stats["emitted_tokens"] += emitted
        return emitted

    def check_drained(self) -> None:
        """Leak telemetry at drain: with no work left, the page pool must
        hold ZERO allocated pages outside the reserved null pages, and no
        page may be referenced without a slot mapping reaching it."""
        if self.has_work or not self.engine.paged:
            return
        pool = self.engine.pool
        leaked = pool.leaked_pages()
        assert pool.allocated_pages == 0 and not leaked, (
            f"page leak at drain: {pool.allocated_pages} pages still "
            f"allocated, unreachable={leaked}")

    def run(self, requests: Sequence[Request] = (), now=None,
            max_rounds: int = 100_000) -> List[Request]:
        """Submit ``requests`` and drive rounds until everything finishes."""
        for r in requests:
            self.submit(r, now)
        rounds = 0
        while self.has_work:
            self.step(now)
            rounds += 1
            if rounds > max_rounds:
                raise RuntimeError("scheduler failed to drain "
                                   f"({len(self.queue)} queued)")
        if self.engine.scfg.guards:
            self.check_drained()
        return self.finished


def _req_record(r: Request) -> dict:
    """JSON-able snapshot of one request (``on_token`` dropped)."""
    return {"prompt": [int(t) for t in r.prompt],
            "max_new_tokens": r.max_new_tokens,
            "eos_id": r.eos_id, "temperature": r.temperature,
            "top_k": r.top_k, "top_p": r.top_p,
            "deadline": r.deadline, "priority": r.priority,
            "status": r.status.value, "tokens": list(r.tokens),
            "finish_reason": r.finish_reason, "slot": r.slot,
            "arrival_time": r.arrival_time, "finish_time": r.finish_time,
            "retries": r.retries, "seq": getattr(r, "_seq", 0)}


def _req_from_record(d: dict) -> Request:
    r = Request(prompt=d["prompt"], max_new_tokens=d["max_new_tokens"],
                eos_id=d["eos_id"], temperature=d["temperature"],
                top_k=d["top_k"], top_p=d["top_p"],
                deadline=d["deadline"], priority=d["priority"])
    r.status = RequestStatus(d["status"])
    r.tokens = list(d["tokens"])
    r.finish_reason = d["finish_reason"]
    r.slot = d["slot"]
    r.arrival_time = d["arrival_time"]
    r.finish_time = d["finish_time"]
    r.retries = d["retries"]
    r._seq = d["seq"]
    return r
