"""Continuous-batching scheduler: slot-based request engine with chunked
prefill.

A fixed pool of ``slots`` decode lanes over one set of live cache buffers
(static shapes, allocated once).  Requests queue FIFO; every round runs ONE
unified ``Engine.step`` dispatch carrying ``prefill_chunk`` prompt tokens
(the chunk lane — page-aligned slices of the prompts currently admitting,
served FIFO: mid-prefill slots first, then new admissions from the queue
head) followed by ``chunk`` decode tokens for every slot.  A prompt's last
chunk entry samples its first output token in the same dispatch and the
slot joins the decode lane immediately, so admission never stalls decoding:
long prompts admit over several rounds at a fixed per-round cost (flat p99
decode latency) instead of monopolizing a whole admission round, and the
chunk budget is filled with real prompt tokens (padding waste ~1.0).
Batch slots are never idle while work is queued — the request-level
analogue of keeping the LUT fabric saturated.

Models whose prompt state cannot be built one token at a time fall back to
*monolithic admission* (``Engine.admit_monolithic``: one batched
exact-length prefill dispatch, stitched into the masked slots): recurrent
(SSM/RWKV) layers, MoE routing, int8-KV — and, per-request, SWA prompts
longer than the attention window (see ``Engine.chunk_eligible``).
Monolithic rounds group equal-length requests and prefill at exact prompt
length — no padding buckets anywhere.

Static-shape invariants (TPU-friendly, no retrace after warmup):
  * live caches are ``[G, slots, max_len, ...]``; the unified step compiles
    once per (has-chunk-entries, chunk, greedy) — chunk entries are fixed
    ``[prefill_chunk]`` vectors padded with no-op entries, and slot state
    (token, position, done, EOS id, sampling params) are all traced
    ``[slots]`` vectors; free slots carry the negative-position sentinel,
    which keeps every one of their keys masked;
  * mid-prefill slots park done=True on their latest chunk entry's (token,
    position) — iterations that don't target them re-run that cache write
    idempotently, so interleaving is bit-transparent (fresh admissions are
    parked on their FIRST entry host-side before the dispatch, replacing
    the free-slot sentinel whose clamped write would corrupt page 0).

With a paged engine (``ServeConfig(paged=True)``) the scheduler also runs
the block accounting: admission is gated on free pool pages (FIFO, no
skip-ahead), every decode round first maps pages for the chunk ahead, and
when the pool runs dry a slot is deterministically preempted and requeued
at the queue head with its emitted tokens intact — its re-admission
prefills prompt + emitted and continues bit-exactly, so temperature-0
transcripts match an uncontended run.  Page tables are fixed ``[slots,
entries]`` int32 arrays whose VALUES change round to round, so none of the
executors above ever retrace.

Fault tolerance (serve.faults + serve.request):

  * **Logical time only.**  Every robustness decision — deadline expiry,
    shed ordering, preemption slack — reads the ``now=`` values the caller
    threads through ``submit``/``step``/``run``, never wall clock, so a
    transcript replays bit-for-bit.
  * **Deadlines**: requests whose ``deadline`` passed finish ``timed_out``
    (queued or mid-decode) instead of emitting forever.
  * **Load shedding**: when the page pool (or, dense, the slot map)
    saturates past ``shed_watermark`` and more than ``overload_queue``
    requests wait, the excess is shed deterministically — lowest priority
    first, then least deadline slack, then latest submitted.
  * **Preemption ordering**: when the pool exhausts mid-decode and any
    active request carries a deadline, the victim is the MOST-slack slot
    (it can be requeued and still make its deadline); youngest-first is
    the tie-break and the no-deadline fallback.
  * **Detection + recovery**: the engine's finite-logits guard and
    ``PagePool.validate()`` surface corrupted state as
    :class:`~repro.serve.faults.CacheCorruption`; with
    ``snapshot_interval > 0`` the scheduler keeps a host-side rolling
    :meth:`snapshot` and on any :class:`~repro.serve.faults.EngineFault`
    restores it and replays — in-flight requests carry a bounded
    ``retries`` count and are dropped (status ``failed``) past
    ``max_retries``.  Injected dispatch failures roll back locally and
    simply re-dispatch.  Streaming callbacks never observe poisoned
    tokens (detection precedes ``emit``), but a recovery may replay
    tokens already streamed before the snapshot — at-least-once delivery.
  * **Crash recovery**: :meth:`save` / :meth:`load` round-trip the whole
    serving state (caches, slot vectors, queue, page tables, allocator,
    PRNG step) through ``ckpt.checkpoint``, so a fresh process resumes
    mid-stream and continues token-identically.
"""
from __future__ import annotations

import collections
import math
import warnings
from typing import Deque, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt_lib
from repro.serve.engine import Engine
from repro.serve.faults import CacheCorruption, EngineFault, InjectedFault
from repro.serve.request import Request, RequestStatus

_UNSET = object()


class Scheduler:
    """FIFO admission over a fixed slot map; ``Engine`` executes the batch."""

    def __init__(self, engine: Engine, slots: int = 4, chunk: int = 8,
                 prompt_bucket=_UNSET, *, max_retries: int = 2,
                 snapshot_interval: int = 0,
                 shed_watermark: Optional[float] = None,
                 overload_queue: Optional[int] = None):
        if engine.is_encdec:
            raise NotImplementedError(
                "continuous batching serves decoder-only LMs")
        self.engine = engine
        self.n_slots = slots
        self.chunk = chunk
        if prompt_bucket is not _UNSET:
            # one-release deprecation shim: the bucket machinery is gone —
            # prompts admit in page-aligned chunks (ServeConfig.prefill_chunk)
            # and the monolithic fallback prefills at exact length
            warnings.warn(
                "Scheduler(prompt_bucket=...) is deprecated and ignored: "
                "admission is chunked — size it with "
                "ServeConfig.prefill_chunk; the monolithic fallback "
                "(recurrent/MoE/int8-KV models) prefills at exact prompt "
                "length", DeprecationWarning, stacklevel=2)
        # fault tolerance / overload policy
        self.max_retries = max_retries
        self.snapshot_interval = snapshot_interval
        self.shed_watermark = shed_watermark
        self.overload_queue = slots if overload_queue is None else \
            overload_queue
        scfg = engine.scfg
        self.cache = engine.init_cache(slots)
        # per-slot device state ([slots] vectors; free slot: pos=-1, done);
        # placed by the engine (sharded: pinned along the data axis)
        self.tok = engine.place_slot_state(jnp.zeros((slots,), jnp.int32))
        self.pos = engine.place_slot_state(jnp.full((slots,), -1, jnp.int32))
        self.done = engine.place_slot_state(jnp.ones((slots,), bool))
        # per-slot sampling state is mirrored host-side so admission can
        # rebuild the vectors without device reads
        self._eos_h = [-1] * slots
        self._temp_h = [scfg.temperature] * slots
        self._topk_h = [scfg.top_k] * slots
        self._topp_h = [scfg.top_p] * slots
        self._push_sampling_state()
        self._step = 0                      # global token step (PRNG fold-in)
        self.queue: Deque[Request] = collections.deque()
        self.slots: List[Optional[Request]] = [None] * slots
        self.finished: List[Request] = []
        # paged block accounting: admission order per slot (preemption
        # tie-breaks pick the youngest), monotone admission counter
        self._admit_seq = [0] * slots
        self._admit_counter = 0
        # chunked-prefill bookkeeping: per-slot tokens already fed through
        # the chunk lane and the total the admission must feed (progress <
        # target = mid-prefill; monolithic admissions set both at once)
        self._progress = [0] * slots
        self._target = [0] * slots
        # fault-recovery state: rolling snapshot + requests submitted since
        # it was taken (restore re-queues them so no submission is lost)
        self._snap = None
        self._submit_log: List[Request] = []
        self._submit_count = 0
        self._ticks = 0
        self._retries_since_progress = 0
        # serving telemetry (the bench commits these): admission padding
        # waste = prefill_tokens / admitted_tokens (the chunk lane always
        # dispatches its fixed [prefill_chunk] width), per-round slot
        # occupancy as a running sum (bounded state — a long-running server
        # never grows it)
        self.stats = {"rounds": 0, "admission_rounds": 0,
                      "prefill_tokens": 0, "admitted_tokens": 0,
                      "emitted_tokens": 0, "occupancy_sum": 0.0,
                      "preemptions": 0, "shed": 0, "timed_out": 0,
                      "recoveries": 0, "dispatch_retries": 0, "failed": 0,
                      "spec_rounds": 0, "spec_drafted": 0,
                      "spec_accepted": 0}

    # -- paged helpers -------------------------------------------------------

    @staticmethod
    def _seq(req: Request) -> List[int]:
        """The token sequence a (re-)admission must prefill: the prompt plus
        everything already emitted (non-empty only on a preemption resume)."""
        return list(req.prompt) + [int(t) for t in req.tokens]

    def _free_on_device(self, freed: List[int]) -> None:
        """Mark freed slots done with the negative-position sentinel."""
        fm = np.zeros((self.n_slots,), bool)
        fm[freed] = True
        fm = self.engine.place_slot_state(jnp.asarray(fm))
        self.done = self.done | fm
        self.pos = jnp.where(fm, -1, self.pos)

    def _preempt_victim(self, now_v) -> tuple[int, Request]:
        """Deterministic preemption: evict the slot with the MOST deadline
        slack (it can be requeued and still make its deadline; no-deadline
        requests have infinite slack and go first), tie-broken — and, when
        nothing carries a deadline, replaced — by youngest-first.  The
        victim's pages are released and the request keeps its emitted
        tokens: re-admission prefills prompt + emitted and continues, so
        temperature-0 transcripts match an uncontended run."""
        victim = max((s for s, r in enumerate(self.slots) if r is not None),
                     key=lambda s: (self.slots[s].slack(now_v),
                                    self._admit_seq[s]))
        req = self.slots[victim]
        self.slots[victim] = None
        self.engine.pool.release(victim)
        self._reset_slot_sampling(victim)
        self._progress[victim] = self._target[victim] = 0
        req.status = RequestStatus.QUEUED
        req.slot = None
        self.stats["preemptions"] += 1
        self.engine.pool.preemptions += 1
        return victim, req

    def _ensure_chunk_pages(self, now_v=None) -> None:
        """Grow every active slot's page mapping to cover the next decode
        chunk; when the pool runs dry, preempt-and-requeue (most-slack /
        youngest first) until the remaining slots fit (or one sequence
        alone exhausts the pool, which is a configuration error)."""
        pool = self.engine.pool
        scfg = self.engine.scfg
        max_len = scfg.max_len
        # a speculative round writes a draft_k+1-token block per slot, so
        # reserve for whichever lane this round ends up running (the spec
        # fallback decision happens after assembly; over-reservation trims
        # back after the round)
        W = max(self.chunk, scfg.draft_k + 1) if scfg.spec_decode \
            else self.chunk
        freed, evicted = [], []
        while True:
            active = [(s, r) for s, r in enumerate(self.slots)
                      if r is not None]
            # a decoding slot's pending token (sampled, unwritten) is the
            # first of the chunk's writes, so it needs chunk-1 positions past
            # its residency; a mid-prefill slot that completes this round
            # decodes a FULL chunk past its sequence (which may include
            # previously emitted tokens after a preempt-and-resume), so it
            # needs one more
            need = [(s, min(len(r.prompt) + len(r.tokens) + W
                            - (0 if self._progress[s] < self._target[s]
                               else 1), max_len))
                    for s, r in active]
            failed = next((s for s, n in need if not pool.ensure(s, n)),
                          None)
            if failed is None:
                break
            if len(active) == 1:
                raise RuntimeError(
                    "KV page pool exhausted by a single sequence — "
                    "raise ServeConfig.num_pages (or lower max_len)")
            slot, req = self._preempt_victim(now_v)
            evicted.append(req)
            freed.append(slot)
        if evicted:
            # requeue so original FIFO order survives: we evicted in
            # decreasing expendability, so appendleft in eviction order puts
            # the least expendable evictee at the queue head
            for req in evicted:
                self.queue.appendleft(req)
            self._free_on_device(freed)

    # -- admission -----------------------------------------------------------

    def submit(self, request: Request, now=None) -> Request:
        """Validate and queue a request.  ``now`` (here and in ``step``/
        ``run``) may be a timestamp or a zero-arg clock callable — the
        callable is read at the bookkeeping moment, so finish times stamp
        after the decode chunk that produced the final token.  Malformed
        requests are rejected HERE with a clear ``ValueError`` — not as a
        shape error (or a silent hang) deep inside admission."""
        L = len(request.prompt)
        max_len = self.engine.scfg.max_len
        if request.max_new_tokens < 0:
            raise ValueError(
                f"max_new_tokens must be >= 0, got {request.max_new_tokens}")
        if L > max_len:
            raise ValueError(
                f"prompt length ({L}) exceeds max_len ({max_len})")
        if L + request.max_new_tokens > max_len:
            raise ValueError(
                f"prompt ({L}) + max_new_tokens ({request.max_new_tokens}) "
                f"exceeds max_len ({max_len})")
        if request.deadline is not None and (
                not isinstance(request.deadline, (int, float))
                or not math.isfinite(request.deadline)):
            raise ValueError(
                f"deadline must be a finite logical time, got "
                f"{request.deadline!r}")
        if not isinstance(request.priority, (int, float)) or \
                not math.isfinite(request.priority):
            raise ValueError(
                f"priority must be finite, got {request.priority!r}")
        request.arrival_time = now() if callable(now) else now
        request.status = RequestStatus.QUEUED
        self._submit_count += 1
        request._seq = self._submit_count
        if self.snapshot_interval:
            self._submit_log.append(request)
        self.queue.append(request)
        return request

    def _sampling_for(self, req: Request):
        scfg = self.engine.scfg
        temp = scfg.temperature if req.temperature is None else req.temperature
        top_k = scfg.top_k if req.top_k is None else req.top_k
        top_p = scfg.top_p if req.top_p is None else req.top_p
        return float(temp), int(top_k), float(top_p)

    def _reset_slot_sampling(self, slot: int) -> None:
        """Freed slots fall back to the engine defaults so a past sampling
        request doesn't keep the greedy decode fast path disabled."""
        scfg = self.engine.scfg
        self._eos_h[slot] = -1
        (self._temp_h[slot], self._topk_h[slot],
         self._topp_h[slot]) = (scfg.temperature, scfg.top_k, scfg.top_p)

    def _admit(self, now=None, only_ineligible: bool = False) -> int:
        """Monolithic admission: fill free slots from the queue head in ONE
        fused dispatch (batched exact-length prefill + masked stitch +
        first-token sampling + slot-state merge); returns #admissions.
        Prompt state that cannot be built a token at a time is never
        pad-invariant either (recurrent integration, MoE capacity), so the
        dispatch takes only the leading run of EQUAL-length requests and
        prefills unpadded — a prefill retrace per distinct length, zero
        padding.  With ``only_ineligible`` (chunk-capable engines) the run
        additionally stops at the first chunk-eligible request, which
        admits through the chunk lane instead.

        Paged engines gate admission on free pool pages — candidates that
        don't fit go back to the queue head in FIFO order (no skip-ahead,
        so ordering stays deterministic).  An injected dispatch failure
        rolls the admission back locally (pages released, candidates
        requeued in order) and re-raises for the retry path."""
        free = [s for s in range(self.n_slots) if self.slots[s] is None]
        take: List[Request] = []
        for r in self.queue:
            if len(take) >= len(free):
                break
            if only_ineligible and self.engine.chunk_eligible(
                    len(self._seq(r))):
                break
            if take and len(self._seq(r)) != len(self._seq(take[0])):
                break
            take.append(r)
        for _ in take:
            self.queue.popleft()
        admitted = list(zip(free, take))
        if self.engine.paged and admitted:
            fits = []
            for i, (slot, req) in enumerate(admitted):
                if self.engine.pool.admit(slot, self._seq(req)) is None:
                    if (not fits
                            and not any(r is not None for r in self.slots)
                            and self.engine.pool.allocated_pages == 0):
                        raise RuntimeError(
                            "request needs more KV pages than the whole "
                            "pool holds — raise ServeConfig.num_pages")
                    for _, r in reversed(admitted[i:]):
                        self.queue.appendleft(r)
                    admitted = fits
                    break
                fits.append((slot, req))
        if not admitted:
            return 0
        R = self.n_slots
        # exact length: every admitted request is L0 tokens (equal-length
        # run), and submit() guarantees L0 <= max_len
        P = len(self._seq(admitted[0][1]))
        prompts = np.zeros((R, P), np.int32)
        lengths = np.ones((R,), np.int32)
        mask = np.zeros((R,), bool)
        budget_one = np.zeros((R,), bool)
        for slot, req in admitted:
            seq = self._seq(req)
            L = len(seq)
            prompts[slot, :L] = seq
            lengths[slot] = L
            mask[slot] = True
            # <=1: budget-0 requests also finish at admission (their slot is
            # never occupied; the sampled token is simply not emitted).
            # ``remaining`` (not max_new_tokens) so preemption resumes with
            # a partially spent budget admit correctly.
            budget_one[slot] = req.remaining <= 1
            (self._temp_h[slot], self._topk_h[slot],
             self._topp_h[slot]) = self._sampling_for(req)
            self._eos_h[slot] = -1 if req.eos_id is None else int(req.eos_id)
        self._push_sampling_state()
        try:
            (self.cache, self.tok, self.pos, self.done, tok0, done0,
             ok0) = self.engine.admit_monolithic(
                self.cache, prompts, lengths, mask, budget_one, self.eos,
                self.temperature, self.top_k, self.top_p, self.tok, self.pos,
                self.done, self._step)
        except InjectedFault:
            # the dispatch never ran: release this admission's pages, put
            # the candidates back at the queue head in FIFO order, and let
            # the retry path re-dispatch an identical round
            for slot, _ in admitted:
                if self.engine.paged:
                    self.engine.pool.release(slot)
                self._reset_slot_sampling(slot)
            self._push_sampling_state()
            for _, req in reversed(admitted):
                self.queue.appendleft(req)
            raise
        self._step += 1
        self.stats["admission_rounds"] += 1
        self.stats["prefill_tokens"] += R * P
        self.stats["admitted_tokens"] += int(
            sum(lengths[s] for s, _ in admitted))
        if self.engine.scfg.guards:
            ok0_h = np.asarray(ok0)
            bad = [s for s, _ in admitted if not ok0_h[s]]
            if bad:
                raise CacheCorruption(
                    f"non-finite logits at admission for slots {bad}")
        tok0_h, done0_h = np.asarray(tok0), np.asarray(done0)
        if callable(now):
            now = now()
        for slot, req in admitted:
            req.status = RequestStatus.RUNNING
            req.slot = slot
            self._admit_counter += 1
            self._admit_seq[slot] = self._admit_counter
            L = int(lengths[slot])
            self._progress[slot] = self._target[slot] = L
            cb_ok = True
            if req.remaining >= 1:
                cb_ok = self._deliver(req, int(tok0_h[slot]))
            if not cb_ok:
                # a raising streaming callback fails only ITS request; the
                # rest of the admission round stands
                self._retire(req, "failed", now)
                self.stats["failed"] += 1
                self._free_on_device([slot])
            elif done0_h[slot]:
                eos = self._eos_h[slot]
                req.finish("eos" if eos >= 0 and req.tokens
                           and req.tokens[-1] == eos
                           else "length", now)
                self.finished.append(req)
                self._reset_slot_sampling(slot)
                self._progress[slot] = self._target[slot] = 0
                if self.engine.paged:
                    self.engine.pool.release(slot)
            else:
                self.slots[slot] = req
        return len(admitted)

    def _push_sampling_state(self) -> None:
        place = self.engine.place_slot_state
        self.eos = place(jnp.asarray(self._eos_h, jnp.int32))
        self.temperature = place(jnp.asarray(self._temp_h, jnp.float32))
        self.top_k = place(jnp.asarray(self._topk_h, jnp.int32))
        self.top_p = place(jnp.asarray(self._topp_h, jnp.float32))

    # -- deadlines & load shedding (logical time only) ------------------------

    def _retire(self, req: Request, reason: str, now_v) -> None:
        """Terminal bookkeeping shared by expiry/shed/failure paths."""
        slot = req.slot
        req.finish(reason, now_v)
        self.finished.append(req)
        if slot is not None:
            self.slots[slot] = None
            self._reset_slot_sampling(slot)
            self._progress[slot] = self._target[slot] = 0
            if self.engine.paged:
                self.engine.pool.release(slot)

    def _expire_deadlines(self, now_v) -> None:
        """Finish every request whose logical deadline passed — queued ones
        without running, mid-decode ones with their partial transcript —
        with status ``timed_out``.  No-op when the caller runs clockless."""
        if now_v is None:
            return
        expired = [r for r in self.queue
                   if r.deadline is not None and r.deadline <= now_v]
        if expired:
            gone = set(map(id, expired))
            self.queue = collections.deque(
                r for r in self.queue if id(r) not in gone)
        freed = []
        for s, r in enumerate(self.slots):
            if r is not None and r.deadline is not None \
                    and r.deadline <= now_v:
                expired.append(r)
                freed.append(s)
        for r in expired:
            self._retire(r, "timed_out", now_v)
            self.stats["timed_out"] += 1
        if freed:
            self._free_on_device(freed)

    def _shed_overload(self, now_v) -> None:
        """Deterministic admission control: when the page pool (or, dense,
        the slot map) saturates past ``shed_watermark`` and more than
        ``overload_queue`` requests wait, shed the excess — lowest priority
        first, then least deadline slack (it was going to miss anyway),
        then latest submitted.  Same state + same watermark => same shed
        set, replayable bit-for-bit."""
        if self.shed_watermark is None or not self.queue:
            return
        if self.engine.paged:
            saturation = self.engine.pool.saturation
        else:
            saturation = sum(r is not None for r in self.slots) / self.n_slots
        if saturation < self.shed_watermark:
            return
        excess = len(self.queue) - self.overload_queue
        if excess <= 0:
            return
        order = sorted(self.queue,
                       key=lambda r: (r.priority, r.slack(now_v),
                                      -getattr(r, "_seq", 0)))
        victims = set(map(id, order[:excess]))
        self.queue = collections.deque(
            r for r in self.queue if id(r) not in victims)
        for r in order[:excess]:
            self._retire(r, "shed", now_v)
            self.stats["shed"] += 1

    # -- snapshot / restore / crash recovery ----------------------------------

    def snapshot(self) -> dict:
        """Host-side copy of the COMPLETE serving state: decode caches,
        slot vectors, sampling mirrors, PRNG step, queue/slot request
        states, page-pool allocator, telemetry.  Everything a restore needs
        to replay token-identically; per-request ``retries`` deliberately
        stays OUT (it must survive restores, or the retry bound would reset
        with every recovery)."""
        reqs = [r for r in self.queue] + \
               [r for r in self.slots if r is not None]
        return {
            "cache": ckpt_lib.tree_to_host(self.cache),
            "tok": np.asarray(self.tok), "pos": np.asarray(self.pos),
            "done": np.asarray(self.done),
            "eos_h": list(self._eos_h), "temp_h": list(self._temp_h),
            "topk_h": list(self._topk_h), "topp_h": list(self._topp_h),
            "step": self._step,
            "admit_seq": list(self._admit_seq),
            "admit_counter": self._admit_counter,
            "progress": list(self._progress),
            "target": list(self._target),
            "queue": list(self.queue),
            "slots": list(self.slots),
            "finished_len": len(self.finished),
            "req_state": [(r, r.status, list(r.tokens), r.finish_reason,
                           r.finish_time, r.slot) for r in reqs],
            "pool": (self.engine.pool.state_dict()
                     if self.engine.paged else None),
            "stats": dict(self.stats),
        }

    def restore(self, snap: dict) -> None:
        """Reinstate a :meth:`snapshot` — device state re-placed through the
        engine (sharded placements pinned, so executors never retrace),
        request objects mutated back in place, allocator reloaded.
        Requests submitted AFTER the snapshot rejoin the queue tail in
        submit order, so recovery never drops a submission."""
        eng = self.engine
        self.cache = eng.place_cache(snap["cache"])
        self.tok = eng.place_slot_state(jnp.asarray(snap["tok"]))
        self.pos = eng.place_slot_state(jnp.asarray(snap["pos"]))
        self.done = eng.place_slot_state(jnp.asarray(snap["done"]))
        self._eos_h = list(snap["eos_h"])
        self._temp_h = list(snap["temp_h"])
        self._topk_h = list(snap["topk_h"])
        self._topp_h = list(snap["topp_h"])
        self._push_sampling_state()
        self._step = snap["step"]
        self._admit_seq = list(snap["admit_seq"])
        self._admit_counter = snap["admit_counter"]
        self._progress = list(snap["progress"])
        self._target = list(snap["target"])
        self.queue = collections.deque(snap["queue"])
        self.slots = list(snap["slots"])
        del self.finished[snap["finished_len"]:]
        for r, status, toks, reason, ftime, slot in snap["req_state"]:
            r.status = status
            r.tokens = list(toks)
            r.finish_reason = reason
            r.finish_time = ftime
            r.slot = slot
        if snap["pool"] is not None:
            eng.pool.load_state(snap["pool"])
        self.stats = dict(snap["stats"])
        for k in ("spec_rounds", "spec_drafted", "spec_accepted"):
            self.stats.setdefault(k, 0)
        for r in self._submit_log:       # post-snapshot submissions survive
            r.status = RequestStatus.QUEUED
            r.tokens = []
            r.finish_reason = None
            r.finish_time = None
            r.slot = None
            self.queue.append(r)

    def _recover(self, err: EngineFault, now_v) -> None:
        """Bounded-retry fault recovery.  Dispatch failures already rolled
        back locally — count and re-dispatch next round.  Corruption
        restores the rolling snapshot, charges one retry to every
        in-flight request, and drops (status ``failed``) any that crossed
        ``max_retries`` — deterministic, since the charge set and the
        restore are both functions of the replayed state."""
        self._retries_since_progress += 1
        if self._retries_since_progress > self.max_retries:
            raise err
        if isinstance(err, InjectedFault):
            self.stats["recoveries"] += 1
            self.stats["dispatch_retries"] += 1
            return
        if self._snap is None:
            raise RuntimeError(
                "corrupted serving state detected but snapshots are "
                "disabled — construct Scheduler(snapshot_interval=1) to "
                "enable recovery") from err
        affected = [r for r in self.slots if r is not None]
        self.restore(self._snap)     # also rewinds stats to the snapshot
        self.stats["recoveries"] += 1
        for r in affected:
            r.retries += 1
            if r.retries > self.max_retries:
                # Request is a value-eq dataclass: filter by IDENTITY
                if any(q is r for q in self.queue):
                    self.queue = collections.deque(
                        q for q in self.queue if q is not r)
                if r.slot is not None and self.slots[r.slot] is r:
                    self._free_on_device([r.slot])
                self._retire(r, "failed", now_v)
                self.stats["failed"] += 1

    def save(self, ckpt_dir: str, step: Optional[int] = None):
        """Write the whole serving state as a committed ``ckpt.checkpoint``
        (atomic dir, msgpack+zstd arrays, JSON manifest): the crash-
        recovery path.  Streaming callbacks (``on_token``) are process-
        local and are NOT serialized — a restored request streams only
        from its restore point on."""
        tree = {"cache": self.cache, "tok": self.tok, "pos": self.pos,
                "done": self.done}
        recs = {
            "queue": [_req_record(r) for r in self.queue],
            "slots": [None if r is None else _req_record(r)
                      for r in self.slots],
            "finished": [_req_record(r) for r in self.finished],
        }
        extra = {"serving": {
            "step": self._step, "ticks": self._ticks,
            "eos_h": self._eos_h, "temp_h": self._temp_h,
            "topk_h": self._topk_h, "topp_h": self._topp_h,
            "admit_seq": self._admit_seq,
            "admit_counter": self._admit_counter,
            "progress": self._progress,
            "target": self._target,
            "submit_count": self._submit_count,
            "stats": self.stats,
            "pool": (self.engine.pool.state_dict()
                     if self.engine.paged else None),
            "geometry": {"slots": self.n_slots, "chunk": self.chunk,
                         "max_len": self.engine.scfg.max_len,
                         "paged": self.engine.paged,
                         "prefill_chunk": self.engine.prefill_chunk},
            **recs,
        }}
        return ckpt_lib.save(ckpt_dir, self._ticks if step is None
                               else step, tree, extra=extra)

    def load(self, ckpt_dir: str, step: Optional[int] = None) -> None:
        """Restore :meth:`save` state into this (freshly constructed)
        scheduler — same engine config / slot count / chunk.  Requests are
        rebuilt as new ``Request`` objects (find them in ``queue`` /
        ``slots`` / ``finished``); decode then continues token-identically
        to the uninterrupted run."""
        tree = {"cache": self.cache, "tok": self.tok, "pos": self.pos,
                "done": self.done}
        restored, extra = ckpt_lib.restore(
            ckpt_dir, tree, step=step,
            shardings=self.engine.serving_state_shardings())
        s = extra["serving"]
        geo = s["geometry"]
        if (geo["slots"], geo["chunk"], geo["max_len"], geo["paged"],
                geo.get("prefill_chunk", self.engine.prefill_chunk)) != \
                (self.n_slots, self.chunk, self.engine.scfg.max_len,
                 self.engine.paged, self.engine.prefill_chunk):
            raise ValueError(
                f"serving-checkpoint geometry {geo} does not match this "
                "scheduler/engine")
        self.cache = self.engine.place_cache(restored["cache"])
        self.tok = self.engine.place_slot_state(restored["tok"])
        self.pos = self.engine.place_slot_state(restored["pos"])
        self.done = self.engine.place_slot_state(restored["done"])
        self._eos_h = list(s["eos_h"])
        self._temp_h = list(s["temp_h"])
        self._topk_h = list(s["topk_h"])
        self._topp_h = list(s["topp_h"])
        self._push_sampling_state()
        self._step = s["step"]
        self._ticks = s["ticks"]
        self._admit_seq = list(s["admit_seq"])
        self._admit_counter = s["admit_counter"]
        self._progress = list(s.get("progress", [0] * self.n_slots))
        self._target = list(s.get("target", [0] * self.n_slots))
        self._submit_count = s["submit_count"]
        self.stats = dict(s["stats"])
        for k in ("spec_rounds", "spec_drafted", "spec_accepted"):
            self.stats.setdefault(k, 0)
        if s["pool"] is not None:
            self.engine.pool.load_state(s["pool"])
        self.queue = collections.deque(
            _req_from_record(d) for d in s["queue"])
        self.slots = [None if d is None else _req_from_record(d)
                      for d in s["slots"]]
        self.finished = [_req_from_record(d) for d in s["finished"]]

    # -- the scheduling loop -------------------------------------------------

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slots)

    @property
    def padding_waste(self) -> float:
        """prefill_tokens / admitted_tokens across all rounds with prefill
        work — chunk-lane iterations spent per useful prompt token (1.0 =
        every iteration carried a real token; under backlog the fixed
        [prefill_chunk] lane fills completely, so this sits at ~1.0).
        Monolithic fallback rounds count their full [slots, L] dispatch
        against the real prompt tokens admitted."""
        a = self.stats["admitted_tokens"]
        return self.stats["prefill_tokens"] / a if a else 0.0

    @property
    def mean_occupancy(self) -> float:
        """Mean fraction of slots holding live requests per decode round."""
        n = self.stats["rounds"]
        return self.stats["occupancy_sum"] / n if n else 0.0

    def step(self, now=None) -> int:
        """One scheduling round: expire deadlines, shed overload, (maybe)
        snapshot, admit into free slots, decode one chunk, retire finished
        sequences.  Returns the number of useful tokens emitted this round
        (0 on a recovered fault — the retry replays next round)."""
        now_v = now() if callable(now) else now
        self._expire_deadlines(now_v)
        self._shed_overload(now_v)
        if self.snapshot_interval and \
                self._ticks % self.snapshot_interval == 0:
            self._snap = self.snapshot()
            self._submit_log.clear()
        self._ticks += 1
        try:
            emitted = self._step_inner(now, now_v)
        except EngineFault as err:
            self._recover(err, now_v)
            return 0
        self._retries_since_progress = 0
        return emitted

    def _assemble_chunk(self, allow_admission: bool):
        """Build this round's chunk-lane entries: continue mid-prefill slots
        in admission order, then admit from the queue head (no skip-ahead)
        while budget, free slots, and pool pages last.  New admissions are
        committed host-side here (slot assigned, pool mapped, sampling
        mirrors set) and recorded in ``fresh`` so an injected dispatch
        failure can roll them back; ``plan`` (slot -> new progress) is only
        applied after the dispatch commits.

        Returns (entries | None, plan, fresh, completing) — entries is the
        [prefill_chunk] arrays dict ``Engine.step`` consumes (None when the
        round has no prefill work), completing the slots whose last prompt
        token lands this round (their first output token is in tok0)."""
        C = self.engine.prefill_chunk
        e_slot: List[int] = []
        e_tok: List[int] = []
        e_pos: List[int] = []
        e_first: List[bool] = []
        e_b1: List[bool] = []
        plan: dict = {}
        fresh: List[tuple] = []
        completing: set = set()
        parks: dict = {}

        def feed(slot, req, p0):
            seq, L = self._seq(req), self._target[slot]
            take = min(C - len(e_slot), L - p0)
            for p in range(p0, p0 + take):
                last = p == L - 1
                e_slot.append(slot)
                e_tok.append(int(seq[p]))
                e_pos.append(p)
                e_first.append(last)
                e_b1.append(last and req.remaining <= 1)
                if last:
                    completing.add(slot)

            plan[slot] = p0 + take

        for slot in sorted(
                (s for s in range(self.n_slots)
                 if self.slots[s] is not None
                 and self._progress[s] < self._target[s]),
                key=lambda s: self._admit_seq[s]):
            if len(e_slot) >= C:
                break
            feed(slot, self.slots[slot], self._progress[slot])
        while allow_admission and len(e_slot) < C and self.queue:
            req = self.queue[0]
            seq = self._seq(req)
            L = len(seq)
            if not self.engine.chunk_eligible(L):
                break               # head takes the monolithic fallback
            slot = next((s for s in range(self.n_slots)
                         if self.slots[s] is None), None)
            if slot is None:
                break
            p0 = 0
            if self.engine.paged:
                # SWA admissions are isolated (share=False): they replay
                # the window from position 0, and their chunk-lane page
                # bits must never mix with a monolithic sharer's
                share = self.engine.chunk_window_limit is None
                start = self.engine.pool.admit(slot, seq, fills_now=False,
                                               share=share)
                if start is None:
                    if (not any(r is not None for r in self.slots)
                            and self.engine.pool.allocated_pages == 0):
                        raise RuntimeError(
                            "request needs more KV pages than the whole "
                            "pool holds — raise ServeConfig.num_pages")
                    break
                # a fully-shared prompt still replays its last token: the
                # completion entry's logits are the first-token logits
                p0 = min(start, L - 1)
                if (L - p0 <= C - len(e_slot)
                        and not self.engine.pool.ensure(
                            slot, min(L + self.chunk,
                                      self.engine.scfg.max_len))):
                    # completes this round but decode growth doesn't fit:
                    # undo the mapping and wait (no skip-ahead)
                    self.engine.pool.release(slot)
                    break
            self.queue.popleft()
            req.status = RequestStatus.RUNNING
            req.slot = slot
            self.slots[slot] = req
            self._target[slot] = L
            self._progress[slot] = p0
            (self._temp_h[slot], self._topk_h[slot],
             self._topp_h[slot]) = self._sampling_for(req)
            self._eos_h[slot] = -1 if req.eos_id is None else int(req.eos_id)
            fresh.append((slot, req))
            parks[slot] = (int(seq[p0]), p0)
            feed(slot, req, p0)
        if not e_slot:
            return None, plan, fresh, completing, parks
        if fresh:
            self._push_sampling_state()
        pad = C - len(e_slot)
        entries = {"slot": e_slot + [-1] * pad,
                   "tok": e_tok + [0] * pad,
                   "pos": e_pos + [0] * pad,
                   "first": e_first + [False] * pad,
                   "budget_one": e_b1 + [False] * pad}
        return entries, plan, fresh, completing, parks

    def _step_inner(self, now, now_v) -> int:
        entries, plan, fresh, completing = None, {}, [], set()
        parks: dict = {}
        if self.engine.requires_monolithic_admission:
            self._admit(now)
            if not any(r is not None for r in self.slots):
                return 0
            if self.engine.paged:
                # block accounting: map pages for the chunk ahead; preempts
                # most-slack/youngest-first when the pool is exhausted
                self._ensure_chunk_pages(now_v)
        else:
            allow = True
            if self.queue and not self.engine.chunk_eligible(
                    len(self._seq(self.queue[0]))):
                # the head needs the monolithic fallback (SWA prompt past
                # the window): admit its equal-length run first; chunk
                # admissions follow only if the new head is eligible
                # (FIFO — no skip-ahead past a blocked head)
                self._admit(now, only_ineligible=True)
                allow = (not self.queue or self.engine.chunk_eligible(
                    len(self._seq(self.queue[0]))))
            if self.engine.paged:
                self._ensure_chunk_pages(now_v)
            entries, plan, fresh, completing, parks = \
                self._assemble_chunk(allow)
        if not any(r is not None for r in self.slots):
            return 0
        C = self.engine.prefill_chunk if entries is not None else 0
        if parks:
            # freshly admitted rows must park at their first entry BEFORE
            # the dispatch: chunk iterations preceding the row's first
            # target iteration re-run its held (tok, pos), and the free-slot
            # sentinel pos=-1 would clamp the paged KV write onto page 0 of
            # the row's table — a SHARED page under prefix reuse.  Parking
            # at (seq[p0], p0) makes every such pre-write the same bits the
            # entry itself writes.
            tok_h, pos_h = np.asarray(self.tok).copy(), \
                np.asarray(self.pos).copy()
            for s, (t, p) in parks.items():
                tok_h[s], pos_h[s] = t, p
            place = self.engine.place_slot_state
            self.tok = place(jnp.asarray(tok_h))
            self.pos = place(jnp.asarray(pos_h))
        # host mirrors let us pick the argmax-only decode variant statically
        greedy = all(t <= 0.0 and k == 0 and p >= 1.0 for t, k, p in
                     zip(self._temp_h, self._topk_h, self._topp_h))
        scfg = self.engine.scfg
        use_spec = scfg.spec_decode
        if use_spec:
            # a speculative block writes draft_k+1 positions from every
            # occupied row's post-chunk-lane held position; fall back to a
            # plain round whenever any row sits too close to max_len for
            # the block to land unclamped (the decision is a pure function
            # of host state, so fault replays re-derive it identically)
            lim = scfg.max_len - (scfg.draft_k + 1)
            for slot, req in enumerate(self.slots):
                if req is None:
                    continue
                p = plan.get(slot, self._progress[slot])
                if p < self._target[slot]:
                    held = p - 1                  # parks on its latest entry
                elif slot in completing:
                    held = self._target[slot]     # becomes a decoder at L
                else:
                    held = len(req.prompt) + len(req.tokens) - 1
                if held > lim:
                    use_spec = False
                    break
        try:
            (self.cache, self.tok, self.pos, self.done, tok0, done0, toks,
             dones, ok, n_valid) = self.engine.step(
                self.cache, entries, self.tok, self.pos, self.done, self.eos,
                self.temperature, self.top_k, self.top_p, self._step,
                self.chunk, greedy=greedy, spec=use_spec)
        except InjectedFault:
            # the dispatch never ran: roll back this round's fresh chunk
            # admissions (pages released, candidates back at the queue head
            # in FIFO order) and re-raise for the retry path
            for slot, req in reversed(fresh):
                if self.engine.paged:
                    self.engine.pool.release(slot)
                self.slots[slot] = None
                self._reset_slot_sampling(slot)
                self._progress[slot] = self._target[slot] = 0
                req.status = RequestStatus.QUEUED
                req.slot = None
                self.queue.appendleft(req)
            if fresh:
                self._push_sampling_state()
                # restore the free-slot sentinel the parks overwrote
                self._free_on_device([slot for slot, _ in fresh])
            raise
        # spec rounds burn draft_k draft + draft_k+1 verify sampling streams
        self._step += C + (2 * scfg.draft_k + 1 if use_spec else self.chunk)
        if self.engine.scfg.guards:
            ok_h = np.asarray(ok)
            if not ok_h.all():
                # poisoned logits never reach a streaming callback:
                # detection precedes every emit below
                raise CacheCorruption(
                    "non-finite logits in decode for slots "
                    f"{np.flatnonzero(~ok_h).tolist()}")
        # commit the chunk lane: progress applied, freshly covered pages
        # become prefix-shareable, admission bookkeeping recorded
        for slot, p in plan.items():
            self._progress[slot] = p
            if self.engine.paged:
                self.engine.pool.mark_filled(slot, p)
        for slot, req in fresh:
            self._admit_counter += 1
            self._admit_seq[slot] = self._admit_counter
        if entries is not None:
            self.stats["admission_rounds"] += 1
            self.stats["prefill_tokens"] += C
            self.stats["admitted_tokens"] += sum(
                1 for s in entries["slot"] if s >= 0)
        self.stats["rounds"] += 1
        self.stats["occupancy_sum"] += (
            sum(r is not None for r in self.slots) / self.n_slots)
        toks_h, dones_h = np.asarray(toks), np.asarray(dones)
        tok0_h, done0_h = np.asarray(tok0), np.asarray(done0)
        nv_h = np.asarray(n_valid)
        if use_spec:
            # accept-rate telemetry: every live decode row drafted draft_k
            # tokens and committed n_valid-1 of them (the last committed
            # token is the verifier's own bonus/correction sample)
            self.stats["spec_rounds"] += 1
            self.stats["spec_drafted"] += int((nv_h > 0).sum()) * \
                scfg.draft_k
            self.stats["spec_accepted"] += int(
                np.maximum(nv_h - 1, 0).sum())
        if callable(now):      # stamp finish times after the round completed
            now = now()
        emitted, freed = 0, []
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            if self._progress[slot] < self._target[slot]:
                continue            # still mid-prefill: nothing to emit yet
            cb_ok = True
            if slot in completing:
                # the slot's last prompt token landed this round: its first
                # output token was sampled in the same dispatch
                if req.remaining >= 1:
                    cb_ok = self._deliver(req, int(tok0_h[slot]))
                    emitted += 1 if cb_ok else 0
                if cb_ok and done0_h[slot]:
                    eos = self._eos_h[slot]
                    req.finish("eos" if eos >= 0 and req.tokens
                               and req.tokens[-1] == eos else "length", now)
            if cb_ok and not req.done:
                # only the first n_valid columns of the row are real (all
                # of them on a plain round; the accepted prefix + bonus
                # token on a speculative one)
                for j in range(int(nv_h[slot])):
                    cb_ok = self._deliver(req, int(toks_h[slot, j]))
                    if not cb_ok:
                        break
                    emitted += 1
                    if dones_h[slot, j]:
                        req.finish("eos", now)
                        break
                    if req.remaining <= 0:
                        req.finish("length", now)
                        break
            if not cb_ok:
                # a raising streaming callback fails only ITS request —
                # every other slot's tokens this round still commit
                req.finish("failed", now)
                self.stats["failed"] += 1
            if req.done:
                self.finished.append(req)
                self.slots[slot] = None
                self._reset_slot_sampling(slot)
                self._progress[slot] = self._target[slot] = 0
                if self.engine.paged:
                    self.engine.pool.release(slot)
                freed.append(slot)
        if use_spec and self.engine.paged:
            # paged-KV rollback of rejected speculation: drop page mappings
            # grown for the draft_k+1 block past the accepted sequence (the
            # pending token's slot stays resident)
            for slot, req in enumerate(self.slots):
                if req is None or self._progress[slot] < self._target[slot]:
                    continue
                self.engine.pool.trim(
                    slot, len(req.prompt) + len(req.tokens))
        if freed:
            self._free_on_device(freed)
        self.stats["emitted_tokens"] += emitted
        return emitted

    @staticmethod
    def _deliver(req: Request, token: int) -> bool:
        """Emit one token; False when the streaming callback raised (the
        token itself is already on the transcript — at-least-once delivery
        ends at the callback boundary)."""
        try:
            req.emit(token)
            return True
        except Exception:
            return False

    def check_drained(self) -> None:
        """Leak telemetry at drain: with no work left, the page pool must
        hold ZERO allocated pages outside the reserved null pages, and no
        page may be referenced without a slot mapping reaching it."""
        if self.has_work or not self.engine.paged:
            return
        pool = self.engine.pool
        leaked = pool.leaked_pages()
        assert pool.allocated_pages == 0 and not leaked, (
            f"page leak at drain: {pool.allocated_pages} pages still "
            f"allocated, unreachable={leaked}")

    def run(self, requests: Sequence[Request] = (), now=None,
            max_rounds: int = 100_000) -> List[Request]:
        """Submit ``requests`` and drive rounds until everything finishes."""
        for r in requests:
            self.submit(r, now)
        rounds = 0
        while self.has_work:
            self.step(now)
            rounds += 1
            if rounds > max_rounds:
                raise RuntimeError("scheduler failed to drain "
                                   f"({len(self.queue)} queued)")
        if self.engine.scfg.guards:
            self.check_drained()
        return self.finished


def _req_record(r: Request) -> dict:
    """JSON-able snapshot of one request (``on_token`` dropped)."""
    return {"prompt": [int(t) for t in r.prompt],
            "max_new_tokens": r.max_new_tokens,
            "eos_id": r.eos_id, "temperature": r.temperature,
            "top_k": r.top_k, "top_p": r.top_p,
            "deadline": r.deadline, "priority": r.priority,
            "status": r.status.value, "tokens": list(r.tokens),
            "finish_reason": r.finish_reason, "slot": r.slot,
            "arrival_time": r.arrival_time, "finish_time": r.finish_time,
            "retries": r.retries, "seq": getattr(r, "_seq", 0)}


def _req_from_record(d: dict) -> Request:
    r = Request(prompt=d["prompt"], max_new_tokens=d["max_new_tokens"],
                eos_id=d["eos_id"], temperature=d["temperature"],
                top_k=d["top_k"], top_p=d["top_p"],
                deadline=d["deadline"], priority=d["priority"])
    r.status = RequestStatus(d["status"])
    r.tokens = list(d["tokens"])
    r.finish_reason = d["finish_reason"]
    r.slot = d["slot"]
    r.arrival_time = d["arrival_time"]
    r.finish_time = d["finish_time"]
    r.retries = d["retries"]
    r._seq = d["seq"]
    return r
