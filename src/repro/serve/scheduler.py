"""Continuous-batching scheduler: slot-based request engine.

A fixed pool of ``slots`` decode lanes over one set of live cache buffers
(static shapes, allocated once).  Requests queue FIFO; whenever slots are
free the queue head is admitted in ONE batched prefill dispatch (prompts
padded right to a shared bucket, dummy rows for slots that stay empty), the
fresh caches are stitched into their slots with one masked write, and decode
resumes — sequences at different depths advance together through
per-sequence positions.  Decode runs in ``chunk``-token scan dispatches;
between chunks the scheduler drains emitted tokens, retires finished
sequences (EOS or budget), frees their slots, and admits from the queue.
Batch slots are never idle while work is queued — the request-level
analogue of keeping the LUT fabric saturated.

Static-shape invariants (TPU-friendly, no retrace after warmup):
  * live caches are ``[G, slots, max_len, ...]`` — admission writes slot
    rows via ``Engine.admit_batch`` (traced per-slot lengths + admit mask);
  * admission prefills a fixed ``[slots, bucket]`` batch, so prefill and
    stitch compile once per prompt bucket, not per prompt length or per
    number of admitted requests;
  * the chunked decode compiles exactly once — slot state (token, position,
    done, EOS id, sampling params) are all traced ``[slots]`` vectors; free
    slots carry the negative-position sentinel, which keeps every one of
    their keys masked.

With a paged engine (``ServeConfig(paged=True)``) the scheduler also runs
the block accounting: admission is gated on free pool pages (FIFO, no
skip-ahead), every decode round first maps pages for the chunk ahead, and
when the pool runs dry the *youngest* slot is deterministically preempted
and requeued at the queue head with its emitted tokens intact — its
re-admission prefills prompt + emitted and continues bit-exactly, so
temperature-0 transcripts match an uncontended run.  Page tables are fixed
``[slots, entries]`` int32 arrays whose VALUES change round to round, so
none of the executors above ever retrace.
"""
from __future__ import annotations

import collections
from typing import Deque, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.serve.engine import Engine
from repro.serve.request import Request, RequestStatus


def _bucket_len(L: int, mode) -> int:
    """Pad target for a length-L prompt: "exact", "pow2", or a fixed multiple."""
    if mode == "exact":
        return L
    if mode == "pow2":
        P = 8
        while P < L:
            P *= 2
        return P
    return -(-L // int(mode)) * int(mode)


class Scheduler:
    """FIFO admission over a fixed slot map; ``Engine`` executes the batch."""

    def __init__(self, engine: Engine, slots: int = 4, chunk: int = 8,
                 prompt_bucket="pow2"):
        if engine.is_encdec:
            raise NotImplementedError(
                "continuous batching serves decoder-only LMs")
        self.engine = engine
        self.n_slots = slots
        self.chunk = chunk
        # recurrent (SSM/RWKV) states are not pad-invariant: the recurrence
        # integrates pad-token embeddings, so those models prefill at exact
        # prompt length and admission groups equal-length requests (trades a
        # prefill retrace per distinct length for correctness)
        if engine.has_recurrent_state:
            prompt_bucket = "exact"
        self.prompt_bucket = prompt_bucket
        scfg = engine.scfg
        self.cache = engine.init_cache(slots)
        # per-slot device state ([slots] vectors; free slot: pos=-1, done);
        # placed by the engine (sharded: pinned along the data axis)
        self.tok = engine.place_slot_state(jnp.zeros((slots,), jnp.int32))
        self.pos = engine.place_slot_state(jnp.full((slots,), -1, jnp.int32))
        self.done = engine.place_slot_state(jnp.ones((slots,), bool))
        # per-slot sampling state is mirrored host-side so admission can
        # rebuild the vectors without device reads
        self._eos_h = [-1] * slots
        self._temp_h = [scfg.temperature] * slots
        self._topk_h = [scfg.top_k] * slots
        self._topp_h = [scfg.top_p] * slots
        self._push_sampling_state()
        self._step = 0                      # global token step (PRNG fold-in)
        self.queue: Deque[Request] = collections.deque()
        self.slots: List[Optional[Request]] = [None] * slots
        self.finished: List[Request] = []
        # paged block accounting: admission order per slot (preemption picks
        # the youngest), monotone admission counter
        self._admit_seq = [0] * slots
        self._admit_counter = 0
        # serving telemetry (the bench commits these): admission padding
        # waste = prefill_tokens / admitted_tokens (prefill always runs the
        # fixed [slots, bucket] shape), per-round slot occupancy as a
        # running sum (bounded state — a long-running server never grows it)
        self.stats = {"rounds": 0, "admission_rounds": 0,
                      "prefill_tokens": 0, "admitted_tokens": 0,
                      "emitted_tokens": 0, "occupancy_sum": 0.0,
                      "preemptions": 0}

    # -- paged helpers -------------------------------------------------------

    @staticmethod
    def _seq(req: Request) -> List[int]:
        """The token sequence a (re-)admission must prefill: the prompt plus
        everything already emitted (non-empty only on a preemption resume)."""
        return list(req.prompt) + [int(t) for t in req.tokens]

    def _free_on_device(self, freed: List[int]) -> None:
        """Mark freed slots done with the negative-position sentinel."""
        fm = np.zeros((self.n_slots,), bool)
        fm[freed] = True
        fm = self.engine.place_slot_state(jnp.asarray(fm))
        self.done = self.done | fm
        self.pos = jnp.where(fm, -1, self.pos)

    def _preempt_youngest(self) -> tuple[int, Request]:
        """Deterministic preemption: evict the most recently admitted slot,
        release its pages, and hand the request back (its emitted tokens are
        kept — re-admission prefills prompt + emitted and continues, so
        temperature-0 transcripts match an uncontended run)."""
        victim = max((s for s, r in enumerate(self.slots) if r is not None),
                     key=lambda s: self._admit_seq[s])
        req = self.slots[victim]
        self.slots[victim] = None
        self.engine.pool.release(victim)
        self._reset_slot_sampling(victim)
        req.status = RequestStatus.QUEUED
        req.slot = None
        self.stats["preemptions"] += 1
        self.engine.pool.preemptions += 1
        return victim, req

    def _ensure_chunk_pages(self) -> None:
        """Grow every active slot's page mapping to cover the next decode
        chunk; when the pool runs dry, preempt-and-requeue youngest-first
        until the remaining slots fit (or one sequence alone exhausts the
        pool, which is a configuration error)."""
        pool = self.engine.pool
        max_len = self.engine.scfg.max_len
        freed, evicted = [], []
        while True:
            active = [(s, r) for s, r in enumerate(self.slots)
                      if r is not None]
            need = [(s, min(len(r.prompt) + len(r.tokens) + self.chunk - 1,
                            max_len)) for s, r in active]
            failed = next((s for s, n in need if not pool.ensure(s, n)),
                          None)
            if failed is None:
                break
            if len(active) == 1:
                raise RuntimeError(
                    "KV page pool exhausted by a single sequence — "
                    "raise ServeConfig.num_pages (or lower max_len)")
            slot, req = self._preempt_youngest()
            evicted.append(req)
            freed.append(slot)
        if evicted:
            # requeue so original FIFO order survives: we evicted
            # youngest-first, so appendleft in eviction order puts the
            # oldest evictee at the queue head
            for req in evicted:
                self.queue.appendleft(req)
            self._free_on_device(freed)

    # -- admission -----------------------------------------------------------

    def submit(self, request: Request, now=None) -> Request:
        """Queue a request.  ``now`` (here and in ``step``/``run``) may be a
        timestamp or a zero-arg clock callable — the callable is read at the
        bookkeeping moment, so finish times stamp after the decode chunk
        that produced the final token."""
        L = len(request.prompt)
        max_len = self.engine.scfg.max_len
        if L + request.max_new_tokens > max_len:
            raise ValueError(
                f"prompt ({L}) + max_new_tokens ({request.max_new_tokens}) "
                f"exceeds max_len ({max_len})")
        request.arrival_time = now() if callable(now) else now
        request.status = RequestStatus.QUEUED
        self.queue.append(request)
        return request

    def _sampling_for(self, req: Request):
        scfg = self.engine.scfg
        temp = scfg.temperature if req.temperature is None else req.temperature
        top_k = scfg.top_k if req.top_k is None else req.top_k
        top_p = scfg.top_p if req.top_p is None else req.top_p
        return float(temp), int(top_k), float(top_p)

    def _reset_slot_sampling(self, slot: int) -> None:
        """Freed slots fall back to the engine defaults so a past sampling
        request doesn't keep the greedy decode fast path disabled."""
        scfg = self.engine.scfg
        self._eos_h[slot] = -1
        (self._temp_h[slot], self._topk_h[slot],
         self._topp_h[slot]) = (scfg.temperature, scfg.top_k, scfg.top_p)

    def _admit(self, now=None) -> int:
        """Fill free slots from the queue head in ONE fused dispatch
        (batched prefill + masked stitch + first-token sampling + slot-state
        merge); returns #admissions.  Paged engines gate admission on free
        pool pages — candidates that don't fit go back to the queue head in
        FIFO order (no skip-ahead, so ordering stays deterministic)."""
        free = [s for s in range(self.n_slots) if self.slots[s] is None]
        take = [self.queue.popleft()
                for _ in range(min(len(free), len(self.queue)))]
        if self.engine.has_recurrent_state and take:
            # recurrent states must prefill unpadded: admit only the leading
            # run of equal-length requests, requeue the rest (FIFO order)
            L0 = len(self._seq(take[0]))
            for i, r in enumerate(take):
                if len(self._seq(r)) != L0:
                    for r2 in reversed(take[i:]):
                        self.queue.appendleft(r2)
                    take = take[:i]
                    break
        admitted = list(zip(free, take))
        if self.engine.paged and admitted:
            fits = []
            for i, (slot, req) in enumerate(admitted):
                if self.engine.pool.admit(slot, self._seq(req)) is None:
                    if (not fits
                            and not any(r is not None for r in self.slots)
                            and self.engine.pool.allocated_pages == 0):
                        raise RuntimeError(
                            "request needs more KV pages than the whole "
                            "pool holds — raise ServeConfig.num_pages")
                    for _, r in reversed(admitted[i:]):
                        self.queue.appendleft(r)
                    admitted = fits
                    break
                fits.append((slot, req))
        if not admitted:
            return 0
        R = self.n_slots
        # the bucket never exceeds max_len: submit() guarantees every prompt
        # fits, and the live buffers are max_len slots long
        P = min(max(_bucket_len(len(self._seq(r)), self.prompt_bucket)
                    for _, r in admitted), self.engine.scfg.max_len)
        prompts = np.zeros((R, P), np.int32)
        lengths = np.ones((R,), np.int32)
        mask = np.zeros((R,), bool)
        budget_one = np.zeros((R,), bool)
        for slot, req in admitted:
            seq = self._seq(req)
            L = len(seq)
            prompts[slot, :L] = seq
            lengths[slot] = L
            mask[slot] = True
            # <=1: budget-0 requests also finish at admission (their slot is
            # never occupied; the sampled token is simply not emitted).
            # ``remaining`` (not max_new_tokens) so preemption resumes with
            # a partially spent budget admit correctly.
            budget_one[slot] = req.remaining <= 1
            (self._temp_h[slot], self._topk_h[slot],
             self._topp_h[slot]) = self._sampling_for(req)
            self._eos_h[slot] = -1 if req.eos_id is None else int(req.eos_id)
        self._push_sampling_state()
        self.stats["admission_rounds"] += 1
        self.stats["prefill_tokens"] += R * P
        self.stats["admitted_tokens"] += int(
            sum(lengths[s] for s, _ in admitted))
        (self.cache, self.tok, self.pos, self.done, tok0,
         done0) = self.engine.admit_batch(
            self.cache, prompts, lengths, mask, budget_one, self.eos,
            self.temperature, self.top_k, self.top_p, self.tok, self.pos,
            self.done, self._step)
        self._step += 1
        tok0_h, done0_h = np.asarray(tok0), np.asarray(done0)
        if callable(now):
            now = now()
        for slot, req in admitted:
            req.status = RequestStatus.RUNNING
            req.slot = slot
            self._admit_counter += 1
            self._admit_seq[slot] = self._admit_counter
            if req.remaining >= 1:
                req.emit(int(tok0_h[slot]))
            if done0_h[slot]:
                eos = self._eos_h[slot]
                req.finish("eos" if eos >= 0 and req.tokens
                           and req.tokens[-1] == eos
                           else "length", now)
                self.finished.append(req)
                self._reset_slot_sampling(slot)
                if self.engine.paged:
                    self.engine.pool.release(slot)
            else:
                self.slots[slot] = req
        return len(admitted)

    def _push_sampling_state(self) -> None:
        place = self.engine.place_slot_state
        self.eos = place(jnp.asarray(self._eos_h, jnp.int32))
        self.temperature = place(jnp.asarray(self._temp_h, jnp.float32))
        self.top_k = place(jnp.asarray(self._topk_h, jnp.int32))
        self.top_p = place(jnp.asarray(self._topp_h, jnp.float32))

    # -- the scheduling loop -------------------------------------------------

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slots)

    @property
    def padding_waste(self) -> float:
        """prefill_tokens / admitted_tokens across all admission rounds —
        how many padded prefill tokens the fixed [slots, bucket] admission
        shape cost per useful prompt token (1.0 = no waste)."""
        a = self.stats["admitted_tokens"]
        return self.stats["prefill_tokens"] / a if a else 0.0

    @property
    def mean_occupancy(self) -> float:
        """Mean fraction of slots holding live requests per decode round."""
        n = self.stats["rounds"]
        return self.stats["occupancy_sum"] / n if n else 0.0

    def step(self, now=None) -> int:
        """One scheduling round: admit into free slots, decode one chunk,
        retire finished sequences.  Returns the number of useful tokens
        emitted this round."""
        self._admit(now)
        if not any(r is not None for r in self.slots):
            return 0
        if self.engine.paged:
            # block accounting: map pages for the chunk ahead; preempts
            # youngest-first when the pool is exhausted
            self._ensure_chunk_pages()
            if not any(r is not None for r in self.slots):
                return 0
        self.stats["rounds"] += 1
        self.stats["occupancy_sum"] += (
            sum(r is not None for r in self.slots) / self.n_slots)
        # host mirrors let us pick the argmax-only decode variant statically
        greedy = all(t <= 0.0 and k == 0 and p >= 1.0 for t, k, p in
                     zip(self._temp_h, self._topk_h, self._topp_h))
        (self.cache, self.tok, self.pos, self.done, toks,
         dones) = self.engine.decode_chunk(
            self.cache, self.tok, self.pos, self.done, self.eos,
            self.temperature, self.top_k, self.top_p, self._step, self.chunk,
            greedy=greedy)
        self._step += self.chunk
        toks_h, dones_h = np.asarray(toks), np.asarray(dones)
        if callable(now):      # stamp finish times after the chunk completed
            now = now()
        emitted, freed = 0, []
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            for j in range(self.chunk):
                req.emit(int(toks_h[slot, j]))
                emitted += 1
                if dones_h[slot, j]:
                    req.finish("eos", now)
                    break
                if req.remaining <= 0:
                    req.finish("length", now)
                    break
            if req.done:
                self.finished.append(req)
                self.slots[slot] = None
                self._reset_slot_sampling(slot)
                if self.engine.paged:
                    self.engine.pool.release(slot)
                freed.append(slot)
        if freed:
            self._free_on_device(freed)
        self.stats["emitted_tokens"] += emitted
        return emitted

    def run(self, requests: Sequence[Request] = (), now=None,
            max_rounds: int = 100_000) -> List[Request]:
        """Submit ``requests`` and drive rounds until everything finishes."""
        for r in requests:
            self.submit(r, now)
        rounds = 0
        while self.has_work:
            self.step(now)
            rounds += 1
            if rounds > max_rounds:
                raise RuntimeError("scheduler failed to drain "
                                   f"({len(self.queue)} queued)")
        return self.finished
