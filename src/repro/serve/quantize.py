"""Offline weight quantization for serving — the deployment-side of the
paper's flow: weights leave the QAT checkpoint as *integer codes* (packed
int4 nibbles or int8) + per-output-channel scales, exactly what the LUT
kernel consumes.  At decode, weight HBM traffic drops 4x (w4) / 2x (w8) vs
bf16 — the memory-roofline move that is LUTMUL's claim transposed to TPU.

A quantized projection leaf looks like::

    {"w_q": uint8[.., K//2, N]   (packed int4)   or  int8[.., K, N],
     "w_scale": f32[.., 1, N]}

``models.layers.linear`` dispatches on the presence of ``w_q``.
Embedding and lm_head follow the paper's first/last-layer rule (8-bit).
"""
from __future__ import annotations

import re

import jax
import jax.numpy as jnp

from repro.core.lut import pack_int4

# projection leaves eligible for low-bit quantization (trailing ['w'])
_INNER_W = re.compile(
    r"\['(wq|wk|wv|wo|wi|wg|wr|in_proj|out_proj)'\]\['w'\]$")
_MOE_W = re.compile(r"\['moe'\]\['w[igo]'\]$")
_HEAD_W = re.compile(r"\['lm_head'\]\['w'\]$")


def quantize_leaf(w: jax.Array, bits: int):
    """Float weight [..., K, N] -> {"w_q", "w_scale"} serving codes.

    Every weight-quantization event in the codebase funnels through here or
    ``kernels.lutmul.ops.quantize_weights`` — both bump
    ``ops.WEIGHT_QUANT_COUNT`` so tests can assert that cached layers
    quantize once at load, never per forward call.
    """
    from repro.kernels.lutmul import ops as lut_ops
    lut_ops.WEIGHT_QUANT_COUNT += 1
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-2, keepdims=True) \
        / qmax
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(w / scale), -qmax - 1, qmax).astype(jnp.int8)
    if bits == 4:
        q = jnp.swapaxes(pack_int4(jnp.swapaxes(q, -1, -2)), -1, -2)
    return {"w_q": q, "w_scale": scale.astype(jnp.float32)}


_quantize_leaf = quantize_leaf          # backwards-compat alias


def quantize_params_for_serving(params, mode: str = "w4a4_mxu"):
    """Replace eligible projection weights with integer codes + scales.

    mode: w4a4_lut | w4a4_mxu -> int4 inner, int8 head; w8a8 -> int8 all.

    Every eligible leaf is converted through ``models.layers.QuantizedLinear``
    — THE weight-code cache: quantize + pack exactly once here, zero
    weight-quantization events afterwards (serving decode and the QAT eval
    path in ``train.loop`` both ride this invariant).
    """
    from repro.models.layers import QuantizedLinear

    def codes(leaf: dict, leaf_mode: str) -> dict:
        return QuantizedLinear(leaf, mode=leaf_mode).params

    def walk(tree, path=""):
        if isinstance(tree, dict):
            out = {}
            for k, v in tree.items():
                sub = f"{path}['{k}']"
                if isinstance(v, dict) and "w" in v and _INNER_W.search(
                        sub + "['w']") and v["w"].ndim >= 2:
                    out[k] = codes(v, mode)
                elif _MOE_W.search(sub) and not isinstance(v, dict):
                    out[k] = codes({"w": v}, mode)
                elif isinstance(v, dict) and "w" in v and _HEAD_W.search(
                        sub + "['w']"):
                    out[k] = codes(v, "w8a8")     # paper: last layer 8-bit
                else:
                    out[k] = walk(v, sub)
            return out
        if isinstance(tree, (tuple, list)):
            return type(tree)(walk(v, f"{path}[{i}]")
                              for i, v in enumerate(tree))
        return tree

    return walk(params)


def dequantize_weight(p: dict, dtype=jnp.bfloat16) -> jax.Array:
    """Reassemble a float weight from codes (tests / fallbacks)."""
    from repro.core.lut import unpack_int4
    q = p["w_q"]
    if q.dtype == jnp.uint8:      # packed int4
        q = jnp.swapaxes(unpack_int4(jnp.swapaxes(q, -1, -2), signed=True),
                         -1, -2)
    return (q.astype(jnp.float32) * p["w_scale"]).astype(dtype)
