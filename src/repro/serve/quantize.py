"""Offline weight quantization for serving — the deployment-side of the
paper's flow: weights leave the QAT checkpoint as *integer codes* (packed
int4 nibbles or int8) + per-output-channel scales, exactly what the LUT
kernel consumes.  At decode, weight HBM traffic drops 4x (w4) / 2x (w8) vs
bf16 — the memory-roofline move that is LUTMUL's claim transposed to TPU.

A quantized projection leaf looks like::

    {"w_q": uint8[.., K//2, N]   (packed int4)   or  int8[.., K, N],
     "w_scale": f32[.., 1, N]}

or, for the T-MAC bitplane family (w1/w2/w3/w4/ternary weights)::

    {"w_q": uint8[P, K//8, N]    (packed bitplanes, P = plane count),
     "w_scale": f32[1, N],
     "w_tmac": uint8[0],          # zero-size formulation marker
     "w_tern": uint8[0]}          # present iff ternary (P=2 is ambiguous)

The markers are zero-size arrays so the choice is *static pytree
structure* (same idiom as the dist.tp ``tp_*`` markers) — ``jit`` sees the
bit width without tracing on values.  ``models.layers.linear`` dispatches
on the presence of ``w_q`` and on its rank (3D = tmac).
Embedding and lm_head follow the paper's first/last-layer rule (8-bit).
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.lut import pack_int4

# projection leaves eligible for low-bit quantization (trailing ['w'])
_INNER_W = re.compile(
    r"\['(wq|wk|wv|wo|wi|wg|wr|in_proj|out_proj)'\]\['w'\]$")
_MOE_W = re.compile(r"\['moe'\]\['w[igo]'\]$")
_HEAD_W = re.compile(r"\['lm_head'\]\['w'\]$")


def quantize_leaf(w: jax.Array, bits: int):
    """Float weight [..., K, N] -> {"w_q", "w_scale"} serving codes.

    Every weight-quantization event in the codebase funnels through here or
    ``kernels.lutmul.ops.quantize_weights`` — both bump
    ``ops.WEIGHT_QUANT_COUNT`` so tests can assert that cached layers
    quantize once at load, never per forward call.
    """
    from repro.kernels.lutmul import ops as lut_ops
    lut_ops.WEIGHT_QUANT_COUNT += 1
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-2, keepdims=True) \
        / qmax
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(w / scale), -qmax - 1, qmax).astype(jnp.int8)
    if bits == 4:
        q = jnp.swapaxes(pack_int4(jnp.swapaxes(q, -1, -2)), -1, -2)
    return {"w_q": q, "w_scale": scale.astype(jnp.float32)}


_quantize_leaf = quantize_leaf          # backwards-compat alias


def quantize_leaf_mode(w: jax.Array, mode: str):
    """Mode-aware leaf quantizer: float weight -> serving codes dict.

    Legacy modes ("w4a4_lut"/"w4a4_mxu"/"w8a8") produce the nibble/int8
    leaf; tmac-family modes produce the bitplane leaf with markers (leading
    stack dims — the scanned per-group block axis — pass through).  A
    suffix-free sub-4-bit mode ("w2a4") lets :func:`ops.pick_formulation`
    A/B tmac vs one-hot per (bits, shape) and stores the winner's format —
    the stored leaf IS the formulation choice.  MoE expert banks must use
    legacy modes (``quantize_params_for_serving`` coerces them): tmac
    targets the dense projections; ``moe._expert_einsum`` consumes
    nibble/int8 stacks.
    """
    from repro.kernels.lutmul import ops as lut_ops
    form, wspec, abits = lut_ops.parse_mode(mode)
    if form == "int":
        return quantize_leaf(w, 8 if abits >= 8 else 4)
    if form == "auto":
        form = lut_ops.pick_formulation(wspec, abits, w.shape[-2],
                                        w.shape[-1])
    if form == "onehot":
        # sub-4-bit codes are valid 4-bit codes: quantize at the leaf's own
        # width, store in the nibble format the one-hot kernel consumes
        if lut_ops.weight_bits(wspec) < 4:
            planes, scale = lut_ops.quantize_weights_planes(w, wspec)
            from repro.core.lut import decode_planes, unpack_bitplanes
            q = decode_planes(unpack_bitplanes(planes), wspec).astype(jnp.int8)
            q = jnp.swapaxes(pack_int4(jnp.swapaxes(q, -1, -2)), -1, -2)
            return {"w_q": q, "w_scale": scale.astype(jnp.float32)}
        return quantize_leaf(w, 4)
    planes, scale = lut_ops.quantize_weights_planes(w, wspec)
    # markers shaped leading_stack_dims + (0,) so they scan like any leaf
    marker = jnp.zeros(planes.shape[:-3] + (0,), jnp.uint8)
    leaf = {"w_q": planes, "w_scale": scale.astype(jnp.float32),
            "w_tmac": marker}
    if wspec == "ternary":
        leaf["w_tern"] = marker
    return leaf


def quantize_params_for_serving(params, mode: str = "w4a4_mxu",
                                bits_plan: Optional[dict] = None):
    """Replace eligible projection weights with integer codes + scales.

    mode: w4a4_lut | w4a4_mxu -> int4 inner, int8 head; w8a8 -> int8 all;
    tmac family (``w{1,2,3,4}a{4,8}[_tmac]``, ``ternary_a{4,8}[_tmac]``) ->
    bitplane leaves (suffix-free = formulation auto-picked per shape).

    ``bits_plan``: optional {path -> mode string} per-leaf override (the
    output of ``roofline.analysis.plan_mixed_bits``) keyed by the same
    ``"...['wq']['w']"`` path strings this walk builds — lets the roofline
    model choose mixed per-layer bit widths while everything else follows
    ``mode``.

    Every eligible leaf is converted through ``models.layers.QuantizedLinear``
    — THE weight-code cache: quantize + pack exactly once here, zero
    weight-quantization events afterwards (serving decode and the QAT eval
    path in ``train.loop`` both ride this invariant).
    """
    from repro.kernels.lutmul import ops as lut_ops
    from repro.models.layers import QuantizedLinear

    plan = bits_plan or {}

    def codes(leaf: dict, leaf_mode: str) -> dict:
        return QuantizedLinear(leaf, mode=leaf_mode).params

    def legacy(leaf_mode: str) -> str:
        # MoE expert banks stay on the nibble/int8 stack format
        # (moe._expert_einsum consumes it); coerce tmac modes down
        form, _, abits = lut_ops.parse_mode(leaf_mode)
        if form in ("int", "onehot"):
            return leaf_mode
        return "w8a8" if abits >= 8 else "w4a4_mxu"

    def walk(tree, path=""):
        if isinstance(tree, dict):
            out = {}
            for k, v in tree.items():
                sub = f"{path}['{k}']"
                if isinstance(v, dict) and "w" in v and _INNER_W.search(
                        sub + "['w']") and v["w"].ndim >= 2:
                    out[k] = codes(v, plan.get(sub + "['w']", mode))
                elif _MOE_W.search(sub) and not isinstance(v, dict):
                    out[k] = codes({"w": v}, legacy(plan.get(sub, mode)))
                elif isinstance(v, dict) and "w" in v and _HEAD_W.search(
                        sub + "['w']"):
                    out[k] = codes(v, "w8a8")     # paper: last layer 8-bit
                else:
                    out[k] = walk(v, sub)
            return out
        if isinstance(tree, (tuple, list)):
            return type(tree)(walk(v, f"{path}[{i}]")
                              for i, v in enumerate(tree))
        return tree

    return walk(params)


def _draftable(leaf, draft_planes: int) -> bool:
    """True for tmac leaves whose plane stack truncates to ``draft_planes``.

    Positional int planes only: ternary's two planes are (+1, -1) masks, not
    powers of two, so it (and w1) pass through undrafted — as do leaves
    already at or below the draft width, one-hot nibble leaves, the w8a8
    head, and MoE banks (legacy stack format).
    """
    return (isinstance(leaf, dict) and "w_tmac" in leaf
            and "w_tern" not in leaf and leaf["w_q"].ndim >= 3
            and leaf["w_q"].shape[-3] > draft_planes >= 2)


def draft_params_view(params, draft_planes: int):
    """Truncated-plane drafter view of quantized serving params.

    For every draftable tmac leaf, slice the top ``draft_planes`` bitplanes
    (plane axis -3 — leading scanned stack dims pass through) and fold the
    ``2^(B-p)`` coefficient factor into ``w_scale``; every other leaf is the
    *same object* as the target's.  The view is a pure tree walk over slices
    — zero extra weight memory, safe to build inside ``jit`` (XLA hoists it
    as loop-invariant), and it preserves the ``w_tmac``/tp markers so
    formulation dispatch and the row-parallel int32 psum work unchanged.
    """
    from repro.kernels.lutmul import ops as lut_ops

    def walk(tree):
        if isinstance(tree, dict):
            if _draftable(tree, draft_planes):
                wbits = int(tree["w_q"].shape[-3])
                sliced, _, mult = lut_ops.truncate_planes(
                    tree["w_q"], wbits, draft_planes)
                out = dict(tree)
                out["w_q"] = sliced
                out["w_scale"] = tree["w_scale"] * jnp.float32(mult)
                return out
            return {k: walk(v) for k, v in tree.items()}
        if isinstance(tree, (tuple, list)):
            return type(tree)(walk(v) for v in tree)
        return tree

    return walk(params)


def count_draftable_leaves(params, draft_planes: int) -> int:
    """How many leaves :func:`draft_params_view` would actually truncate."""
    n = 0

    def walk(tree):
        nonlocal n
        if isinstance(tree, dict):
            if _draftable(tree, draft_planes):
                n += 1
            else:
                for v in tree.values():
                    walk(v)
        elif isinstance(tree, (tuple, list)):
            for v in tree:
                walk(v)

    walk(params)
    return n


def dequantize_weight(p: dict, dtype=jnp.bfloat16) -> jax.Array:
    """Reassemble a float weight from codes (tests / fallbacks)."""
    from repro.core.lut import decode_planes, unpack_bitplanes, unpack_int4
    q = p["w_q"]
    if "w_tmac" in p:             # packed bitplanes (plane axis is -3:
        # leading stack dims — the scanned block axis — pass through)
        spec = "ternary" if "w_tern" in p else int(q.shape[-3])
        q = decode_planes(unpack_bitplanes(q), spec)
    elif q.dtype == jnp.uint8:    # packed int4
        q = jnp.swapaxes(unpack_int4(jnp.swapaxes(q, -1, -2), signed=True),
                         -1, -2)
    return (q.astype(jnp.float32) * p["w_scale"]).astype(dtype)
