"""Request: the unit of work the continuous-batching scheduler admits.

A request carries everything needed to run one sequence independently of its
batch neighbours: the prompt, a decode budget, an optional EOS id, per-request
sampling knobs, and an optional streaming callback invoked as tokens are
emitted.  Status moves QUEUED -> RUNNING -> FINISHED; ``finish_reason``
records why decode stopped ("eos" | "length").
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, List, Optional, Sequence


class RequestStatus(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    prompt: Sequence[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # per-request sampling (defaults to the engine ServeConfig when None)
    temperature: Optional[float] = None
    top_k: Optional[int] = None
    top_p: Optional[float] = None
    # streaming: called with (request, token) for every emitted token
    on_token: Optional[Callable[["Request", int], None]] = None

    # -- scheduler-managed state --------------------------------------------
    status: RequestStatus = RequestStatus.QUEUED
    tokens: List[int] = dataclasses.field(default_factory=list)
    finish_reason: Optional[str] = None
    slot: Optional[int] = None            # decode slot while RUNNING
    arrival_time: Optional[float] = None  # set by the scheduler on submit
    finish_time: Optional[float] = None

    def __post_init__(self):
        # budget 0 is legal (score-the-prompt / warmup requests): the
        # scheduler finishes it at admission without emitting a token
        if self.max_new_tokens < 0:
            raise ValueError("max_new_tokens must be >= 0")
        if len(self.prompt) < 1:
            raise ValueError("prompt must be non-empty")

    @property
    def done(self) -> bool:
        return self.status == RequestStatus.FINISHED

    @property
    def remaining(self) -> int:
        return self.max_new_tokens - len(self.tokens)

    def emit(self, token: int) -> None:
        """Record one generated token (and stream it)."""
        self.tokens.append(int(token))
        if self.on_token is not None:
            self.on_token(self, int(token))

    def finish(self, reason: str, now: Optional[float] = None) -> None:
        self.status = RequestStatus.FINISHED
        self.finish_reason = reason
        self.finish_time = now
        self.slot = None
