"""Request: the unit of work the continuous-batching scheduler admits.

A request carries everything needed to run one sequence independently of its
batch neighbours: the prompt, a decode budget, an optional EOS id, per-request
sampling knobs, and an optional streaming callback invoked as tokens are
emitted.  Status moves QUEUED -> RUNNING -> FINISHED; ``finish_reason``
records why decode stopped ("eos" | "length").

Fault tolerance adds three terminal statuses the scheduler can impose:

  * TIMED_OUT — the request's ``deadline`` passed (in the scheduler's
    LOGICAL clock, the ``now=`` values the caller threads through
    ``submit``/``step`` — never wall clock, so replays are exact);
  * SHED — deterministic admission-control overload shedding picked this
    request (lowest priority first, then least deadline slack);
  * FAILED — the request was in flight across more than ``max_retries``
    fault recoveries and was dropped instead of retried again.

``deadline`` is a logical-time instant (same units as ``now``), ``priority``
an integer where HIGHER survives shedding longer.  Both must be finite —
validated here and again at ``Scheduler.submit``.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Callable, List, Optional, Sequence


class RequestStatus(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    FINISHED = "finished"
    TIMED_OUT = "timed_out"
    SHED = "shed"
    FAILED = "failed"


# finish_reason -> terminal status (anything else finishes FINISHED)
_REASON_STATUS = {
    "timed_out": RequestStatus.TIMED_OUT,
    "shed": RequestStatus.SHED,
    "failed": RequestStatus.FAILED,
}

_TERMINAL = frozenset((RequestStatus.FINISHED, RequestStatus.TIMED_OUT,
                       RequestStatus.SHED, RequestStatus.FAILED))


@dataclasses.dataclass
class Request:
    prompt: Sequence[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # per-request sampling (defaults to the engine ServeConfig when None)
    temperature: Optional[float] = None
    top_k: Optional[int] = None
    top_p: Optional[float] = None
    # streaming: called with (request, token) for every emitted token
    on_token: Optional[Callable[["Request", int], None]] = None
    # fault tolerance / QoS: logical-time deadline + shedding priority
    deadline: Optional[float] = None
    priority: int = 0

    # -- scheduler-managed state --------------------------------------------
    status: RequestStatus = RequestStatus.QUEUED
    tokens: List[int] = dataclasses.field(default_factory=list)
    finish_reason: Optional[str] = None
    slot: Optional[int] = None            # decode slot while RUNNING
    arrival_time: Optional[float] = None  # set by the scheduler on submit
    finish_time: Optional[float] = None
    retries: int = 0                      # fault recoveries survived in flight

    def __post_init__(self):
        # budget 0 is legal (score-the-prompt / warmup requests): the
        # scheduler finishes it at admission without emitting a token
        if self.max_new_tokens < 0:
            raise ValueError("max_new_tokens must be >= 0")
        if len(self.prompt) < 1:
            raise ValueError("prompt must be non-empty")
        if self.deadline is not None and not math.isfinite(self.deadline):
            raise ValueError(f"deadline must be finite, got {self.deadline}")
        if not math.isfinite(self.priority):
            raise ValueError(f"priority must be finite, got {self.priority}")

    @property
    def done(self) -> bool:
        return self.status in _TERMINAL

    @property
    def remaining(self) -> int:
        return self.max_new_tokens - len(self.tokens)

    def slack(self, now: Optional[float]) -> float:
        """Logical time to spare before the deadline; +inf when the request
        has no deadline (or the caller runs without a clock).  The scheduler
        preempts the MOST-slack slot (it can be requeued and still make its
        deadline) and sheds the LEAST-slack queued request (it was going to
        miss anyway)."""
        if self.deadline is None or now is None:
            return math.inf
        return self.deadline - now

    def emit(self, token: int) -> None:
        """Record one generated token (and stream it).  The token lands on
        the transcript BEFORE the callback runs, and a raising ``on_token``
        propagates to the caller — the scheduler catches it and fails only
        this request (status ``failed``), never the serving round."""
        self.tokens.append(int(token))
        if self.on_token is not None:
            self.on_token(self, int(token))

    def finish(self, reason: str, now: Optional[float] = None) -> None:
        self.status = _REASON_STATUS.get(reason, RequestStatus.FINISHED)
        self.finish_reason = reason
        self.finish_time = now
        self.slot = None
