"""Paged KV-cache pool: host-side block allocator with prefix reuse.

The serving analogue of the paper's trade — many small cheap units instead
of one big expensive one: instead of a dense ``[slots, max_len]`` KV buffer
per layer, every layer holds a shared pool of ``num_pages`` fixed-size pages
(``[G, num_pages, page_size, n_kv, head_dim]``) and each decode slot owns a
*page table* — a fixed-shape ``[slots, entries]`` int32 row of physical page
ids.  Memory then scales with the tokens actually resident, not with the
worst case, and identical prompt prefixes can map to the SAME physical
pages.

This module is the host-side half: allocation, refcounts, hash-chained
prefix identity, and the numpy page tables the compiled executors index
with.  The device-side half (ordered gather / scatter so temperature-0
output stays bit-identical to the dense cache) lives in
``models.attention`` + ``serve.engine``.

Design points:

  * **Page id 0 of every shard is the reserved null page.**  Unallocated
    table entries are 0, and the compiled scatters route every masked /
    free-slot write there, so a freed-and-reused page can never be
    corrupted by a stale slot.  Usable pages per shard =
    ``pages_per_shard - 1``.
  * **Prefix reuse is hash-chained page identity**: page ``j`` of a prompt
    is identified by ``(identity of page j-1, tokens of page j)``; only
    FULL pages register (a partial tail is still being written).  A new
    admission walks its chain against the registry and maps every leading
    hit to the existing physical page (refcount++); the first miss — the
    copy-on-write divergence point — and everything after it get fresh
    pages which the admission prefill then fills.  Registered pages are
    immutable afterwards (decode only writes at positions >= prompt
    length), so sharing is safe; content is bit-identical across sharers
    because every per-token computation in prefill is causal and row-wise.
  * **SWA rings are page-aligned**: local-attention layers keep their
    rolling ``min(max_len, window)``-slot ring, stored in pool pages
    addressed through a separate per-slot ring table (ring content is a
    function of the slot's own rolling history, so ring pages are never
    shared).  Ring entries allocate lazily in write order, exactly like
    full entries.
  * **Sharding**: page ids are SHARD-LOCAL.  Under the sharded engine the
    pool page axis splits over the data mesh axis; each data shard runs an
    independent allocator + prefix registry over its own slots, and the
    table rows it sees (batch axis also data-split) contain its local ids.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Static geometry of the paged cache (everything shape-determining)."""
    page_size: int
    max_len: int
    full_entries: int            # max_len // page_size
    ring_entries: int            # min(max_len, window) // page_size, or 0
    ring_len: int                # min(max_len, window), or 0

    @staticmethod
    def build(cfg, max_len: int, page_size: int) -> "PagedLayout":
        if page_size < 1 or max_len % page_size:
            raise ValueError(
                f"page_size ({page_size}) must divide max_len ({max_len})")
        has_ring = any(
            spec.kind == "attn" and spec.attn_type == "local"
            and bool(getattr(cfg, "window", None))
            for spec in getattr(cfg, "pattern", ()))
        ring_len = min(max_len, cfg.window) if has_ring else 0
        if ring_len % page_size:
            raise ValueError(
                f"page_size ({page_size}) must divide the SWA ring length "
                f"({ring_len} = min(max_len, window)) — rings are stored as "
                "page-aligned windows")
        return PagedLayout(page_size=page_size, max_len=max_len,
                           full_entries=max_len // page_size,
                           ring_entries=ring_len // page_size,
                           ring_len=ring_len)

    def auto_pages_per_shard(self, slots_per_shard: int) -> int:
        """Worst-case capacity + the null page: exhaustion-free default."""
        return slots_per_shard * (self.full_entries + self.ring_entries) + 1


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


class _Shard:
    """One data shard's allocator state (free heap, refcounts, registry)."""

    def __init__(self, pages: int):
        self.free = list(range(1, pages))        # id 0 = reserved null page
        heapq.heapify(self.free)
        self.ref = np.zeros((pages,), np.int32)
        self.hash2page: dict = {}                # chain key -> page id
        self.page_key: dict = {}                 # page id -> chain key
        # registered pages whose content has actually been written: chunked
        # prefill registers a prompt's pages at admission but fills them a
        # chunk at a time, and only a FILLED page may be prefix-shared
        self.ready: set = set()

    def alloc(self) -> int:
        return heapq.heappop(self.free)

    def decref(self, pid: int) -> bool:
        """Drop one reference; True when the page was actually freed."""
        self.ref[pid] -= 1
        if self.ref[pid] > 0:
            return False
        key = self.page_key.pop(pid, None)
        if key is not None and self.hash2page.get(key) == pid:
            del self.hash2page[key]
        self.ready.discard(pid)
        heapq.heappush(self.free, pid)
        return True


class PagePool:
    """Block allocator + page tables for one engine's slot pool.

    All methods are host-side and deterministic (lowest-id-first allocation,
    FIFO-order admission gating is the caller's job).  ``table`` / ``ring``
    / ``start`` are plain numpy arrays the engine snapshots to device per
    dispatch.
    """

    def __init__(self, slots: int, layout: PagedLayout, *,
                 pages_per_shard: Optional[int] = None, n_shards: int = 1,
                 prefix_reuse: bool = True):
        if slots % n_shards:
            raise ValueError(f"slots ({slots}) must divide over page shards "
                             f"({n_shards})")
        self.layout = layout
        self.slots = slots
        self.n_shards = n_shards
        self.slots_per_shard = slots // n_shards
        if pages_per_shard is None:
            pages_per_shard = layout.auto_pages_per_shard(
                self.slots_per_shard)
        if pages_per_shard < 2:
            raise ValueError("pages_per_shard must be >= 2 (one null page "
                             "+ at least one usable page)")
        self.pages_per_shard = pages_per_shard
        self.prefix_reuse = prefix_reuse
        self._shards = [_Shard(pages_per_shard) for _ in range(n_shards)]
        E = max(layout.full_entries, 1)
        self.table = np.zeros((slots, E), np.int32)
        self.ring = np.zeros((slots, max(layout.ring_entries, 1)), np.int32)
        self.start = np.zeros((slots,), np.int32)   # first stitched token
        self.n_full = [0] * slots
        self.n_ring = [0] * slots
        # stats
        self.allocated_pages = 0                 # unique in-use pages, now
        self.peak_pages = 0
        self.prefix_hits = 0                     # prompt pages mapped shared
        self.prefix_fresh = 0                    # prompt pages freshly filled
        self.preemptions = 0                     # bumped by the scheduler
        self._peak_per_shard = 0

    # -- geometry ------------------------------------------------------------

    def shard_of(self, slot: int) -> int:
        return slot // self.slots_per_shard

    def free_pages(self, shard: int) -> int:
        return len(self._shards[shard].free)

    @property
    def peak_pages_per_shard(self) -> int:
        """Peak unique in-use pages on the busiest shard (the per-shard
        residency figure the sharded engine reports)."""
        return getattr(self, "_peak_per_shard", 0)

    def _entries_for(self, n_tokens: int) -> tuple[int, int]:
        """(full entries, ring entries) needed to hold ``n_tokens``."""
        lay = self.layout
        nf = min(_ceil_div(n_tokens, lay.page_size), lay.full_entries)
        nr = 0
        if lay.ring_entries:
            nr = min(_ceil_div(min(n_tokens, lay.ring_len), lay.page_size),
                     lay.ring_entries)
        return nf, nr

    # -- admission / growth / release ---------------------------------------

    def admit(self, slot: int, tokens: Sequence[int], *,
              fills_now: bool = True, share: bool = True) -> Optional[int]:
        """Map ``slot`` onto pages holding ``tokens`` (the prompt, or prompt
        + already-emitted tokens on a preemption resume).

        Walks the hash chain over the FULL prompt pages and shares every
        leading READY hit (a page is ready once its content is actually
        written — registered-but-unfilled pages of an in-flight chunked
        admission never match); allocates fresh pages for the divergence
        tail and the ring.  Returns the first token index the admission
        must fill (``start_tok`` — everything before it lives in shared
        pages), or None when the shard has too few free pages (the caller
        gates admission / preempts).  Leaves no state behind on failure.

        ``fills_now=True`` (the monolithic path: one prefill dispatch
        writes every page before anything else runs) marks the fresh full
        pages ready immediately; chunked admissions pass ``fills_now=False``
        and report progress through :meth:`mark_filled`.  ``share=False``
        fully isolates the admission — neither maps shared pages nor
        registers its own (chunked SWA admissions replay their window from
        position 0, so their pages must never be mixed with a monolithic
        sharer's prefill-written bits, in either direction).
        """
        assert self.n_full[slot] == 0 and self.n_ring[slot] == 0, \
            f"slot {slot} already mapped"
        sh = self._shards[self.shard_of(slot)]
        L = len(tokens)
        nf, nr = self._entries_for(L)
        ps = self.layout.page_size
        keys, key = [], None
        for j in range(L // ps):                 # full pages only
            key = (key, tuple(int(t) for t in tokens[j * ps:(j + 1) * ps]))
            keys.append(key)
        shared: list[int] = []
        if self.prefix_reuse and share:
            for key in keys:
                pid = sh.hash2page.get(key)
                if pid is None or pid not in sh.ready:
                    break
                shared.append(pid)
        fresh = nf - len(shared)
        if len(sh.free) < fresh + nr:
            return None
        row = self.table[slot]
        for j, pid in enumerate(shared):
            sh.ref[pid] += 1
            row[j] = pid
        for j in range(len(shared), nf):
            pid = sh.alloc()
            sh.ref[pid] = 1
            row[j] = pid
            if self.prefix_reuse and share and j < len(keys):   # register
                sh.hash2page[keys[j]] = pid
                sh.page_key[pid] = keys[j]
                if fills_now:
                    sh.ready.add(pid)
        for j in range(nr):
            pid = sh.alloc()
            sh.ref[pid] = 1
            self.ring[slot, j] = pid
        self.n_full[slot], self.n_ring[slot] = nf, nr
        start = len(shared) * ps
        self.start[slot] = start
        self.prefix_hits += len(shared)
        self.prefix_fresh += fresh
        self._bump(fresh + nr)
        return start

    def mark_filled(self, slot: int, n_tokens: int) -> None:
        """Record that ``slot``'s first ``n_tokens`` positions have been
        written on device: every fully-covered registered page becomes ready
        (shareable).  The chunked-prefill scheduler calls this as each
        round's writes commit; already-ready (shared) pages are no-ops."""
        sh = self._shards[self.shard_of(slot)]
        for j in range(min(n_tokens // self.layout.page_size,
                           self.n_full[slot])):
            pid = int(self.table[slot, j])
            if pid in sh.page_key:
                sh.ready.add(pid)

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow ``slot``'s mapping to cover ``n_tokens`` positions (called
        before every decode chunk).  Atomic: allocates nothing on failure."""
        sh = self._shards[self.shard_of(slot)]
        nf, nr = self._entries_for(n_tokens)
        extra_f = max(0, nf - self.n_full[slot])
        extra_r = max(0, nr - self.n_ring[slot])
        if len(sh.free) < extra_f + extra_r:
            return False
        for j in range(self.n_full[slot], nf):
            pid = sh.alloc()
            sh.ref[pid] = 1
            self.table[slot, j] = pid
        for j in range(self.n_ring[slot], nr):
            pid = sh.alloc()
            sh.ref[pid] = 1
            self.ring[slot, j] = pid
        self.n_full[slot] = max(self.n_full[slot], nf)
        self.n_ring[slot] = max(self.n_ring[slot], nr)
        self._bump(extra_f + extra_r)
        return True

    def trim(self, slot: int, keep_tokens: int) -> int:
        """Shrink ``slot``'s FULL mapping to the fewest entries covering
        ``keep_tokens`` positions — the paged rollback of rejected
        speculative writes: a draft/verify round maps pages for the whole
        ``draft_k+1``-token block up front, and the tail past the accepted
        prefix unmaps here so low-accept rounds can't hold pages other
        slots need.  Callers keep at least the committed sequence (prompt +
        emitted + the pending token's slot), so registered prompt pages are
        never reachable by a trim; shared pages just drop one reference.
        Ring entries never shrink (the SWA ring is a rolling window).
        Returns the number of pages actually freed."""
        sh = self._shards[self.shard_of(slot)]
        nf, _ = self._entries_for(max(int(keep_tokens), 1))
        freed = 0
        for j in range(nf, self.n_full[slot]):
            freed += sh.decref(int(self.table[slot, j]))
            self.table[slot, j] = 0
        self.n_full[slot] = min(self.n_full[slot], nf)
        self.allocated_pages -= freed
        return freed

    def release(self, slot: int) -> None:
        """Return every page ``slot`` references (shared pages survive while
        other sharers hold them) and point the slot back at the null page so
        its idempotent free-slot decode writes can never corrupt anything."""
        sh = self._shards[self.shard_of(slot)]
        freed = 0
        for j in range(self.n_full[slot]):
            freed += sh.decref(int(self.table[slot, j]))
        for j in range(self.n_ring[slot]):
            freed += sh.decref(int(self.ring[slot, j]))
        self.table[slot] = 0
        self.ring[slot] = 0
        self.start[slot] = 0
        self.n_full[slot] = self.n_ring[slot] = 0
        self.allocated_pages -= freed

    def _bump(self, n: int) -> None:
        self.allocated_pages += n
        self.peak_pages = max(self.peak_pages, self.allocated_pages)
        per = max(self.pages_per_shard - 1 - len(s.free)
                  for s in self._shards)
        self._peak_per_shard = max(self._peak_per_shard, per)

    # -- stats ---------------------------------------------------------------

    @property
    def prefix_hit_rate(self) -> float:
        total = self.prefix_hits + self.prefix_fresh
        return self.prefix_hits / total if total else 0.0

    @property
    def usable_pages(self) -> int:
        """Total allocatable pages across shards (null pages excluded)."""
        return self.n_shards * (self.pages_per_shard - 1)

    @property
    def saturation(self) -> float:
        """Fraction of usable pages currently allocated — the quantity the
        scheduler's shed watermark is compared against."""
        return self.allocated_pages / self.usable_pages

    # -- invariant audit / leak telemetry ------------------------------------

    def _in_use(self, shard: int) -> dict:
        """page id -> reference count recomputed from the slot mappings."""
        refs: dict = {}
        lo = shard * self.slots_per_shard
        for slot in range(lo, lo + self.slots_per_shard):
            for j in range(self.n_full[slot]):
                pid = int(self.table[slot, j])
                refs[pid] = refs.get(pid, 0) + 1
            for j in range(self.n_ring[slot]):
                pid = int(self.ring[slot, j])
                refs[pid] = refs.get(pid, 0) + 1
        return refs

    def validate(self) -> list:
        """Cheap host-side audit of the allocator invariants; returns a list
        of problem strings (empty = healthy).  The engine runs this before
        every dispatch on a paged engine — an out-of-range or stale table
        entry is caught BEFORE the compiled scatter/gather would silently
        clamp it into corrupting a live page."""
        errs = []
        P = self.pages_per_shard
        for s in range(self.n_shards):
            sh = self._shards[s]
            refs = self._in_use(s)
            for pid in refs:
                if not 0 < pid < P:
                    errs.append(f"shard {s}: table entry {pid} out of "
                                f"range (0, {P})")
            want = np.zeros((P,), np.int32)
            for pid, n in refs.items():
                if 0 < pid < P:
                    want[pid] = n
            bad = np.flatnonzero(want != sh.ref)
            if bad.size:
                errs.append(
                    f"shard {s}: refcount mismatch at pages "
                    f"{bad[:4].tolist()} (mapped {want[bad[:4]].tolist()} "
                    f"vs recorded {sh.ref[bad[:4]].tolist()})")
            free = set(sh.free)
            overlap = free & {p for p in refs if 0 < p < P}
            if overlap:
                errs.append(f"shard {s}: free-list/in-use overlap "
                            f"{sorted(overlap)[:4]}")
            if len(free) != len(sh.free):
                errs.append(f"shard {s}: duplicate free-list entries")
        total = sum(len(self._in_use(s)) for s in range(self.n_shards))
        if not errs and total != self.allocated_pages:
            errs.append(f"allocated_pages {self.allocated_pages} != "
                        f"{total} pages mapped by slots")
        return errs

    def leaked_pages(self) -> list:
        """Pages still holding references that NO slot mapping reaches —
        i.e. real leaks (shared prefix pages held by live sharers are
        reachable, so they don't count).  Returns (shard, page) tuples.
        At scheduler drain this and ``allocated_pages`` must both be
        empty/zero."""
        leaks = []
        for s in range(self.n_shards):
            reachable = set(self._in_use(s))
            for pid in range(1, self.pages_per_shard):
                if self._shards[s].ref[pid] > 0 and pid not in reachable:
                    leaks.append((s, pid))
        return leaks

    # -- snapshot / restore ---------------------------------------------------

    @staticmethod
    def _key_to_prefix(key) -> list:
        """Flatten a nested chain key ((...), page_tokens) to the flat token
        prefix it identifies — the JSON/msgpack-serializable canonical form."""
        pages = []
        while key is not None:
            key, toks = key
            pages.append(list(toks))
        return [t for page in reversed(pages) for t in page]

    def _key_from_prefix(self, prefix) -> tuple:
        ps = self.layout.page_size
        key = None
        for j in range(len(prefix) // ps):
            key = (key, tuple(int(t) for t in prefix[j * ps:(j + 1) * ps]))
        return key

    def state_dict(self) -> dict:
        """JSON-able snapshot of the complete allocator state (tables,
        free lists, refcounts, prefix registry, stats) — what the
        scheduler's snapshot/checkpoint carries for crash recovery."""
        return {
            "table": self.table.tolist(),
            "ring": self.ring.tolist(),
            "start": self.start.tolist(),
            "n_full": list(self.n_full),
            "n_ring": list(self.n_ring),
            "shards": [{
                "free": sorted(sh.free),
                "ref": sh.ref.tolist(),
                "registry": [[self._key_to_prefix(key), int(pid)]
                             for key, pid in sh.hash2page.items()],
                "ready": sorted(sh.ready),
            } for sh in self._shards],
            "stats": {
                "allocated_pages": self.allocated_pages,
                "peak_pages": self.peak_pages,
                "prefix_hits": self.prefix_hits,
                "prefix_fresh": self.prefix_fresh,
                "preemptions": self.preemptions,
                "peak_per_shard": self._peak_per_shard,
            },
        }

    def load_state(self, state: dict) -> None:
        """Restore :meth:`state_dict` in place (geometry must match)."""
        self.table = np.asarray(state["table"], np.int32)
        self.ring = np.asarray(state["ring"], np.int32)
        self.start = np.asarray(state["start"], np.int32)
        self.n_full = list(state["n_full"])
        self.n_ring = list(state["n_ring"])
        if len(state["shards"]) != self.n_shards:
            raise ValueError("page-pool shard count mismatch")
        for sh, rec in zip(self._shards, state["shards"]):
            sh.free = list(rec["free"])
            heapq.heapify(sh.free)
            sh.ref = np.asarray(rec["ref"], np.int32)
            sh.hash2page = {}
            sh.page_key = {}
            for prefix, pid in rec["registry"]:
                key = self._key_from_prefix(prefix)
                sh.hash2page[key] = int(pid)
                sh.page_key[int(pid)] = key
            # older snapshots predate ready tracking: every registered page
            # they carry was written by a monolithic admission
            sh.ready = set(rec.get("ready", sh.page_key))
        st = state["stats"]
        self.allocated_pages = int(st["allocated_pages"])
        self.peak_pages = int(st["peak_pages"])
        self.prefix_hits = int(st["prefix_hits"])
        self.prefix_fresh = int(st["prefix_fresh"])
        self.preemptions = int(st["preemptions"])
        self._peak_per_shard = int(st["peak_per_shard"])
