"""Fault-tolerant training runner: checkpoint/restart supervision, failure
injection, straggler monitoring, elastic restore.

``run()`` is the supervisor: it (re)builds state from the latest committed
checkpoint, executes steps, saves asynchronously every ``ckpt_every``, and on
any step failure (including injected ``SimulatedFailure``) restarts from the
last committed checkpoint — the single-process embodiment of the restart
policy a 1000-node job runs under a cluster scheduler.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax

from repro.ckpt import checkpoint
from repro.data import pipeline
from repro.dist.straggler import StragglerMonitor
from repro.train.step import (TrainConfig, init_state, loss_for,
                              make_train_step)


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class RunConfig:
    steps: int = 20
    ckpt_every: int = 5
    ckpt_dir: str = "/tmp/repro_ckpt"
    async_ckpt: bool = True
    fail_at_step: Optional[int] = None     # inject exactly one failure
    max_restarts: int = 3
    log_every: int = 1
    # QAT eval: periodically evaluate the *deployed* (integer-code) model
    eval_every: int = 0                    # 0 disables
    eval_batches: int = 2
    eval_quant: str = "w4a4_mxu"


def make_eval_fn(model_cfg, eval_quant: str = "w4a4_mxu"):
    """QAT eval through the weight-code cache.

    Evaluating the deployed model means running the integer-code path the
    serving engine runs.  Weights are quantized + packed ONCE per evaluation
    (``models.layers.QuantizedLinear`` under ``serve.quantize``); every eval
    batch then reads the cached codes through ``prequant_matmul`` — zero
    weight-quantization events per batch, which tests assert via
    ``kernels.lutmul.ops.WEIGHT_QUANT_COUNT``.
    """
    ecfg = dataclasses.replace(model_cfg, quant=eval_quant)
    eval_step = jax.jit(loss_for(ecfg))

    def evaluate(params, batches) -> float:
        from repro.serve.quantize import quantize_params_for_serving
        coded = quantize_params_for_serving(params, mode=eval_quant)
        losses = [float(eval_step(coded, b)) for b in batches]
        return sum(losses) / len(losses)

    return evaluate


def run(model_cfg, init_params_fn: Callable, dcfg: pipeline.DataConfig,
        tcfg: TrainConfig = TrainConfig(), rcfg: RunConfig = RunConfig(),
        batch_kind: str = "lm") -> dict:
    """Returns {"history": [metrics...], "restarts": n, "straggler": report}."""
    step_fn = jax.jit(make_train_step(model_cfg, tcfg))
    eval_fn = make_eval_fn(model_cfg, rcfg.eval_quant) if rcfg.eval_every \
        else None
    monitor = StragglerMonitor()
    history: list[dict] = []
    restarts = 0
    failed_once = False

    def fresh_state():
        return init_state(init_params_fn())

    state = fresh_state()
    start = checkpoint.latest_step(rcfg.ckpt_dir)
    if start is not None:
        state, extra = checkpoint.restore(rcfg.ckpt_dir, state)
        step0 = extra.get("next_step", start)
    else:
        step0 = 0

    pending_save = None
    step = step0
    while step < rcfg.steps:
        try:
            batch = pipeline.lm_batch(dcfg, step) if batch_kind == "lm" \
                else pipeline.image_batch(dcfg, step)
            if rcfg.fail_at_step is not None and step == rcfg.fail_at_step \
                    and not failed_once:
                failed_once = True
                raise SimulatedFailure(f"injected failure at step {step}")
            t0 = time.time()
            state, metrics = step_fn(state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.time() - t0
            monitor.record("host0", dt)
            metrics.update(step=step, wall_s=dt)
            if eval_fn is not None and (step + 1) % rcfg.eval_every == 0:
                # eval batches come from a disjoint step range (held-out
                # shards of the synthetic stream)
                ebatches = [
                    pipeline.lm_batch(dcfg, 10 ** 6 + i) if batch_kind == "lm"
                    else pipeline.image_batch(dcfg, 10 ** 6 + i)
                    for i in range(rcfg.eval_batches)]
                metrics["eval_loss"] = eval_fn(state["params"], ebatches)
            history.append(metrics)
            step += 1
            if step % rcfg.ckpt_every == 0:
                if pending_save is not None:
                    pending_save.join()
                pending_save = checkpoint.save(
                    rcfg.ckpt_dir, step, state, extra={"next_step": step},
                    async_save=rcfg.async_ckpt)
        except SimulatedFailure:
            restarts += 1
            if restarts > rcfg.max_restarts:
                raise
            if pending_save is not None:
                pending_save.join()
                pending_save = None
            last = checkpoint.latest_step(rcfg.ckpt_dir)
            if last is not None:
                state, extra = checkpoint.restore(rcfg.ckpt_dir, state)
                step = extra.get("next_step", last)
            else:
                state = fresh_state()
                step = 0
    if pending_save is not None:
        pending_save.join()
    return {"history": history, "restarts": restarts,
            "straggler": monitor.evaluate()}
