"""Train step factory: loss -> grads (with optional microbatch accumulation)
-> clip -> AdamW -> optional QAT weight projection.

One factory serves every model family; the loss function is dispatched by
``cfg.family``.  The returned step is pure and jit/pjit-friendly.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import encdec, mobilenet, transformer
from repro.optim import adamw, schedules


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"              # cosine | wsd
    adamw: adamw.AdamWConfig = adamw.AdamWConfig()
    n_microbatches: int = 1
    qat_project: bool = False             # paper Sec 3.6 post-update projection
    bf16_params: bool = False             # bf16 compute params + fp32 master
                                          # in opt (halves FSDP all-gather)


def loss_for(cfg) -> Callable:
    if getattr(cfg, "enc_dec", False):
        return lambda p, b: encdec.loss_fn(p, cfg, b)
    if cfg.__class__.__name__ == "MobileNetConfig":
        return lambda p, b: mobilenet.loss_fn(p, cfg, b)
    return lambda p, b: transformer.loss_fn(p, cfg, b)


def init_state(params, bf16_params: bool = False) -> dict:
    if bf16_params:
        compute = jax.tree_util.tree_map(
            lambda x: x.astype(jnp.bfloat16)
            if x.dtype == jnp.float32 and x.ndim >= 1 else x, params)
        return {"params": compute, "opt": adamw.init(params, keep_master=True)}
    return {"params": params, "opt": adamw.init(params)}


def _split_batch(batch, n):
    return [jax.tree_util.tree_map(
        lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:])[i], batch)
        for i in range(n)]


def make_train_step(model_cfg, tcfg: TrainConfig = TrainConfig()):
    loss_fn = loss_for(model_cfg)
    if tcfg.schedule == "wsd":
        sched = schedules.make(
            "wsd", peak_lr=tcfg.peak_lr, warmup=tcfg.warmup,
            stable=int(tcfg.total_steps * 0.8), decay=int(tcfg.total_steps * 0.1))
    else:
        sched = schedules.make("cosine", peak_lr=tcfg.peak_lr,
                               warmup=tcfg.warmup, total=tcfg.total_steps)

    def train_step(state: dict, batch: dict):
        params = state["params"]
        if tcfg.n_microbatches > 1:
            micro = _split_batch(batch, tcfg.n_microbatches)

            def acc_body(carry, mb):
                loss_acc, grad_acc = carry
                loss_mb, g = jax.value_and_grad(loss_fn)(params, mb)
                return (loss_acc + loss_mb,
                        jax.tree_util.tree_map(jnp.add, grad_acc, g)), None

            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *micro)
            (loss, grads), _ = jax.lax.scan(acc_body,
                                            (jnp.zeros(()), zero_g), stacked)
            loss = loss / tcfg.n_microbatches
            grads = jax.tree_util.tree_map(
                lambda g: g / tcfg.n_microbatches, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        lr = sched(state["opt"]["step"])
        new_params, new_opt, gnorm = adamw.update(params, grads, state["opt"],
                                                  lr, tcfg.adamw)
        if tcfg.qat_project:
            from repro.core.quantization import W4, fake_quant
            def proj(path, leaf):
                name = jax.tree_util.keystr(path)
                if name.endswith("['w']") and leaf.ndim >= 2:
                    return fake_quant(leaf, W4)
                return leaf
            new_params = jax.tree_util.tree_map_with_path(proj, new_params)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step
