"""Per-cell lowering specs: the function to lower, ShapeDtypeStruct inputs,
and in/out shardings for every (arch x shape x mesh) combination.

Nothing here allocates device memory — params/state/caches are eval_shape'd
(the shannon/kernels ShapeDtypeStruct pattern).
"""
from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs
from repro.dist import partitioning
from repro.dist.sharding import Rules
from repro.models import encdec, transformer
from repro.train.step import TrainConfig, init_state, make_train_step

SDS = jax.ShapeDtypeStruct


def _named(mesh: Mesh, tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    names = entry if isinstance(entry, (tuple, list)) else (entry,)
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return n


def validate_specs(sds_tree, spec_tree, mesh: Mesh):
    """Drop spec axes whose dimension is not divisible by the mesh axes.

    pjit in/out shardings require exact divisibility (unlike internal
    with_sharding_constraint, which GSPMD pads).  Non-divisible cases —
    GQA KV heads (4/8/10/20 over model=16), MiniCPM's 122753 vocab — fall
    back to replication on that dim; DESIGN.md notes the cost.
    """
    def fix(sds, spec):
        if not isinstance(spec, P):
            return spec
        parts = list(spec) + [None] * (len(sds.shape) - len(spec))
        out = []
        for dim, entry in zip(sds.shape, parts):
            if entry is not None and dim % _axis_size(mesh, entry) != 0:
                entry = None
            out.append(entry)
        return P(*out)

    return jax.tree_util.tree_map(fix, sds_tree, spec_tree,
                                  is_leaf=lambda x: isinstance(
                                      x, jax.ShapeDtypeStruct))


def _batch_axes(rules: Rules):
    return rules.get("batch")


def cache_specs(cache_sds, rules: Rules, mesh: Mesh | None = None) -> Any:
    """PartitionSpec tree for a decode cache (by leaf name/rank).

    KV leaves prefer head sharding; when the arch's kv-head count does not
    divide the model axis (GQA: 4/8/10/20/36 vs 16), the cache falls back to
    *sequence-over-model* sharding — attention then contracts over a sharded
    T axis (partial-softmax + small all-reduce), which is the right serving
    layout for kv-head-poor models (fixes e.g. minicpm decode_32k going from
    a replicated 388 GB/device cache to a fully sharded one).
    """
    b = rules.get("batch")
    kvh = rules.get("kv_heads")
    h = rules.get("heads")
    m = rules.get("mlp")
    seq_kv = rules.get("seq_kv")

    def _kv_spec(x):
        T_dim, H_dim = x.shape[-3], x.shape[-2]
        kv_ok = (mesh is None or kvh is None
                 or (H_dim % _axis_size(mesh, kvh) == 0))
        if kv_ok:
            return (b, seq_kv, kvh, None)
        # fall back: shard T over the model axis (plus any seq_kv axes)
        model_ax = kvh
        seq_axes = []
        for ax in (seq_kv, model_ax):
            if ax is None:
                continue
            seq_axes.extend(ax if isinstance(ax, (tuple, list)) else (ax,))
        seq_entry = tuple(seq_axes) if seq_axes else None
        if seq_entry is not None and mesh is not None \
                and T_dim % _axis_size(mesh, seq_entry) != 0:
            seq_entry = None
        return (b, seq_entry, None, None)

    def leaf(path, x):
        name = jax.tree_util.keystr(path)
        nd = x.ndim
        if re.search(r"'(k_scale|v_scale)'", name) and nd >= 3:
            # int8-KV scales [..., B, T, Hkv] — shard like the cache minus D
            fake = jax.ShapeDtypeStruct(x.shape + (1,), x.dtype)
            spec = _kv_spec(fake)[:-1]
        elif re.search(r"(shared_k|shared_v|'k'|'v'|xk|xv)", name) and nd >= 4:
            # [..., B, T, Hkv, D]
            spec = _kv_spec(x)
        elif re.search(r"'h'", name) and nd >= 4:        # mamba [.., B,H,N,P]
            spec = (b, h, None, None)
        elif re.search(r"'S'", name) and nd >= 4:        # rwkv  [.., B,H,K,V]
            spec = (b, h, None, None)
        elif re.search(r"'conv'", name):                 # [.., B, 3, C]
            spec = (b, None, m)
        elif re.search(r"'(xt|xc)'", name):              # [.., B, 1, d]
            spec = (b, None, None)
        else:
            spec = (None,) * nd
        pad = (None,) * (nd - len(spec))
        return P(*(pad + tuple(spec)))

    return jax.tree_util.tree_map_with_path(leaf, cache_sds)


# decode-cache leaves that hold per-head KV state: [.., B, T, H, D] tensors
# and their int8-KV [.., B, T, H] scale companions (exact key names — mamba
# "h" / rwkv "S" recurrent states must NOT match)
KV_CACHE_LEAVES = frozenset({"k", "v", "shared_k", "shared_v", "xk", "xv"})
KV_SCALE_LEAVES = frozenset({"k_scale", "v_scale"})


def _leaf_key(path) -> str:
    last = path[-1]
    return getattr(last, "key", getattr(last, "name", ""))


def serving_cache_specs(cache_sds, data_axis: str | None,
                        model_axis: str | None):
    """PartitionSpec tree for the serving engine's decode cache.

    Every per-slot buffer splits its batch axis over ``data_axis`` (each
    data shard runs an independent slot pool).  When ``model_axis`` is given
    (head-sharded attention: ``n_heads`` and ``n_kv`` both divide the model
    axis), KV leaves additionally split their head axis over it, so the
    per-shard KV cache holds ``n_kv / tp`` heads.  Pass ``None`` for a
    size-1 axis — specs stay in the canonical (elided) form XLA hands back
    on computation outputs, preserving the no-retrace invariant.

    The SAME specs cover both cache layouts because they were designed to
    line up: dense KV leaves are ``[G, slots, T, H, D]`` and paged pools
    (``serve.paged``) are ``[G, num_pages, page_size, H, D]`` — dim 1 is
    the data-split axis either way (slots, or pool pages with shard-local
    page ids) and dim 3 is the head axis.  Page tables themselves are
    per-slot ``[slots, E]`` vectors and ride the engine's slot-state spec
    (``P(data)``), not this tree.
    """
    def leaf(path, x):
        key = _leaf_key(path)
        if model_axis is not None and x.ndim >= 5 \
                and key in KV_CACHE_LEAVES:
            return P(None, data_axis, None, model_axis)
        if model_axis is not None and x.ndim >= 4 \
                and key in KV_SCALE_LEAVES:
            return P(None, data_axis, None, model_axis)
        return P(None, data_axis) if data_axis is not None else P()

    return jax.tree_util.tree_map_with_path(leaf, cache_sds)


def serving_chunk_specs():
    """PartitionSpec tuple for the unified step's chunk-entry lane:
    ``(slot, tok, pos, first, budget_one)``, each ``[prefill_chunk]``.

    All five are REPLICATED.  The slot column carries GLOBAL row ids; each
    data shard's step impl matches them against its own
    ``arange(local_slots) + axis_index(data) * local_slots`` rows, so
    non-owning shards see all-False targets and run idempotent no-op
    iterations.  Splitting these vectors over the data axis instead would
    force the host to route entries per shard and break the fixed
    ``[prefill_chunk]`` dispatch shape."""
    return (P(), P(), P(), P(), P())


def batch_specs(batch_sds, rules: Rules):
    b = rules.get("batch")

    def leaf(path, x):
        return P(*((b,) + (None,) * (x.ndim - 1)))
    return jax.tree_util.tree_map_with_path(leaf, batch_sds)


def make_batch_sds(cfg, shape: configs.ShapeSpec) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if getattr(cfg, "enc_dec", False):
        return {"frames": SDS((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16),
                "tokens": SDS((B, S), jnp.int32),
                "labels": SDS((B, S), jnp.int32)}
    batch = {"tokens": SDS((B, S), jnp.int32), "labels": SDS((B, S), jnp.int32)}
    if cfg.family == "vlm":
        batch["embeddings"] = SDS((B, S, cfg.d_model), jnp.bfloat16)
        batch["mrope_positions"] = SDS((B, S, 3), jnp.int32)
    return batch


# microbatch accumulation per arch for train_4k: chosen so the remat residual
# footprint (B_mb x S x d x 2 bytes x n_groups) stays well under HBM
TRAIN_MICROBATCHES = {
    "mixtral-8x22b": 16, "qwen2-vl-72b": 16, "phi3-medium-14b": 8,
    "qwen2-7b": 8, "zamba2-2.7b": 4, "gemma2-2b": 4, "minicpm-2b": 4,
    "rwkv6-1.6b": 4, "qwen2-moe-a2.7b": 4, "whisper-large-v3": 4,
}


def build_cell(arch: str, shape_name: str, mesh: Mesh, rules: Rules,
               train_cfg: TrainConfig | None = None,
               quant: str = "none", unroll: bool = True,
               cfg_overrides: dict | None = None):
    """Returns dict(fn, args_sds, in_shardings, out_shardings, cfg).

    ``cfg_overrides`` keys are split between ModelConfig and TrainConfig
    fields (hillclimbing plumbing: ``--set bf16_params=true`` etc.).
    """
    cfg = configs.get_config(arch, quant=quant)
    import dataclasses as _dc
    over = {"unroll_groups": unroll}
    if cfg_overrides:
        over.update(cfg_overrides)
    tc_fields = {f.name for f in _dc.fields(TrainConfig)}
    tc_over = {k: v for k, v in over.items() if k in tc_fields}
    over = {k: v for k, v in over.items()
            if k in {f.name for f in _dc.fields(cfg)}}
    cfg = _dc.replace(cfg, **over)
    shape = configs.SHAPES[shape_name]
    ok, reason = configs.shape_applicable(cfg, shape)
    if not ok:
        return {"skip": reason, "cfg": cfg}
    is_encdec = getattr(cfg, "enc_dec", False)
    init_fn = encdec.init_params if is_encdec else transformer.init_params
    params_sds = jax.eval_shape(lambda: init_fn(jax.random.PRNGKey(0), cfg))
    pspecs = partitioning.param_specs(params_sds, rules)

    if shape.kind == "train":
        import dataclasses as _dc2
        tcfg = train_cfg or TrainConfig(
            n_microbatches=TRAIN_MICROBATCHES.get(arch, 4))
        if tc_over:
            tcfg = _dc2.replace(tcfg, **tc_over)
        if tcfg.n_microbatches > shape.global_batch:   # smoke/tiny shapes
            tcfg = _dc2.replace(tcfg, n_microbatches=max(
                1, shape.global_batch))
        step_fn = make_train_step(cfg, tcfg)
        state_sds = jax.eval_shape(
            lambda p: init_state(p, tcfg.bf16_params), params_sds)
        sspecs = validate_specs(state_sds,
                                partitioning.state_specs(state_sds, rules),
                                mesh)
        batch_sds = make_batch_sds(cfg, shape)
        bspecs = validate_specs(batch_sds, batch_specs(batch_sds, rules), mesh)
        metrics_spec = {"loss": P(), "grad_norm": P(), "lr": P()}
        return {
            "fn": step_fn,
            "args_sds": (state_sds, batch_sds),
            "in_shardings": (_named(mesh, sspecs), _named(mesh, bspecs)),
            "out_shardings": (_named(mesh, sspecs), _named(mesh, metrics_spec)),
            "cfg": cfg, "kind": "train",
        }

    # inference cells use bf16 params; quantized serving stores integer
    # weight codes + fp32 scales (serve/quantize.py — the paper's technique)
    params_sds = jax.tree_util.tree_map(
        lambda s: SDS(s.shape, jnp.bfloat16)
        if s.dtype == jnp.float32 and s.ndim >= 1 else s, params_sds)
    if quant != "none":
        from repro.serve.quantize import quantize_params_for_serving
        params_sds = jax.eval_shape(
            lambda p: quantize_params_for_serving(p, mode=quant), params_sds)
    pspecs = partitioning.param_specs(params_sds, rules)
    serve_cfg = cfg

    if shape.kind == "prefill":
        B, S = shape.global_batch, shape.seq_len
        if is_encdec:
            def fn(params, frames, tokens):
                return encdec.prefill(params, serve_cfg, frames, tokens)
            args = (params_sds, SDS((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16),
                    SDS((B, S), jnp.int32))
            arg_specs = (pspecs, P(rules.get("batch"), None, None),
                         P(rules.get("batch"), None))
        elif cfg.family == "vlm":
            def fn(params, embeddings, mrope_positions):
                return transformer.prefill(params, serve_cfg, None,
                                           embeddings=embeddings,
                                           mrope_positions=mrope_positions)
            args = (params_sds, SDS((B, S, cfg.d_model), jnp.bfloat16),
                    SDS((B, S, 3), jnp.int32))
            arg_specs = (pspecs, P(rules.get("batch"), None, None),
                         P(rules.get("batch"), None, None))
        else:
            def fn(params, tokens):
                return transformer.prefill(params, serve_cfg, tokens)
            args = (params_sds, SDS((B, S), jnp.int32))
            arg_specs = (pspecs, P(rules.get("batch"), None))
        out_sds = jax.eval_shape(fn, *args)
        logits_spec = validate_specs(
            out_sds[0], P(rules.get("batch"), rules.get("vocab")), mesh)
        cspecs = validate_specs(out_sds[1],
                                cache_specs(out_sds[1], rules, mesh),
                                mesh)
        arg_specs = tuple(validate_specs(a, s, mesh)
                          for a, s in zip(args, arg_specs))
        return {
            "fn": fn, "args_sds": args,
            "in_shardings": tuple(_named(mesh, s) for s in arg_specs),
            "out_shardings": (_named(mesh, logits_spec), _named(mesh, cspecs)),
            "cfg": cfg, "kind": "prefill",
        }

    # decode: one token with a cache of seq_len
    B, S = shape.global_batch, shape.seq_len
    if is_encdec:
        cache_sds = jax.eval_shape(
            lambda: encdec.init_cache(serve_cfg, B, S))
        def fn(params, token, cache, pos):
            return encdec.decode_step(params, serve_cfg, token, cache, pos)
    else:
        cache_sds = jax.eval_shape(
            lambda: transformer.init_cache(serve_cfg, B, S))
        def fn(params, token, cache, pos):
            return transformer.decode_step(params, serve_cfg, token, cache, pos)
    cspecs = validate_specs(cache_sds,
                            cache_specs(cache_sds, rules, mesh), mesh)
    args = (params_sds, SDS((B,), jnp.int32), cache_sds, SDS((), jnp.int32))
    arg_specs = (validate_specs(params_sds, pspecs, mesh),
                 validate_specs(args[1], P(rules.get("batch")), mesh),
                 cspecs, P())
    logits_spec = validate_specs(SDS((B, cfg.vocab), jnp.float32),
                                 P(rules.get("batch"), rules.get("vocab")),
                                 mesh)
    return {
        "fn": fn, "args_sds": args,
        "in_shardings": tuple(_named(mesh, s) for s in arg_specs),
        "out_shardings": (_named(mesh, logits_spec), _named(mesh, cspecs)),
        "cfg": cfg, "kind": "decode",
    }
