"""Production mesh + per-cell sharding rules.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single pod: (16, 16) ("data", "model") = 256 chips;
multi-pod: (2, 16, 16) ("pod", "data", "model") = 512 chips.
"""
from __future__ import annotations

import jax

from repro.dist.sharding import Rules, make_mesh, production_rules


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for subprocess integration tests (8 fake devices)."""
    return make_mesh((n_data, n_model), ("data", "model"))


def parse_mesh(spec: str) -> tuple[int, int]:
    """``"DxM"`` -> (data, model) axis sizes (e.g. ``"2x4"`` -> (2, 4))."""
    try:
        d, m = spec.lower().split("x")
        d, m = int(d), int(m)
    except ValueError:
        raise ValueError(f"mesh spec must look like '2x4', got {spec!r}")
    if d < 1 or m < 1:
        raise ValueError(f"mesh axes must be positive, got {spec!r}")
    return d, m


def make_serving_mesh(spec: str):
    """(data, model) mesh for ``serve.sharded.ShardedEngine`` from a "DxM"
    string.  Works on CPU hosts via the CI recipe
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``."""
    n_data, n_model = parse_mesh(spec)
    need = n_data * n_model
    if need > jax.device_count():
        raise ValueError(
            f"mesh {spec} needs {need} devices but only "
            f"{jax.device_count()} are visible; on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need}")
    return make_mesh((n_data, n_model), ("data", "model"))


# Archs whose bf16 weights exceed comfortable TP-only residency -> shard
# params over "data" too when serving (FSDP-style serving).
FSDP_SERVE_ARCHS = {"mixtral-8x22b", "qwen2-vl-72b", "phi3-medium-14b"}
# MoE expert placement: 60 experts -> EP over model axis (pad 60->64);
# 8 experts -> TP inside experts (ff over model) instead.
MOE_EP_ARCHS = {"qwen2-moe-a2.7b"}


def rules_for(cfg, shape_kind: str, shape_name: str, *,
              multi_pod: bool = False, overrides: dict | None = None) -> Rules:
    """Sharding-rule table for one (arch x shape) cell."""
    r = production_rules(multi_pod)
    if shape_kind == "train":
        r["fsdp"] = "data"          # ZeRO-style param+opt sharding everywhere
    else:
        r["fsdp"] = "data" if cfg.name in FSDP_SERVE_ARCHS else None
    if getattr(cfg, "moe", None) is not None:
        if cfg.name in MOE_EP_ARCHS:
            r["expert"], r["expert_mlp"] = "model", None
            r["moe_capacity"] = None
        else:
            # TP-mode MoE: ff over model, capacity (token) dim over data
            r["expert"], r["expert_mlp"] = None, "model"
            r["moe_capacity"] = "data"
    if shape_name == "long_500k":
        # batch=1: shard the KV/sequence dimension over "data" instead
        r["batch"] = None
        r["seq_kv"] = ("pod", "data") if multi_pod else ("data",)
    else:
        r["seq_kv"] = None
    if overrides:
        r.update(overrides)
    return r
