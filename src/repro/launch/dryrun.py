import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes, dump memory/cost/collective analysis to JSON.

Must be run as a script/subprocess (it forces 512 host devices before any jax
import).  ``--all`` orchestrates one subprocess per cell so a pathological
compile can't take the whole sweep down, and cells run in parallel.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--jobs 6]
"""
import argparse          # noqa: E402
import json              # noqa: E402
import subprocess        # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402

import jax               # noqa: E402


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_path: str,
             quant: str = "none", rule_overrides: dict | None = None,
             cfg_overrides: dict | None = None) -> dict:
    from repro import configs
    from repro.dist.sharding import use_rules
    from repro.launch.mesh import make_production_mesh, rules_for
    from repro.launch.specs import build_cell
    from repro.roofline import analysis

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = configs.get_config(arch, quant=quant)
    shape = configs.SHAPES[shape_name]
    rules = rules_for(cfg, shape.kind, shape_name, multi_pod=multi_pod,
                      overrides=rule_overrides)
    record = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "mesh": list(mesh.devices.shape), "quant": quant,
        "n_devices": mesh.devices.size,
        "rule_overrides": rule_overrides or {},
        "cfg_overrides": cfg_overrides or {},
    }
    def _mem_record(compiled):
        mem = compiled.memory_analysis()
        if mem is None:
            return None
        return {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "total_per_device_bytes": (mem.argument_size_in_bytes
                                       + mem.output_size_in_bytes
                                       + mem.temp_size_in_bytes
                                       - mem.alias_size_in_bytes),
        }

    def _compile(cell):
        # donate the train state / decode cache: in-place update halves the
        # in+out residency (the output aliases the input buffers)
        donate = ()
        if cell["kind"] == "train":
            donate = (0,)
        elif cell["kind"] == "decode":
            donate = (2,)
        jf = jax.jit(cell["fn"], in_shardings=cell["in_shardings"],
                     out_shardings=cell["out_shardings"],
                     donate_argnums=donate)
        lowered = jf.lower(*cell["args_sds"])
        return lowered.compile()

    with mesh, use_rules(rules, mesh):
        shape = configs.SHAPES[shape_name]
        is_train = shape.kind == "train"
        from repro.train.step import TrainConfig

        # ---- exec variant: the FULL production program (scan over groups,
        # microbatched train step). This is the required .lower().compile()
        # proof and the real per-device memory footprint.
        cell = build_cell(arch, shape_name, mesh, rules, quant=quant,
                          unroll=False, cfg_overrides=cfg_overrides)
        if "skip" in cell:
            record["status"] = "skipped"
            record["reason"] = cell["skip"]
            _dump(out_path, record)
            return record
        record["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = _compile(cell)
        record["compile_s"] = round(time.time() - t1, 1)
        record["memory"] = _mem_record(compiled)
        if is_train:
            from repro.launch.specs import TRAIN_MICROBATCHES
            record["exec_microbatches"] = TRAIN_MICROBATCHES.get(arch, 4)

        # ---- cost variants: cost_analysis counts scan bodies ONCE, so we
        # compile 1-group and 2-group UNROLLED programs; the (2g - 1g) delta
        # is the exact per-group cost and extrapolates linearly to G groups
        # (embed/head/loss terms cancel in the delta). Train cost variants
        # drop the microbatch loop for the same reason.
        full_cfg = cell["cfg"]
        P = len(getattr(full_cfg, "pattern", (None,)))
        G = getattr(full_cfg, "n_groups", full_cfg.n_layers)
        t2 = time.time()

        def _cost_terms(n_groups: int):
            over = dict(cfg_overrides or {})
            over["n_layers"] = n_groups * P
            if getattr(full_cfg, "enc_dec", False):
                over["n_enc_layers"] = n_groups
            c = build_cell(arch, shape_name, mesh, rules, quant=quant,
                           unroll=True, cfg_overrides=over,
                           train_cfg=TrainConfig(n_microbatches=1)
                           if is_train else None)
            comp = _compile(c)
            return analysis.roofline_terms(comp.cost_analysis() or {},
                                           comp.as_text())

        t1g = _cost_terms(1)
        t2g = _cost_terms(2)
        record["cost_compile_s"] = round(time.time() - t2, 1)
        terms = analysis.extrapolate_terms(t1g, t2g, G)
        record["roofline"] = terms
        record["roofline_1g"] = {k: v for k, v in t1g.items()
                                 if not isinstance(v, (dict, list))}
        record["top_collectives_2g"] = t2g.get("top_collectives", [])

        # MODEL_FLOPS bookkeeping
        moe = getattr(cell["cfg"], "moe", None)
        counts = analysis.count_params(
            cell["args_sds"][0]["params"] if cell["kind"] == "train"
            else cell["args_sds"][0],
            moe_top_k=(moe.top_k if moe else None),
            n_experts=(moe.n_experts if moe else None))
        sh = configs.SHAPES[shape_name]
        mf = analysis.model_flops(cell["kind"], counts["active"],
                                  sh.global_batch, sh.seq_len)
        hlo_total = terms["hlo_flops_per_device"] * mesh.devices.size
        record["params"] = counts
        record["model_flops_global"] = mf
        record["model_vs_hlo_flops"] = (mf / hlo_total) if hlo_total else None
        record["status"] = "ok"
    _dump(out_path, record)
    return record


def _dump(path: str, record: dict):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1, default=str)


def _cell_list():
    from repro import configs
    return [(a, s) for a in configs.ALIASES if a != "mobilenetv2"
            for s in configs.SHAPES]


def orchestrate(args) -> int:
    cells = _cell_list()
    if args.arch:
        cells = [c for c in cells if c[0] == args.arch]
    jobs: list[tuple[subprocess.Popen, str, str]] = []
    failures = []
    pending = list(cells)
    out_dir = args.out_dir
    while pending or jobs:
        while pending and len(jobs) < args.jobs:
            arch, shape = pending.pop(0)
            tag = f"{arch}__{shape}__{'mp' if args.multi_pod else 'sp'}"
            out = os.path.join(out_dir, tag + ".json")
            if args.skip_existing and os.path.exists(out):
                print(f"[skip existing] {tag}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--out", out,
                   "--quant", args.quant]
            if args.multi_pod:
                cmd.append("--multi-pod")
            log = open(os.path.join(out_dir, tag + ".log"), "w")
            jobs.append((subprocess.Popen(cmd, stdout=log, stderr=log), tag, out))
            print(f"[launch] {tag}")
        still = []
        for proc, tag, out in jobs:
            rc = proc.poll()
            if rc is None:
                still.append((proc, tag, out))
            elif rc != 0:
                failures.append(tag)
                print(f"[FAIL rc={rc}] {tag}")
            else:
                print(f"[done] {tag}")
        jobs = still
        time.sleep(2)
    print(f"finished; {len(failures)} failures: {failures}")
    return 1 if failures else 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=6)
    ap.add_argument("--quant", default="none")
    ap.add_argument("--out", default=None)
    ap.add_argument("--out-dir", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (hillclimbing)")
    ap.add_argument("--rule", action="append", default=[],
                    help="sharding-rule override key=value|none")
    args = ap.parse_args()
    if args.all:
        sys.exit(orchestrate(args))

    def _parse_kv(items):
        out = {}
        for it in items:
            k, v = it.split("=", 1)
            if v.lower() in ("none", "null"):
                out[k] = None
            elif v.lower() in ("true", "false"):
                out[k] = v.lower() == "true"
            else:
                try:
                    out[k] = int(v)
                except ValueError:
                    try:
                        out[k] = float(v)
                    except ValueError:
                        out[k] = tuple(v.split("+")) if "+" in v else v
        return out

    out = args.out or os.path.join(
        args.out_dir,
        f"{args.arch}__{args.shape}__{'mp' if args.multi_pod else 'sp'}.json")
    rec = run_cell(args.arch, args.shape, args.multi_pod, out,
                   quant=args.quant, rule_overrides=_parse_kv(args.rule),
                   cfg_overrides=_parse_kv(args.set))
    status = rec.get("status")
    print(json.dumps(rec, indent=1, default=str)[:2000])
    if status not in ("ok", "skipped"):
        sys.exit(1)


if __name__ == "__main__":
    main()
