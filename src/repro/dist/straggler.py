"""Straggler detection for multi-host steps.

Hosts report per-step wall time via :meth:`StragglerMonitor.record`;
:meth:`evaluate` compares each host's recent mean against the across-host
median.  A host whose ratio exceeds ``threshold`` earns a strike; ``patience``
consecutive strikes puts it on the exclude list (the supervisor's signal to
drop/replace the node).  Recovering for one evaluation clears the strikes.
"""
from __future__ import annotations

import collections
import dataclasses
import statistics


@dataclasses.dataclass
class StragglerConfig:
    threshold: float = 1.5      # slow if mean step time > threshold * median
    patience: int = 3           # consecutive slow evaluations before exclude
    window: int = 32            # per-host samples kept


class StragglerMonitor:
    def __init__(self, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self._times: dict[str, collections.deque] = {}
        self._strikes: dict[str, int] = {}

    def record(self, host: str, step_seconds: float) -> None:
        self._times.setdefault(
            host, collections.deque(maxlen=self.cfg.window)).append(
                float(step_seconds))

    def evaluate(self) -> dict:
        """Returns {"slow": {host: ratio}, "exclude": [host...], "median"}."""
        means = {h: statistics.fmean(t) for h, t in self._times.items() if t}
        if not means:
            return {"slow": {}, "exclude": [], "median": None}
        med = statistics.median(means.values())
        slow = {}
        for h, m in means.items():
            ratio = m / med if med > 0 else 1.0
            if ratio > self.cfg.threshold:
                slow[h] = ratio
                self._strikes[h] = self._strikes.get(h, 0) + 1
            else:
                self._strikes[h] = 0
        exclude = sorted(h for h, s in self._strikes.items()
                         if s >= self.cfg.patience)
        return {"slow": slow, "exclude": exclude, "median": med}
