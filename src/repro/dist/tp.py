"""Tensor-parallel plumbing for the quantized/LUT matmul layers.

The paper's scale-out story — fan the multiplication across ~100x more cheap
LUT multipliers instead of making one DSP faster — maps onto devices here:
the integer weight codes of every projection are split across the ``model``
mesh axis and each device runs its share of the LUT contraction.

Four layouts (classic Megatron, adapted to integer codes):

  * **column-parallel** (``tp_col``): the weight keeps full K rows; codes and
    per-channel scales are split along N.  Every device computes its output
    columns with *exactly* the math the single-device kernel runs, then an
    ``all_gather`` rebuilds the full activation.
  * **row-parallel** (``tp_row``): codes split along K.  The activation
    quantization scale is taken over the FULL activation vector
    — identical to the single-device scale — each device contracts its K
    slice into an int32 partial accumulator, and a ``psum`` adds the
    partials.  int32 addition is associative and exact, so the accumulated
    value (and the fp32 dequant epilogue applied to it) is bit-identical to
    the unsharded kernel.  This is why only *integer-code* layers are
    sharded row-parallel: a float row-parallel matmul would reassociate an
    fp32 reduction and drift.
  * **head-parallel** (``tp_head``): column-parallel *without the gather* —
    QKV projections keep their local output columns, which are whole
    attention heads, so attention itself (scores, softmax, KV cache, ring
    writes) runs on ``n_heads / tp`` local heads per shard.  Every head's
    math is independent, so the local heads are a bitwise slice of the
    replicated computation.  The head-local attention output feeds the
    row-parallel ``wo`` directly (its K slice *is* the local heads); the
    full-K activation scale is recovered exactly via a ``pmax`` of the
    per-shard maxima (max is associative and exact).  Applied only when
    both ``n_heads`` and ``n_kv`` divide the model axis — GQA configs with
    ``n_kv % tp != 0`` fall back to the replicated-attention col/row
    marking above (correct, just redundant attention FLOPs).  The 3D
    split-head float variants (``wq3``/``wk3``/``wv3``) are head-parallel
    too (an exact column split over the head axis); ``wo3`` stays
    replicated — a float psum would drift — so the attention output is
    all-gathered back to full heads in front of it.
  * **expert-parallel** (``tp_exp``): MoE expert banks ``[E, d, f]`` split
    along the expert axis.  Router logits (and therefore top-k expert
    choice, gates, and capacity positions) stay replicated and
    bit-identical; each shard runs only its ``E / tp`` local experts and an
    ``all_gather`` over the expert axis rebuilds the full expert-output
    buffer, after which the combine runs the unsharded math.  Applied only
    when ``E % tp == 0``; otherwise the bank stays replicated.

Leaves are tagged structurally: :func:`mark_tp_params` inserts a zero-size
``tp_col``/``tp_row``/``tp_head``/``tp_exp`` marker array into each sharded
leaf dict.  Key presence is static pytree structure, so
``models.layers.linear`` can read the layout under ``jit``/``shard_map``
tracing with no runtime cost, and the markers scan/stitch like any other
(empty) leaf.

The context (:func:`tp_context`) is installed by the sharded engine around
its ``shard_map`` bodies at trace time; outside it every hook here is the
identity, so single-device code pays nothing.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# parent-key names whose quantized leaves are output projections: codes split
# along the contracting dim (K) with an exact int32 psum.  Everything else
# eligible defaults to column-parallel (split N, gather), which is correct
# for any projection.
_ROW_PARALLEL_NAMES = frozenset({"wo", "out_proj"})
# leaves under these parent keys never shard (embeddings are a table lookup)
_SKIP_NAMES = frozenset({"embed"})
# the QKV projections that go head-parallel when the head counts divide
_HEAD_COL_NAMES = ("wq", "wk", "wv")
_HEAD_COL_3D = ("wq3", "wk3", "wv3")
# direct children of a "moe" dict that are stacked expert banks [.., E, d, f]
_EXPERT_BANK_NAMES = frozenset({"wi", "wg", "wo"})

_CTX: list[tuple[str, int, Optional[str]]] = []


@contextlib.contextmanager
def tp_context(model_axis: str, model_size: int,
               data_axis: Optional[str] = None):
    """Activate tensor-parallel dispatch for code traced inside this block
    (the sharded engine wraps its ``shard_map`` bodies with it)."""
    _CTX.append((model_axis, model_size, data_axis))
    try:
        yield
    finally:
        _CTX.pop()


def model_axis() -> Optional[str]:
    return _CTX[-1][0] if _CTX else None


def model_size() -> int:
    return _CTX[-1][1] if _CTX else 1


def data_axis() -> Optional[str]:
    """The data mesh axis active inside a tp_context (None outside one or
    when no data axis is configured) — the unified serving step uses it to
    turn local batch rows into global slot ids."""
    return _CTX[-1][2] if _CTX else None


def fold_in_data(key: jax.Array) -> jax.Array:
    """Give each data shard its own sampling stream (identity outside the
    context or when no data axis is configured).  Greedy decode never reads
    the key, so temperature-0 bit-identity is unaffected."""
    if not _CTX or _CTX[-1][2] is None:
        return key
    return jax.random.fold_in(key, jax.lax.axis_index(_CTX[-1][2]))


def leaf_tp_mode(p: dict) -> Optional[str]:
    """Static layout of a (possibly marked) param leaf dict."""
    if "tp_col" in p:
        return "col"
    if "tp_row" in p:
        return "row"
    if "tp_head" in p:
        return "head"
    if "tp_exp" in p:
        return "exp"
    return None


def head_shardable(n_heads: int, n_kv: int, n_model: int) -> bool:
    """True when attention can run on local heads: every shard gets whole
    Q heads AND whole KV heads.  ``n_kv % n_model != 0`` (GQA with few KV
    heads) falls back to replicated attention — sharding Q but replicating
    KV would straddle the grouped-head reshape."""
    return n_model > 1 and n_heads % n_model == 0 and n_kv % n_model == 0


# ---------------------------------------------------------------------------
# parameter marking + spec derivation
# ---------------------------------------------------------------------------

def _divisible(leaf: dict, mode: str, n_model: int) -> bool:
    w_q = leaf["w_q"]
    if w_q.ndim < 2:
        return False
    if mode == "row":
        # packed int4 rows are K//2: splitting rows evenly keeps every
        # shard's K slice even, so nibble pairs never straddle a boundary
        return w_q.shape[-2] % n_model == 0
    return w_q.shape[-1] % n_model == 0


def _tail(ndim: int, *entries) -> P:
    """Right-aligned PartitionSpec: the trailing dims get ``entries``, any
    leading (stack) dims are replicated — so stacked (leading-G) block
    leaves shard the same trailing dims as unstacked ones."""
    entries = entries[-ndim:]
    return P(*(((None,) * (ndim - len(entries))) + tuple(entries)))


def _leaf_specs(leaf: dict, mode: str, axis: str) -> dict:
    """PartitionSpec per array of one sharded leaf ({"w_q","w_scale"[,"b"]}).

    Biases stay replicated for col/row (they are added after the
    gather/psum on the full output) but split along N for head-parallel
    leaves, whose output stays local.  Expert banks split the expert axis
    (-3) of codes and scales.
    """
    specs = {}
    for k, v in leaf.items():
        nd = getattr(v, "ndim", 0)
        if mode == "exp":
            specs[k] = _tail(nd, axis, None, None) \
                if k in ("w_q", "w_scale") else P()
        elif k == "w_q":
            specs[k] = _tail(nd, axis, None) if mode == "row" \
                else _tail(nd, None, axis)
        elif k == "w_scale" and mode in ("col", "head"):
            specs[k] = _tail(nd, None, axis)
        elif k == "b" and mode == "head":
            specs[k] = _tail(nd, axis)
        else:
            specs[k] = P()
    return specs


def _marker(leaf_arrays: dict, ref_key: str = "w_q"):
    """Zero-size int8 marker shaped ``leading_stack_dims + (0,)`` so it
    scans over stacked block params like any other leaf."""
    ref = leaf_arrays[ref_key]
    return jnp.zeros(ref.shape[:-2] + (0,), jnp.int8)


def _attn_head_counts(attn: dict, head_dim: int):
    """(n_heads, n_kv) of one attention param dict, from leaf shapes."""
    if "wq3" in attn:
        return attn["wq3"]["w"].shape[-2], attn["wk3"]["w"].shape[-2]
    nq = attn["wq"]["w_q"].shape[-1]
    nk = attn["wk"]["w_q"].shape[-1]
    return nq // head_dim, nk // head_dim


def _is_attn_group(v) -> bool:
    if not isinstance(v, dict):
        return False
    if all(k in v and isinstance(v[k], dict) and "w_q" in v[k]
           for k in ("wq", "wk", "wv", "wo")):
        return True
    return all(k in v and isinstance(v[k], dict) and "w" in v[k]
               for k in (*_HEAD_COL_3D, "wo3"))


def _mark_attn_heads(attn: dict, n_model: int, axis: str):
    """Head-parallel marking of one attention group (caller checked
    divisibility).  Returns (marked, specs, n_sharded)."""
    out, spec, n = dict(attn), dict(), 0
    for k, v in attn.items():
        spec[k] = jax.tree_util.tree_map(lambda _: P(), v)
    if "wq3" in attn:
        # float split-head leaves: w [.., d, H, dh] splits the head axis
        # (an exact column split); wo3 [.., H, dh, d] stays replicated —
        # attention output is all-gathered in front of it (a float psum
        # would reassociate the fp32 reduction and drift)
        for k in _HEAD_COL_3D:
            leaf = dict(attn[k])
            leaf["tp_head"] = jnp.zeros(
                leaf["w"].shape[:-3] + (0,), jnp.int8)
            out[k] = leaf
            s = {"w": _tail(leaf["w"].ndim, axis, None),
                 "tp_head": P()}
            if "b" in leaf:
                s["b"] = _tail(leaf["b"].ndim, axis, None)
            spec[k] = s
            n += 1
        return out, spec, n
    for k in _HEAD_COL_NAMES:
        leaf = dict(attn[k])
        leaf["tp_head"] = _marker(leaf)
        out[k] = leaf
        spec[k] = _leaf_specs(leaf, "head", axis)
        n += 1
    # the output projection is ordinary row-parallel: its K rows are
    # head-major, so the even K split IS the head split and the head-local
    # attention output is already each shard's K slice (detected by shape
    # in ops._row_parallel_prequant — same marker, same specs)
    leaf = dict(attn["wo"])
    leaf["tp_row"] = _marker(leaf)
    out["wo"] = leaf
    spec["wo"] = _leaf_specs(leaf, "row", axis)
    return out, spec, n + 1


def _attn_head_marking_ok(attn: dict, head_dim: Optional[int],
                          n_model: int) -> bool:
    if head_dim is None or n_model <= 1:
        return False
    nh, nkv = _attn_head_counts(attn, head_dim)
    if not head_shardable(nh, nkv, n_model):
        return False
    if "wq3" in attn:
        return True
    # every quantized leaf must split cleanly too (packed int4 wo rows are
    # K//2 = n_heads * head_dim // 2: an odd per-shard row count would
    # straddle a nibble pair)
    return all(_divisible(attn[k], "col", n_model)
               for k in _HEAD_COL_NAMES) \
        and _divisible(attn["wo"], "row", n_model)


def mark_tp_params(params, n_model: int, model_axis: str = "model",
                   head_dim: Optional[int] = None):
    """Tag every shardable quantized leaf and derive its PartitionSpecs.

    Walks the param tree for serving-code leaves (``{"w_q", "w_scale"}``,
    produced by ``serve.quantize``) whose parent key names a projection.
    Attention groups (dicts holding ``wq/wk/wv/wo`` or the 3D
    ``wq3/wk3/wv3/wo3`` variants) go **head-parallel** when ``head_dim`` is
    given and both head counts divide ``n_model`` (see module docstring);
    otherwise — and for every other projection — output projections
    (``wo``/``out_proj``) become row-parallel and the rest column-parallel.
    MoE expert banks (``wi/wg/wo`` stacks directly under a ``moe`` dict)
    split the expert axis when ``E % n_model == 0``; the router is always
    replicated so top-k expert choice stays bit-identical.  Leaves whose
    sharded dim is not divisible by ``n_model`` stay replicated (correct,
    just not distributed).

    Returns ``(marked_params, specs, n_sharded)`` — ``specs`` is a pytree of
    PartitionSpec with the same structure as ``marked_params`` (replicated
    ``P()`` everywhere that isn't a sharded code/scale).  Markers are
    zero-size int8 arrays shaped ``leading_stack_dims + (0,)`` so they scan
    over stacked block params like any other leaf.
    """
    n_sharded = 0

    def mark_expert_bank(v: dict):
        nonlocal n_sharded
        if n_model > 1 and v["w_q"].ndim >= 3 \
                and v["w_q"].shape[-3] % n_model == 0:
            leaf = dict(v)
            leaf["tp_exp"] = _marker(leaf)
            n_sharded += 1
            return leaf, _leaf_specs(leaf, "exp", model_axis)
        return v, jax.tree_util.tree_map(lambda _: P(), v)

    def walk(tree, skip=False, in_moe=False):
        nonlocal n_sharded
        if isinstance(tree, dict):
            if not skip and _is_attn_group(tree) \
                    and _attn_head_marking_ok(tree, head_dim, n_model):
                out, spec, n = _mark_attn_heads(tree, n_model, model_axis)
                n_sharded += n
                return out, spec
            out, spec = {}, {}
            for k, v in tree.items():
                if in_moe and k in _EXPERT_BANK_NAMES \
                        and isinstance(v, dict) and "w_q" in v:
                    out[k], spec[k] = mark_expert_bank(v)
                    continue
                if in_moe and k == "router":
                    # replicated router => bit-identical top-k everywhere
                    out[k], spec[k] = walk(v, skip=True)
                    continue
                if (not skip and not in_moe and isinstance(v, dict)
                        and "w_q" in v and k not in _SKIP_NAMES):
                    mode = "row" if k in _ROW_PARALLEL_NAMES else "col"
                    if n_model > 1 and _divisible(v, mode, n_model):
                        leaf = dict(v)
                        leaf["tp_" + mode] = _marker(leaf)
                        out[k] = leaf
                        spec[k] = _leaf_specs(leaf, mode, model_axis)
                        n_sharded += 1
                        continue
                out[k], spec[k] = walk(v, skip or k in _SKIP_NAMES,
                                       k == "moe")
            return out, spec
        if isinstance(tree, (tuple, list)):
            pairs = [walk(v, skip, in_moe) for v in tree]
            return (type(tree)(p[0] for p in pairs),
                    type(tree)(p[1] for p in pairs))
        return tree, P()

    marked, specs = walk(params)
    return marked, specs, n_sharded


def has_marker(params, marker: str) -> bool:
    """True if any leaf dict in ``params`` carries ``marker`` (e.g.
    ``"tp_head"`` — the sharded engine keys its cache layout off this)."""
    found = False

    def walk(tree):
        nonlocal found
        if isinstance(tree, dict):
            if marker in tree:
                found = True
                return
            for v in tree.values():
                walk(v)
        elif isinstance(tree, (tuple, list)):
            for v in tree:
                walk(v)

    walk(params)
    return found


def attn_group_counts(params) -> tuple[int, int]:
    """(attention groups, head-marked attention groups) in a marked tree.

    The sharded engine's KV-cache layout is one global choice, so head
    marking must be all-or-nothing across groups — it asserts
    ``head_marked in (0, total)`` before trusting the cache specs."""
    total = marked = 0

    def walk(tree):
        nonlocal total, marked
        if isinstance(tree, dict):
            if _is_attn_group(tree):
                total += 1
                probe = tree.get("wq", tree.get("wq3", {}))
                if "tp_head" in probe:
                    marked += 1
                return
            for v in tree.values():
                walk(v)
        elif isinstance(tree, (tuple, list)):
            for v in tree:
                walk(v)

    walk(params)
    return total, marked
