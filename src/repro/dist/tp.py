"""Tensor-parallel plumbing for the quantized/LUT matmul layers.

The paper's scale-out story — fan the multiplication across ~100x more cheap
LUT multipliers instead of making one DSP faster — maps onto devices here:
the integer weight codes of every projection are split across the ``model``
mesh axis and each device runs its share of the LUT contraction.

Two layouts (classic Megatron, adapted to integer codes):

  * **column-parallel** (``tp_col``): the weight keeps full K rows; codes and
    per-channel scales are split along N.  Every device computes its output
    columns with *exactly* the math the single-device kernel runs, then an
    ``all_gather`` rebuilds the full activation.
  * **row-parallel** (``tp_row``): codes split along K.  The activation
    quantization scale is taken over the FULL (replicated) activation vector
    — identical to the single-device scale — each device contracts its K
    slice into an int32 partial accumulator, and a ``psum`` adds the
    partials.  int32 addition is associative and exact, so the accumulated
    value (and the fp32 dequant epilogue applied to it) is bit-identical to
    the unsharded kernel.  This is why only *integer-code* layers are
    sharded: a float row-parallel matmul would reassociate an fp32 reduction
    and drift.

Leaves are tagged structurally: :func:`mark_tp_params` inserts a zero-size
``tp_col``/``tp_row`` marker array into each sharded leaf dict.  Key presence
is static pytree structure, so ``models.layers.linear`` can read the layout
under ``jit``/``shard_map`` tracing with no runtime cost, and the markers
scan/stitch like any other (empty) leaf.

The context (:func:`tp_context`) is installed by the sharded engine around
its ``shard_map`` bodies at trace time; outside it every hook here is the
identity, so single-device code pays nothing.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# parent-key names whose quantized leaves are output projections: codes split
# along the contracting dim (K) with an exact int32 psum.  Everything else
# eligible defaults to column-parallel (split N, gather), which is correct
# for any projection.
_ROW_PARALLEL_NAMES = frozenset({"wo", "out_proj"})
# leaves under these parent keys never shard (embeddings are a table lookup;
# MoE banks are 3D expert stacks routed by moe_ffn, out of scope here)
_SKIP_NAMES = frozenset({"embed", "moe"})

_CTX: list[tuple[str, int, Optional[str]]] = []


@contextlib.contextmanager
def tp_context(model_axis: str, model_size: int,
               data_axis: Optional[str] = None):
    """Activate tensor-parallel dispatch for code traced inside this block
    (the sharded engine wraps its ``shard_map`` bodies with it)."""
    _CTX.append((model_axis, model_size, data_axis))
    try:
        yield
    finally:
        _CTX.pop()


def model_axis() -> Optional[str]:
    return _CTX[-1][0] if _CTX else None


def model_size() -> int:
    return _CTX[-1][1] if _CTX else 1


def fold_in_data(key: jax.Array) -> jax.Array:
    """Give each data shard its own sampling stream (identity outside the
    context or when no data axis is configured).  Greedy decode never reads
    the key, so temperature-0 bit-identity is unaffected."""
    if not _CTX or _CTX[-1][2] is None:
        return key
    return jax.random.fold_in(key, jax.lax.axis_index(_CTX[-1][2]))


def leaf_tp_mode(p: dict) -> Optional[str]:
    """Static layout of a (possibly marked) param leaf dict."""
    if "tp_col" in p:
        return "col"
    if "tp_row" in p:
        return "row"
    return None


# ---------------------------------------------------------------------------
# parameter marking + spec derivation
# ---------------------------------------------------------------------------

def _divisible(leaf: dict, mode: str, n_model: int) -> bool:
    w_q = leaf["w_q"]
    if w_q.ndim < 2:
        return False
    if mode == "row":
        # packed int4 rows are K//2: splitting rows evenly keeps every
        # shard's K slice even, so nibble pairs never straddle a boundary
        return w_q.shape[-2] % n_model == 0
    return w_q.shape[-1] % n_model == 0


def _leaf_specs(leaf: dict, mode: str, axis: str) -> dict:
    """PartitionSpec per array of one sharded leaf ({"w_q","w_scale"[,"b"]}).

    Specs are right-aligned so stacked (leading-G) block leaves shard the
    same trailing dims as unstacked ones.  Biases stay replicated: they are
    added after the gather/psum on the full output.
    """
    def tail(ndim: int, *entries) -> P:
        entries = entries[-ndim:]
        return P(*(((None,) * (ndim - len(entries))) + tuple(entries)))

    specs = {}
    for k, v in leaf.items():
        nd = getattr(v, "ndim", 0)
        if k == "w_q":
            specs[k] = tail(nd, axis, None) if mode == "row" \
                else tail(nd, None, axis)
        elif k == "w_scale" and mode == "col":
            specs[k] = tail(nd, None, axis)
        else:
            specs[k] = P()
    return specs


def mark_tp_params(params, n_model: int, model_axis: str = "model"):
    """Tag every shardable quantized leaf and derive its PartitionSpecs.

    Walks the param tree for serving-code leaves (``{"w_q", "w_scale"}``,
    produced by ``serve.quantize``) whose parent key names a projection.
    Output projections (``wo``/``out_proj``) become row-parallel, everything
    else column-parallel; leaves whose sharded dim is not divisible by
    ``n_model`` stay replicated (correct, just not distributed).

    Returns ``(marked_params, specs, n_sharded)`` — ``specs`` is a pytree of
    PartitionSpec with the same structure as ``marked_params`` (replicated
    ``P()`` everywhere that isn't a sharded code/scale).  Markers are
    zero-size int8 arrays shaped ``leading_stack_dims + (0,)`` so they scan
    over stacked block params like any other leaf.
    """
    n_sharded = 0

    def walk(tree, skip=False):
        nonlocal n_sharded
        if isinstance(tree, dict):
            out, spec = {}, {}
            for k, v in tree.items():
                if (not skip and isinstance(v, dict) and "w_q" in v
                        and k not in _SKIP_NAMES):
                    mode = "row" if k in _ROW_PARALLEL_NAMES else "col"
                    if n_model > 1 and _divisible(v, mode, n_model):
                        leaf = dict(v)
                        leaf["tp_" + mode] = jnp.zeros(
                            v["w_q"].shape[:-2] + (0,), jnp.int8)
                        out[k] = leaf
                        spec[k] = _leaf_specs(leaf, mode, model_axis)
                        n_sharded += 1
                        continue
                out[k], spec[k] = walk(v, skip or k in _SKIP_NAMES)
            return out, spec
        if isinstance(tree, (tuple, list)):
            pairs = [walk(v, skip) for v in tree]
            return (type(tree)(p[0] for p in pairs),
                    type(tree)(p[1] for p in pairs))
        return tree, P()

    marked, specs = walk(params)
    return marked, specs, n_sharded
