"""Named sharding rules + in-model constraint points.

A ``Rules`` table maps *logical* axis names ("batch", "heads", "vocab", ...)
to mesh axis names (or None for replicated, or a tuple of mesh axes).  Model
code never mentions mesh axes: it calls ``constrain(x, "batch", "seq", None)``
and the active rules (installed by :func:`use_rules`) decide the placement.
Outside a ``use_rules`` context ``constrain`` is the identity, so single-device
tests and eager code pay nothing.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class Rules(dict):
    """Logical-axis -> mesh-axis table (plain dict with a type name)."""


def production_rules(multi_pod: bool = False) -> Rules:
    """Default rule table for the (data, model) production meshes.

    ``fsdp``/``expert``/``expert_mlp``/``seq_kv`` are filled in per-cell by
    ``launch.mesh.rules_for`` — their defaults here are the serving-friendly
    replicated choices.
    """
    return Rules(
        batch=("pod", "data") if multi_pod else "data",
        seq=None,                 # activations keep full sequence per shard
        seq_kv=None,              # long-context cells shard KV time instead
        vocab="model",
        heads="model",
        kv_heads="model",
        mlp="model",
        expert=None,
        expert_mlp=None,
        moe_capacity=None,
        fsdp=None,
    )


def make_mesh(axis_shapes, axis_names) -> Mesh:
    """``jax.make_mesh`` with Auto axis types when the installed jax has
    them (>= 0.5); plain mesh otherwise — call sites stay version-agnostic."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(axis_type.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)


# -- active-rules context ----------------------------------------------------

_ACTIVE: list[tuple[Rules, Optional[Mesh]]] = []


@contextlib.contextmanager
def use_rules(rules: Rules, mesh: Optional[Mesh] = None):
    """Install ``rules`` (+ optional mesh) for ``constrain`` call sites."""
    _ACTIVE.append((rules, mesh))
    try:
        yield rules
    finally:
        _ACTIVE.pop()


def current_rules() -> Optional[tuple[Rules, Optional[Mesh]]]:
    return _ACTIVE[-1] if _ACTIVE else None


def spec_for(rules: Rules, *axes) -> P:
    """PartitionSpec from logical axis names (None entries stay None)."""
    entries = []
    for a in axes:
        if a is None:
            entries.append(None)
        elif isinstance(a, str):
            entries.append(rules.get(a))
        else:                      # already a mesh-axis tuple
            entries.append(a)
    return P(*entries)


def constrain(x: jax.Array, *axes) -> jax.Array:
    """``with_sharding_constraint`` by logical axis name; identity when no
    rules are active (single-device tests, eager code)."""
    ctx = current_rules()
    if ctx is None:
        return x
    rules, mesh = ctx
    spec = spec_for(rules, *axes)
    if all(e is None for e in spec):
        return x
    if mesh is not None:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)
