"""Param/opt-state PartitionSpec derivation from leaf *names*.

``param_specs`` walks a param pytree (arrays or ShapeDtypeStructs) and assigns
each leaf a PartitionSpec from its key path — the same regex-on-keystr idiom
``serve.quantize`` uses for eligibility.  Projection weights get
(fsdp, tensor-parallel) on their trailing (d_in, d_out) dims; leading stack
dims (layer group, expert) are left unsharded unless named; everything
unmatched is replicated (P()), which is always legal under pjit.

``state_specs`` reuses the same leaf rule: optimizer moments live under
``['opt']['mu']/...`` with identical path *suffixes*, so they inherit their
parameter's layout for free.
"""
from __future__ import annotations

import re

import jax
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import Rules

# weights whose trailing dims are [d_in, d_out] with d_out the TP dim
_COL_PARALLEL = re.compile(
    r"\['(wq|wk|wv|wi|wg|wr|wu|in_proj)'\]\['(w|w_q)'\]$")
# output projections: [tp_in, d_out] — TP on the contracting dim
_ROW_PARALLEL = re.compile(r"\['(wo|out_proj)'\]\['(w|w_q)'\]$")
# split-head 3D variants [d, H, dh] / [H, dh, d]
_COL_3D = re.compile(r"\['(wq3|wk3|wv3)'\]\['w'\]$")
_ROW_3D = re.compile(r"\['wo3'\]\['w'\]$")
# MoE expert banks are raw leaves [E, d, ff] / [E, ff, d]
_MOE_IN = re.compile(r"\['moe'\]\['w[ig]'\](\['w_q'\])?$")
_MOE_OUT = re.compile(r"\['moe'\]\['wo'\](\['w_q'\])?$")
_EMBED = re.compile(r"\['embed'\]\['emb'\]$")
_HEAD = re.compile(r"\['lm_head'\]\['(w|w_q)'\]$")
_SCALE = re.compile(r"\['w_scale'\]$")


def _tail(ndim: int, *entries) -> P:
    """Right-align ``entries`` onto an ndim-rank spec, None-padding the
    leading (stack) dims; drops entries that don't fit small ranks."""
    entries = entries[-ndim:] if len(entries) > ndim else entries
    return P(*(((None,) * (ndim - len(entries))) + tuple(entries)))


def leaf_spec(path: str, ndim: int, rules: Rules) -> P:
    g = rules.get
    tp_attn = g("heads")
    tp_mlp = g("mlp")
    tp = tp_attn if "['attn']" in path else tp_mlp
    if ndim < 2:
        return P()
    if _MOE_IN.search(path):
        return _tail(ndim, g("expert"), g("fsdp"), g("expert_mlp"))
    if _MOE_OUT.search(path):
        return _tail(ndim, g("expert"), g("expert_mlp"), g("fsdp"))
    if _EMBED.search(path):
        return _tail(ndim, g("vocab"), g("fsdp"))
    if _HEAD.search(path):
        return _tail(ndim, g("fsdp"), g("vocab"))
    if _COL_3D.search(path):
        return _tail(ndim, g("fsdp"), g("heads"), None)
    if _ROW_3D.search(path):
        return _tail(ndim, g("heads"), None, g("fsdp"))
    if _COL_PARALLEL.search(path):
        return _tail(ndim, g("fsdp"), tp)
    if _ROW_PARALLEL.search(path):
        return _tail(ndim, tp, g("fsdp"))
    if _SCALE.search(path):
        return _tail(ndim, None, tp)
    return P()


def _specs(tree, rules: Rules):
    def leaf(path, x):
        return leaf_spec(jax.tree_util.keystr(path), getattr(x, "ndim", 0),
                         rules)
    return jax.tree_util.tree_map_with_path(leaf, tree)


def param_specs(params, rules: Rules):
    """PartitionSpec tree for a param pytree (arrays or SDS leaves)."""
    return _specs(params, rules)


def state_specs(state, rules: Rules):
    """PartitionSpec tree for a train state ({"params", "opt"}): optimizer
    moments mirror their parameter specs via identical path suffixes."""
    return _specs(state, rules)
