"""Distribution utilities: sharding rules, parameter partitioning specs,
and straggler monitoring for multi-host training."""
