"""Mixture-of-Experts FFN: top-k routing with capacity-based scatter dispatch
(+ optional shared experts, Qwen-MoE style).

TPU-native dispatch: fixed-shape scatter into an [E, C, d] buffer (tokens over
capacity are dropped, GShard-style), batched expert matmuls via einsum, and a
gather-combine.  Expert weights carry a leading E dim so expert parallelism is
just a sharding rule ("expert" -> "model"); the dispatch scatter/gather then
lowers to all-to-alls under pjit.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.models.layers import Params, init_linear, linear


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert ff
    capacity_factor: float = 1.25
    shared_ff: int = 0             # 0 = no shared expert branch
    norm_topk: bool = True
    router_aux_weight: float = 0.01
    dispatch: str = "global"       # global (one cross-device buffer) |
                                   # grouped (per-sequence groups; §Perf B3)


def init_moe(key, d_model: int, cfg: MoEConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 6)
    E, F = cfg.n_experts, cfg.d_ff
    s = 1.0 / (d_model ** 0.5)
    p = {
        "router": init_linear(ks[0], d_model, E, dtype=dtype),
        "wi": jax.random.normal(ks[1], (E, d_model, F), dtype) * s,
        "wg": jax.random.normal(ks[2], (E, d_model, F), dtype) * s,
        "wo": jax.random.normal(ks[3], (E, F, d_model), dtype) * (1.0 / F ** 0.5),
    }
    if cfg.shared_ff:
        from repro.models.layers import init_mlp
        p["shared"] = init_mlp(ks[4], d_model, cfg.shared_ff, "swiglu", dtype)
        p["shared_gate"] = init_linear(ks[5], d_model, 1, dtype=dtype)
    return p


def _expert_einsum(a: jax.Array, w, compute_dtype, out_contract: bool = False
                   ) -> jax.Array:
    """einsum('ecd,edf->ecf') for float weights or pre-quantized codes.

    The weight is cast to compute dtype and re-constrained to its TP-only
    layout *at the use site*: under FSDP the contracting dim is data-sharded,
    and letting XLA contract a sharded dim turns every expert matmul into a
    partial-sum all-reduce of the (huge) activation buffer — 6.3 TB/step on
    mixtral train_4k.  Re-gathering bf16 weights instead costs ~2 orders of
    magnitude less (§Perf iteration B4)."""
    if not isinstance(w, dict):
        # NOTE: an earlier iteration (§Perf B4) re-constrained the bf16 cast
        # to a TP-only layout here to force weight re-gather over the FSDP
        # axis; under GSPMD this regressed badly (XLA replicated the expert
        # compute). The identified follow-up is an explicit shard_map for the
        # expert block; the plain cast below at least keeps gathers in bf16.
        return jnp.einsum("ecd,edf->ecf", a, w.astype(compute_dtype))
    w_q, w_scale = w["w_q"], w["w_scale"]
    if w_q.dtype == jnp.uint8:                   # packed int4
        from repro.core.lut import unpack_int4
        w_int = jnp.swapaxes(
            unpack_int4(jnp.swapaxes(w_q, -1, -2), signed=True), -1, -2)
        qmax = 7
    else:
        w_int, qmax = w_q, 127
    a_scale = jnp.maximum(
        jnp.max(jnp.abs(a.astype(jnp.float32)), axis=-1, keepdims=True),
        1e-8) / qmax
    a_q = jnp.clip(jnp.round(a / a_scale.astype(a.dtype)), -qmax - 1, qmax
                   ).astype(jnp.int8)
    acc = jnp.einsum("ecd,edf->ecf", a_q, w_int,
                     preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * a_scale * w_scale
            ).astype(compute_dtype)


def moe_ffn(p: Params, x: jax.Array, cfg: MoEConfig, *, quant: str = "none",
            compute_dtype=jnp.bfloat16, deterministic_capacity: Optional[int] = None):
    """x: [B, S, d] -> (y, aux_loss).

    ``dispatch="grouped"`` (default): each batch row is its own routing group
    (GShard group_size = S).  Because the batch dim is data-sharded and groups
    never interact, the scatter/gather dispatch is **collective-free** — the
    global-buffer variant costs TBs of all-reduce per step at mixtral scale
    (EXPERIMENTS.md §Perf iteration B3).  Trade-off: capacity is enforced
    per-sequence, so unbalanced single sequences drop more tokens at equal
    capacity_factor.
    """
    if cfg.dispatch == "grouped":
        C = deterministic_capacity or max(
            cfg.top_k, int(x.shape[1] * cfg.top_k / cfg.n_experts
                           * cfg.capacity_factor))

        def one_group(xg):
            y, aux = _moe_dispatch_flat(p, xg, cfg, quant=quant,
                                        compute_dtype=compute_dtype,
                                        capacity=C, constrain_bufs=False)
            return y, aux

        y, aux = jax.vmap(one_group)(x)
        y = constrain(y, "batch", None, None)
        return y, jnp.mean(aux)
    B, S, d = x.shape
    y, aux = _moe_dispatch_flat(p, x.reshape(B * S, d), cfg, quant=quant,
                                compute_dtype=compute_dtype,
                                capacity=deterministic_capacity)
    return y.reshape(B, S, d), aux


def _moe_dispatch_flat(p: Params, xf: jax.Array, cfg: MoEConfig, *,
                       quant: str, compute_dtype, capacity: Optional[int],
                       constrain_bufs: bool = True):
    """Capacity-based dispatch over a flat token list [T, d]."""
    T, d = xf.shape
    E, k = cfg.n_experts, cfg.top_k
    C = capacity or max(1, int(T * k / E * cfg.capacity_factor))

    logits = linear(p["router"], xf.astype(jnp.float32), "none", jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # [T, E]
    gate_vals, expert_ids = jax.lax.top_k(probs, k)             # [T, k]
    if cfg.norm_topk:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balancing aux loss (Switch): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32), axis=0)
    aux = cfg.router_aux_weight * E * jnp.sum(me * ce)

    # position of each (token, slot) within its expert
    flat_e = expert_ids.reshape(-1)                              # [T*k]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)          # [T*k, E]
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot               # exclusive
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = pos < C
    token_of = jnp.repeat(jnp.arange(T), k)

    safe_pos = jnp.where(keep, pos, C - 1)
    buf = jnp.zeros((E, C, d), compute_dtype)
    contrib = jnp.where(keep[:, None], xf[token_of].astype(compute_dtype), 0)
    buf = buf.at[flat_e, safe_pos].add(
        jnp.where(keep[:, None], contrib, 0))
    if constrain_bufs:
        buf = constrain(buf, "expert", "moe_capacity", None)

    # batched expert SwiGLU (weights may be pre-quantized serving codes).
    # Under expert-parallel tensor sharding (``tp_exp``-marked banks inside
    # a dist.tp context) each shard holds E/tp experts: the dispatch buffer
    # is sliced to the local experts, only they run, and an all_gather over
    # the expert axis rebuilds the full output buffer — every element of
    # which is computed by exactly one shard with the unsharded per-expert
    # math, so the (replicated) combine below stays bit-identical to the
    # single-device path.  Routing above ran on the replicated router, so
    # top-k choice, gates, and capacity positions are identical everywhere.
    from repro.dist import tp as tp_lib
    exp_axis = tp_lib.model_axis() if (isinstance(p["wi"], dict)
                                       and "tp_exp" in p["wi"]) else None
    if exp_axis is not None:
        E_local = p["wi"]["w_q"].shape[0]
        start = jax.lax.axis_index(exp_axis) * E_local
        buf = jax.lax.dynamic_slice_in_dim(buf, start, E_local, axis=0)
    h = _expert_einsum(buf, p["wi"], compute_dtype)
    g = _expert_einsum(buf, p["wg"], compute_dtype)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(compute_dtype) * h
    if exp_axis is None and constrain_bufs:
        h = constrain(h, "expert", "moe_capacity", "expert_mlp")
    out = _expert_einsum(h, p["wo"], compute_dtype, out_contract=True)
    if exp_axis is not None:
        out = jax.lax.all_gather(out, exp_axis, axis=0, tiled=True)
    elif constrain_bufs:
        out = constrain(out, "expert", "moe_capacity", None)

    # combine
    gathered = out[flat_e, safe_pos]                             # [T*k, d]
    gathered = jnp.where(keep[:, None], gathered, 0)
    w = gate_vals.reshape(-1).astype(jnp.float32)
    yf = jax.ops.segment_sum(gathered.astype(jnp.float32) * w[:, None],
                             token_of, num_segments=T)
    y = yf.astype(compute_dtype)

    if "shared" in p:
        from repro.models.layers import mlp
        sg = jax.nn.sigmoid(
            linear(p["shared_gate"], xf.astype(jnp.float32), "none", jnp.float32))
        y = y + (sg * mlp(p["shared"], xf, "swiglu", quant,
                          compute_dtype).astype(jnp.float32)).astype(compute_dtype)
    return y, aux
