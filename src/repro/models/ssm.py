"""State-space / linear-recurrence blocks: Mamba2 (SSD, chunked) and RWKV6.

Both use the chunked formulation: within-chunk interactions are dense matmuls
(MXU-friendly), cross-chunk state is carried by a lax.scan — the TPU-native
adaptation of the recurrences (GPU implementations use fused scans; on TPU the
matmul-rich chunk form is the right decomposition).

Decode paths carry explicit recurrent state (O(1) per token) — this is what
makes the ``long_500k`` shape tractable for these families.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import Params, init_linear, linear


# ===========================================================================
# Mamba2 (SSD)
# ===========================================================================

def init_mamba2(key, d_model: int, d_inner: int, d_state: int, n_heads: int,
                d_conv: int = 4, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    head_p = d_inner // n_heads
    return {
        # order: [z, x, B, C, dt]
        "in_proj": init_linear(ks[0], d_model,
                               2 * d_inner + 2 * d_state + n_heads, dtype=dtype),
        "conv_w": jax.random.normal(ks[1], (d_conv, d_inner + 2 * d_state),
                                    dtype) * 0.1,
        "conv_b": jnp.zeros((d_inner + 2 * d_state,), dtype),
        "A_log": jnp.log(jnp.arange(1, n_heads + 1, dtype=jnp.float32)),
        "D": jnp.ones((n_heads,), dtype),
        "dt_bias": jnp.zeros((n_heads,), dtype),
        "out_proj": init_linear(ks[2], d_inner, d_model, dtype=dtype),
        "norm_scale": jnp.ones((d_inner,), dtype),
    }


def _pick_chunk(T: int, target: int) -> int:
    """Largest divisor of T not exceeding target (static shapes only)."""
    c = min(target, T)
    while T % c:
        c -= 1
    return c


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv over time. x: [B,T,C]; w: [K,C]. Returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    idx = jnp.arange(x.shape[1])[:, None] + jnp.arange(K)[None, :]
    windows = xp[:, idx]                              # [B,T,K,C]
    y = jnp.einsum("btkc,kc->btc", windows, w.astype(x.dtype)) + b.astype(x.dtype)
    new_state = xp[:, -(K - 1):] if K > 1 else None
    return y, new_state


def mamba2(p: Params, x: jax.Array, *, d_inner: int, d_state: int,
           n_heads: int, chunk: int = 128, quant: str = "none",
           compute_dtype=jnp.bfloat16, return_state: bool = False):
    """Full-sequence Mamba2 (training / prefill). x: [B, T, d_model]."""
    B, T, _ = x.shape
    head_p = d_inner // n_heads
    zxbcdt = linear(p["in_proj"], x, quant, compute_dtype)
    z, xs, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + d_state,
                 2 * d_inner + 2 * d_state], axis=-1)
    conv_in = jnp.concatenate([xs, Bc, Cc], axis=-1)
    conv_out, _ = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
    conv_tail = conv_in[:, T - (p["conv_w"].shape[0] - 1):]
    conv_out = jax.nn.silu(conv_out)
    xs, Bc, Cc = jnp.split(conv_out, [d_inner, d_inner + d_state], axis=-1)
    xs = xs.reshape(B, T, n_heads, head_p)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))     # [B,T,H]
    a = -jnp.exp(p["A_log"].astype(jnp.float32))                 # [H]
    y, h_final = _ssd_chunked(xs.astype(jnp.float32), dt, a,
                              Bc.astype(jnp.float32), Cc.astype(jnp.float32),
                              chunk=_pick_chunk(T, chunk))
    y = y + xs.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, T, d_inner)
    # gated RMSNorm (mamba2 norm-before-gate)
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * p["norm_scale"].astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = linear(p["out_proj"], y.astype(compute_dtype), quant, compute_dtype)
    if return_state:
        return out, Mamba2State(h=h_final, conv=conv_tail)
    return out


def _ssd_chunked(xs, dt, a, Bc, Cc, chunk: int):
    """SSD: h_t = exp(a*dt_t) h_{t-1} + dt_t * B_t x_t ;  y_t = C_t . h_t.

    xs: [B,T,H,P] dt: [B,T,H] a: [H] Bc/Cc: [B,T,N].  All fp32.
    """
    B, T, H, P = xs.shape
    N = Bc.shape[-1]
    nc = T // chunk
    xs = xs.reshape(B, nc, chunk, H, P)
    dt = dt.reshape(B, nc, chunk, H)
    Bc = Bc.reshape(B, nc, chunk, N)
    Cc = Cc.reshape(B, nc, chunk, N)
    la = a[None, None, None, :] * dt                     # [B,nc,c,H] log decays
    cum = jnp.cumsum(la, axis=2)                         # inclusive
    # intra-chunk: M[t,s] = exp(cum_t - cum_s) * (C_t.B_s) * dt_s,  s <= t
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # [B,nc,t,s,H]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bgtn,bgsn->bgts", Cc, Bc)
    M = cb[..., None] * decay * dt[:, :, None, :, :]       # [B,nc,t,s,H]
    y_intra = jnp.einsum("bgtsh,bgshp->bgthp", M, xs)
    # chunk summaries: state contribution of each chunk
    last = cum[:, :, -1:, :]                                # [B,nc,1,H]
    k_fac = jnp.exp(last - cum) * dt                        # [B,nc,c,H]
    chunk_state = jnp.einsum("bgcn,bgch,bgchp->bghnp", Bc, k_fac, xs)
    chunk_decay = jnp.exp(last[:, :, 0, :])                 # [B,nc,H]

    def scan_fn(h, inp):
        cs, cd = inp                                        # [B,H,N,P], [B,H]
        h_new = h * cd[:, :, None, None] + cs
        return h_new, h

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    h_final, h_prevs = jax.lax.scan(
        scan_fn, h0, (jnp.moveaxis(chunk_state, 1, 0),
                      jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                   # [B,nc,H,N,P] state entering chunk
    y_inter = jnp.einsum("bgtn,bgth,bghnp->bgthp",
                         Cc, jnp.exp(cum), h_prevs)
    y = (y_intra + y_inter).reshape(B, T, H, P)
    return y, h_final


class Mamba2State(NamedTuple):
    h: jax.Array          # [B, H, N, P] ssm state
    conv: jax.Array       # [B, d_conv-1, d_inner+2N] conv tail


def mamba2_decode(p: Params, x: jax.Array, state: Mamba2State, *,
                  d_inner: int, d_state: int, n_heads: int,
                  quant: str = "none", compute_dtype=jnp.bfloat16):
    """Single-token step. x: [B, 1, d_model]."""
    B = x.shape[0]
    head_p = d_inner // n_heads
    zxbcdt = linear(p["in_proj"], x, quant, compute_dtype)
    z, xs, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + d_state,
                 2 * d_inner + 2 * d_state], axis=-1)
    conv_in = jnp.concatenate([xs, Bc, Cc], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, p["conv_w"], p["conv_b"],
                                        state.conv)
    conv_out = jax.nn.silu(conv_out)
    xs, Bc, Cc = jnp.split(conv_out, [d_inner, d_inner + d_state], axis=-1)
    xs = xs.reshape(B, n_heads, head_p).astype(jnp.float32)
    Bc = Bc[:, 0].astype(jnp.float32)                        # [B,N]
    Cc = Cc[:, 0].astype(jnp.float32)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # [B,H]
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(a[None] * dt)                             # [B,H]
    h = state.h * decay[:, :, None, None] + jnp.einsum(
        "bn,bh,bhp->bhnp", Bc, dt, xs)
    y = jnp.einsum("bn,bhnp->bhp", Cc, h)
    y = y + xs * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, 1, d_inner)
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * p["norm_scale"].astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = linear(p["out_proj"], y.astype(compute_dtype), quant, compute_dtype)
    return out, Mamba2State(h=h, conv=conv_state)


# ===========================================================================
# RWKV6 ("Finch") — data-dependent per-channel decay
# ===========================================================================

def init_rwkv6(key, d_model: int, n_heads: int, decay_lora: int = 64,
               dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 8)
    K = d_model // n_heads
    return {
        "mu": jax.random.uniform(ks[0], (5, d_model), dtype),   # r,k,v,g,w shifts
        "wr": init_linear(ks[1], d_model, d_model, dtype=dtype),
        "wk": init_linear(ks[2], d_model, d_model, dtype=dtype),
        "wv": init_linear(ks[3], d_model, d_model, dtype=dtype),
        "wg": init_linear(ks[4], d_model, d_model, dtype=dtype),
        "w0": jnp.full((d_model,), -6.0, dtype),                # base decay
        "w_lora_a": jax.random.normal(ks[5], (d_model, decay_lora), dtype) * 0.01,
        "w_lora_b": jax.random.normal(ks[6], (decay_lora, d_model), dtype) * 0.01,
        "u": jax.random.normal(ks[7], (n_heads, K), dtype) * 0.1,  # bonus
        "wo": init_linear(ks[7], d_model, d_model, dtype=dtype),
        "ln_scale": jnp.ones((d_model,), dtype),                # group-norm-ish
    }


def _rwkv_projections(p, x, x_prev, quant, compute_dtype):
    """Token-shifted projections. x: [B,T,d]; x_prev: [B,T,d] (shifted)."""
    mu = p["mu"].astype(jnp.float32)
    xf = x.astype(jnp.float32)
    xpf = x_prev.astype(jnp.float32)
    def mix(i):
        return (xf + (xpf - xf) * mu[i]).astype(compute_dtype)
    r = linear(p["wr"], mix(0), quant, compute_dtype)
    k = linear(p["wk"], mix(1), quant, compute_dtype)
    v = linear(p["wv"], mix(2), quant, compute_dtype)
    g = linear(p["wg"], mix(3), quant, compute_dtype)
    # data-dependent decay (the RWKV6 hallmark): low-rank on the shifted mix
    xw = mix(4).astype(jnp.float32)
    dd = jnp.tanh(xw @ p["w_lora_a"].astype(jnp.float32)) \
        @ p["w_lora_b"].astype(jnp.float32)
    logw = -jnp.exp(p["w0"].astype(jnp.float32) + dd)           # log decay < 0
    return r, k, v, g, logw


def rwkv6_timemix(p: Params, x: jax.Array, *, n_heads: int, chunk: int = 32,
                  quant: str = "none", compute_dtype=jnp.bfloat16,
                  return_state: bool = False):
    """Full-sequence WKV6. x: [B,T,d]. T must be a multiple of ``chunk``."""
    B, T, d = x.shape
    K = d // n_heads
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, g, logw = _rwkv_projections(p, x, x_prev, quant, compute_dtype)
    rh = r.reshape(B, T, n_heads, K).astype(jnp.float32)
    kh = k.reshape(B, T, n_heads, K).astype(jnp.float32)
    vh = v.reshape(B, T, n_heads, K).astype(jnp.float32)
    wh = logw.reshape(B, T, n_heads, K)
    u = p["u"].astype(jnp.float32)

    chunk = _pick_chunk(T, chunk)
    nc = T // chunk
    rh, kh, vh, wh = (a.reshape(B, nc, chunk, n_heads, K)
                      for a in (rh, kh, vh, wh))
    cum = jnp.cumsum(wh, axis=2)                        # inclusive log-decay sums
    # intra-chunk pairwise: A[t,s] = sum_k r_t k_s exp(cum_{t-1} - cum_s), s<t
    cprev = cum - wh                                    # cum_{t-1} (exclusive)
    diff = cprev[:, :, :, None] - cum[:, :, None, :]    # [B,nc,t,s,H,K]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    dec = jnp.where(mask[None, None, :, :, None, None], jnp.exp(diff), 0.0)
    A = jnp.einsum("bgthk,bgtshk,bgshk->bgtsh", rh, dec, kh)
    diag = jnp.einsum("bgthk,hk,bgthk->bgth", rh, u, kh)
    A = A + jnp.eye(chunk)[None, None, :, :, None] * diag[:, :, :, None, :]
    y_intra = jnp.einsum("bgtsh,bgshv->bgthv", A, vh)
    # cross-chunk state
    kfac = jnp.exp(cum[:, :, -1:, :, :] - cum) * 1.0    # exp(cum_L - cum_s) <= 1
    chunk_state = jnp.einsum("bgshk,bgshv->bghkv", kh * kfac, vh)
    chunk_decay = jnp.exp(cum[:, :, -1])                # [B,nc,H,K]

    def scan_fn(S, inp):
        cs, cd = inp
        return S * cd[..., None] + cs, S

    S0 = jnp.zeros((B, n_heads, K, K), jnp.float32)     # V dim == K here
    S_final, S_prevs = jax.lax.scan(scan_fn, S0,
                                    (jnp.moveaxis(chunk_state, 1, 0),
                                     jnp.moveaxis(chunk_decay, 1, 0)))
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)               # [B,nc,H,K,V]
    y_inter = jnp.einsum("bgthk,bghkv->bgthv", rh * jnp.exp(cprev), S_prevs)
    y = (y_intra + y_inter).reshape(B, T, n_heads, K)
    # per-head group norm then output gate
    mu_ = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    y = (y - mu_) * jax.lax.rsqrt(var + 64e-5)
    y = y.reshape(B, T, d) * p["ln_scale"].astype(jnp.float32)
    y = y * jax.nn.silu(g.astype(jnp.float32))
    out = linear(p["wo"], y.astype(compute_dtype), quant, compute_dtype)
    if return_state:
        return out, (S_final, x[:, -1:])
    return out


class RWKVState(NamedTuple):
    S: jax.Array          # [B, H, K, V]
    x_prev_t: jax.Array   # [B, 1, d] last input (time-mix shift)
    x_prev_c: jax.Array   # [B, 1, d] last input (channel-mix shift)


def rwkv6_timemix_decode(p: Params, x: jax.Array, state: RWKVState, *,
                         n_heads: int, quant: str = "none",
                         compute_dtype=jnp.bfloat16):
    """One token. x: [B,1,d]."""
    B, _, d = x.shape
    K = d // n_heads
    r, k, v, g, logw = _rwkv_projections(p, x, state.x_prev_t, quant,
                                         compute_dtype)
    rh = r.reshape(B, n_heads, K).astype(jnp.float32)
    kh = k.reshape(B, n_heads, K).astype(jnp.float32)
    vh = v.reshape(B, n_heads, K).astype(jnp.float32)
    wh = jnp.exp(logw.reshape(B, n_heads, K))
    u = p["u"].astype(jnp.float32)
    kv = kh[..., :, None] * vh[..., None, :]             # [B,H,K,V]
    y = jnp.einsum("bhk,bhkv->bhv", rh, state.S + u[None, :, :, None] * kv)
    S_new = state.S * wh[..., None] + kv
    mu_ = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    y = (y - mu_) * jax.lax.rsqrt(var + 64e-5)
    y = y.reshape(B, 1, d) * p["ln_scale"].astype(jnp.float32)
    y = y * jax.nn.silu(g.astype(jnp.float32))
    out = linear(p["wo"], y.astype(compute_dtype), quant, compute_dtype)
    return out, state._replace(S=S_new, x_prev_t=x)


def init_rwkv6_chanmix(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"mu": jax.random.uniform(k1, (2, d_model), dtype),
            "wk": init_linear(k2, d_model, d_ff, dtype=dtype),
            "wv": init_linear(k3, d_ff, d_model, dtype=dtype),
            "wr": init_linear(k1, d_model, d_model, dtype=dtype)}


def rwkv6_chanmix(p: Params, x: jax.Array, x_prev: jax.Array,
                  quant: str = "none", compute_dtype=jnp.bfloat16) -> jax.Array:
    mu = p["mu"].astype(jnp.float32)
    xf, xpf = x.astype(jnp.float32), x_prev.astype(jnp.float32)
    xk = (xf + (xpf - xf) * mu[0]).astype(compute_dtype)
    xr = (xf + (xpf - xf) * mu[1]).astype(compute_dtype)
    k = jnp.square(jax.nn.relu(linear(p["wk"], xk, quant, compute_dtype)))
    kv = linear(p["wv"], k, quant, compute_dtype)
    return jax.nn.sigmoid(linear(p["wr"], xr, quant, compute_dtype)
                          .astype(jnp.float32)).astype(kv.dtype) * kv
