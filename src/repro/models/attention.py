"""Attention: GQA with blocked (flash-style) softmax, sliding windows,
Gemma-2 logit soft-capping, cross-attention, and KV-cache decode.

Blocked attention keeps the score tensor at [.., q_block, kv_block] so 32k
prefill fits in HBM; the online-softmax recurrence is the standard
FlashAttention algorithm expressed in lax.scan (XLA fuses it well on TPU; a
Pallas flash kernel is a beyond-paper optimization tracked in EXPERIMENTS.md).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.models.layers import (Params, apply_mrope, apply_rope, init_linear,
                                 linear, stable_tanh)

NEG_INF = -1e30


def init_attention(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
                   qkv_bias: bool = False, dtype=jnp.float32,
                   split_heads: bool = False) -> Params:
    """``split_heads=True`` stores projections as [d, H, dh] (3D) so the
    head axis is a real param dim — sharding then never straddles a reshape
    boundary (kills GSPMD's involuntary resharding permutes when
    H % mesh != 0; see EXPERIMENTS.md §Perf)."""
    kq, kk, kv, ko = jax.random.split(key, 4)
    if not split_heads:
        return {
            "wq": init_linear(kq, d_model, n_heads * head_dim, bias=qkv_bias, dtype=dtype),
            "wk": init_linear(kk, d_model, n_kv * head_dim, bias=qkv_bias, dtype=dtype),
            "wv": init_linear(kv, d_model, n_kv * head_dim, bias=qkv_bias, dtype=dtype),
            "wo": init_linear(ko, n_heads * head_dim, d_model, dtype=dtype),
        }
    import math as _m
    s = 1.0 / _m.sqrt(d_model)
    p = {
        "wq3": {"w": jax.random.normal(kq, (d_model, n_heads, head_dim), dtype) * s},
        "wk3": {"w": jax.random.normal(kk, (d_model, n_kv, head_dim), dtype) * s},
        "wv3": {"w": jax.random.normal(kv, (d_model, n_kv, head_dim), dtype) * s},
        "wo3": {"w": jax.random.normal(ko, (n_heads, head_dim, d_model), dtype)
                * (1.0 / _m.sqrt(n_heads * head_dim))},
    }
    if qkv_bias:
        for k, h in (("wq3", n_heads), ("wk3", n_kv), ("wv3", n_kv)):
            p[k]["b"] = jnp.zeros((h, head_dim), dtype)
    return p


def _proj_qkv(p: Params, name: str, x: jax.Array, B: int, S: int, H: int,
              D: int, quant: str, cd) -> jax.Array:
    """Project to [B, S, h, D] through either the fused-2D or split-3D params.

    ``h`` is derived from the projection output, not the ``H`` argument:
    under head-sharded tensor parallelism (``tp_head``-marked leaves inside
    a ``dist.tp`` context) the projection emits only this shard's
    ``H / tp`` local heads and everything downstream (RoPE, cache writes,
    attention) is per-head math that works on the local slice unchanged.
    """
    if name + "3" in p:
        w = p[name + "3"]["w"].astype(cd)
        y = jnp.einsum("bsd,dhk->bshk", x.astype(cd), w)
        if "b" in p[name + "3"]:
            y = y + p[name + "3"]["b"].astype(cd)
        return y
    return linear(p[name], x, quant, cd).reshape(B, S, -1, D)


def _proj_out(p: Params, out: jax.Array, B: int, S: int, H: int, D: int,
              quant: str, cd) -> jax.Array:
    """Output projection.  ``out`` may hold only this shard's local heads:
    the 2D quantized ``wo`` is row-parallel (its K rows are head-major, so
    the local heads ARE its K slice — ops._row_parallel_prequant psums the
    exact int32 accumulator); the float ``wo3`` stays replicated, so local
    heads are all-gathered back to the full head axis in front of it."""
    if "wo3" in p:
        if out.shape[2] != H:               # head-sharded input
            from repro.dist import tp as tp_lib
            out = jax.lax.all_gather(out, tp_lib.model_axis(), axis=2,
                                     tiled=True)
        return jnp.einsum("bshk,hkd->bsd", out.astype(cd),
                          p["wo3"]["w"].astype(cd))
    return linear(p["wo"], out.reshape(B, S, -1).astype(cd), quant, cd)


def _mask(q_pos, k_pos, causal: bool, window: Optional[int]):
    """[..., q, k] boolean keep-mask from absolute positions.

    Negative key positions are sentinels for padding / unwritten cache slots
    and are always masked out.
    """
    m = (k_pos >= 0)[..., None, :]
    m = jnp.broadcast_to(m, q_pos.shape[:-1]
                         + (q_pos.shape[-1], k_pos.shape[-1]))
    d = q_pos[..., :, None] - k_pos[..., None, :]
    if causal:
        m = m & (d >= 0)
    if window is not None:
        m = m & (d < window)
    return m


def blocked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      q_pos: jax.Array, k_pos: jax.Array, *,
                      causal: bool = True, window: Optional[int] = None,
                      logit_softcap: Optional[float] = None,
                      kv_block: int = 1024) -> jax.Array:
    """q: [B,S,Hq,D], k/v: [B,T,Hkv,D]; GQA via head grouping (no KV repeat).

    Scans over KV blocks with online softmax; score memory is
    O(B * Hq * S * kv_block).  K/V stay in their storage dtype — the score
    matmul accumulates in fp32 via ``preferred_element_type`` instead of
    materializing fp32 copies of the (possibly huge) K/V.
    """
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    qg = (q.reshape(B, S, Hkv, G, D) * jnp.asarray(scale, q.dtype))

    nblk = -(-T // kv_block)
    pad = nblk * kv_block - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-(10 ** 9))
    kb = k.reshape(B, nblk, kv_block, Hkv, D)
    vb = v.reshape(B, nblk, kv_block, Hkv, D)
    pb = k_pos.reshape(B, nblk, kv_block)

    def step(carry, blk):
        m_run, l_run, acc = carry
        kj, vj, pj = blk                      # [B,kb,Hkv,D], [B,kb]
        s = jnp.einsum("bshgd,bkhd->bshgk", qg, kj,
                       preferred_element_type=jnp.float32)
        if logit_softcap is not None:
            s = logit_softcap * stable_tanh(s / logit_softcap)
        keep = _mask(q_pos, pj, causal, window)   # [B, S, kb]
        s = jnp.where(keep[:, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bshgk,bkhd->bshgd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, S, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, S, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, S, Hkv, G, D), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.moveaxis(pb, 1, 0)))
    out = acc / jnp.maximum(l_f[..., None], 1e-30)
    return out.reshape(B, S, Hq, D)


def full_attention(q, k, v, q_pos, k_pos, *, causal=True, window=None,
                   logit_softcap=None, bias=None) -> jax.Array:
    """Unblocked reference path (tests + short sequences + decode).

    K/V stay in storage dtype (fp32 accumulation via preferred_element_type)
    — for a 32k decode cache this avoids a 2x fp32 materialization.
    """
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, D) * jnp.asarray(1.0 / math.sqrt(D), q.dtype)
    s = jnp.einsum("bshgd,bkhd->bshgk", qg, k,
                   preferred_element_type=jnp.float32)
    if logit_softcap is not None:
        s = logit_softcap * stable_tanh(s / logit_softcap)
    if bias is not None:
        s = s + bias
    keep = _mask(q_pos, k_pos, causal, window)
    s = jnp.where(keep[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bshgk,bkhd->bshgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, S, Hq, D)


def attention(p: Params, x: jax.Array, positions: jax.Array, *,
              n_heads: int, n_kv: int, head_dim: int,
              causal: bool = True, window: Optional[int] = None,
              logit_softcap: Optional[float] = None,
              rope_theta: float = 10000.0, rope_mode: str = "rope",
              mrope_sections: tuple[int, ...] = (),
              mrope_positions: Optional[jax.Array] = None,
              kv_block: int = 1024, quant: str = "none",
              compute_dtype=jnp.bfloat16,
              return_kv: bool = False):
    """Self-attention over a full sequence (train / prefill)."""
    B, S, _ = x.shape
    q = _proj_qkv(p, "wq", x, B, S, n_heads, head_dim, quant, compute_dtype)
    k = _proj_qkv(p, "wk", x, B, S, n_kv, head_dim, quant, compute_dtype)
    v = _proj_qkv(p, "wv", x, B, S, n_kv, head_dim, quant, compute_dtype)
    if rope_mode == "mrope":
        mpos = mrope_positions
        if mpos is None:
            mpos = jnp.broadcast_to(positions[..., None], positions.shape + (3,))
        q = apply_mrope(q, mpos, mrope_sections, rope_theta)
        k = apply_mrope(k, mpos, mrope_sections, rope_theta)
    elif rope_mode == "rope":
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)
    if S <= 2 * kv_block:
        out = full_attention(q, k, v, positions, positions, causal=causal,
                             window=window, logit_softcap=logit_softcap)
    else:
        out = blocked_attention(q, k, v, positions, positions, causal=causal,
                                window=window, logit_softcap=logit_softcap,
                                kv_block=kv_block)
    out = constrain(out.astype(compute_dtype), "batch", None, "heads", None)
    y = _proj_out(p, out, B, S, n_heads, head_dim, quant, compute_dtype)
    if return_kv:
        return y, (k, v)
    return y


def _pos_vec(pos: jax.Array, B: int) -> jax.Array:
    """Normalize a decode position argument to per-sequence [B] int32.

    Scalar positions (the legacy lock-step schedule) broadcast; [B] vectors
    (continuous batching: every slot at its own depth) pass through.  Negative
    positions are the free-slot sentinel — their keys never unmask.
    """
    pos = jnp.asarray(pos, jnp.int32)
    return jnp.broadcast_to(jnp.atleast_1d(pos), (B,))


def _write_kv_slot(cache: jax.Array, new: jax.Array,
                   slot: jax.Array) -> jax.Array:
    """Per-sequence cache write: cache [B,T,...], new [B,1,...], slot [B]."""
    return jax.vmap(
        lambda c, n, s: jax.lax.dynamic_update_slice_in_dim(c, n, s, axis=0)
    )(cache, new.astype(cache.dtype), slot)


# ---------------------------------------------------------------------------
# paged KV cache: ordered gather / per-row page-table writes.  The pool is a
# shared [num_pages, page_size, ...] block store; each batch row owns a
# fixed-shape [E] int32 page-table row.  Gathering the pages in table order
# reconstructs the row's dense [T = E*page_size, ...] buffer with values
# bit-identical to the dense cache (unmapped entries read the reserved null
# page 0, whose junk stays behind the position mask), so the attention math
# downstream is byte-for-byte the dense path.
# ---------------------------------------------------------------------------

def paged_gather(pool: jax.Array, table: jax.Array) -> jax.Array:
    """pool [P, ps, ...], table [B, E] -> dense [B, E*ps, ...] in logical
    order (page j's rows land at positions [j*ps, (j+1)*ps))."""
    B, E = table.shape
    g = jnp.take(pool, table, axis=0)            # [B, E, ps, ...]
    return g.reshape((B, E * pool.shape[1]) + pool.shape[2:])


def paged_write(pool: jax.Array, table: jax.Array, slot: jax.Array,
                new: jax.Array) -> jax.Array:
    """One decode-token write through the page table.

    pool [P, ps, ...]; table [B, E]; slot [B] int32 (the token's slot in the
    row's logical buffer — absolute position, or ``pos % window`` for SWA
    rings); new [B, 1, ...].  A free slot's table row is all zeros, so its
    idempotent write lands in the null page; active rows own their current
    page exclusively, so the scatter never collides across rows.
    """
    ps = pool.shape[1]
    page = jnp.take_along_axis(table, (slot // ps)[:, None], axis=1)[:, 0]
    return pool.at[page, slot % ps].set(new[:, 0].astype(pool.dtype))


def _write_kv_block(cache: jax.Array, new: jax.Array,
                    start: jax.Array) -> jax.Array:
    """Contiguous S-token cache write: cache [B,T,...], new [B,S,...],
    start [B] (dynamic_update_slice clamps starts into [0, T-S])."""
    return jax.vmap(
        lambda c, n, s: jax.lax.dynamic_update_slice_in_dim(c, n, s, axis=0)
    )(cache, new.astype(cache.dtype), start)


def decode_kv_positions(pos: jax.Array, T: int, rolling: bool) -> jax.Array:
    """Absolute positions of cache slots for per-sequence decode.

    pos: [B] int32 (position being written this step).  Returns [B, T] with
    the negative sentinel on unwritten / out-of-ring slots.
    """
    idx = jnp.arange(T)[None]                                  # [1, T]
    posb = pos[:, None]
    if rolling:
        # slot i holds absolute position: the largest p <= pos with p % T == i
        k_pos = posb - ((posb - idx) % T)
        return jnp.where(k_pos < 0, -(10 ** 9), k_pos)
    return jnp.where((idx <= posb) & (posb >= 0), idx, -(10 ** 9))


def decode_attention(p: Params, x: jax.Array, cache_k: jax.Array,
                     cache_v: jax.Array, pos: jax.Array, *,
                     n_heads: int, n_kv: int, head_dim: int,
                     window: Optional[int] = None,
                     logit_softcap: Optional[float] = None,
                     rope_theta: float = 10000.0, rope_mode: str = "rope",
                     mrope_sections: tuple[int, ...] = (),
                     rolling: bool = False,
                     quant: str = "none", compute_dtype=jnp.bfloat16,
                     table: Optional[jax.Array] = None):
    """One decode step. x: [B, 1, d]; cache: [B, T, Hkv, D]; pos: scalar or
    per-sequence [B] int32 (continuous batching: slots at different depths).

    Returns (y, new_cache_k, new_cache_v).  With ``rolling=True`` the cache is
    a ring buffer of size ``window`` (SWA serving — bounded memory, the
    Mistral/Mixtral rolling cache); slot addressing is per-sequence
    ``pos[b] % T``.  A negative ``pos[b]`` marks a free slot: its write lands
    inside its own (free) row and every key stays masked.

    ``table`` ([B, E] int32) switches the cache arguments to paged pools
    ([P, page_size, Hkv, D]): the token write scatters through the row's
    page table and attention runs over the ordered page gather — the dense
    [B, T, Hkv, D] buffer reconstructed value-for-value, so the output is
    bit-identical to the dense path.
    """
    B = x.shape[0]
    paged = table is not None
    T = table.shape[1] * cache_k.shape[1] if paged else cache_k.shape[1]
    q = _proj_qkv(p, "wq", x, B, 1, n_heads, head_dim, quant, compute_dtype)
    k = _proj_qkv(p, "wk", x, B, 1, n_kv, head_dim, quant, compute_dtype)
    v = _proj_qkv(p, "wv", x, B, 1, n_kv, head_dim, quant, compute_dtype)
    posv = _pos_vec(pos, B)
    posb = posv[:, None]                                       # [B,1]
    if rope_mode == "mrope":
        mpos = jnp.broadcast_to(posb[..., None], (B, 1, 3))
        q = apply_mrope(q, mpos, mrope_sections, rope_theta)
        k = apply_mrope(k, mpos, mrope_sections, rope_theta)
    elif rope_mode == "rope":
        q = apply_rope(q, posb, rope_theta)
        k = apply_rope(k, posb, rope_theta)
    slot = posv % T if rolling else jnp.clip(posv, 0, T - 1)
    if paged:
        cache_k = paged_write(cache_k, table, slot, k)
        cache_v = paged_write(cache_v, table, slot, v)
        dense_k = paged_gather(cache_k, table)
        dense_v = paged_gather(cache_v, table)
    else:
        cache_k = dense_k = _write_kv_slot(cache_k, k, slot)
        cache_v = dense_v = _write_kv_slot(cache_v, v, slot)
    k_pos = decode_kv_positions(posv, T, rolling)
    out = full_attention(q, dense_k, dense_v, posb, k_pos, causal=True,
                         window=window, logit_softcap=logit_softcap)
    y = _proj_out(p, out.astype(compute_dtype), B, 1, n_heads, head_dim,
                  quant, compute_dtype)
    return y, cache_k, cache_v


def decode_attention_multi(p: Params, x: jax.Array, cache_k: jax.Array,
                           cache_v: jax.Array, pos: jax.Array, *,
                           n_heads: int, n_kv: int, head_dim: int,
                           logit_softcap: Optional[float] = None,
                           rope_theta: float = 10000.0, rope_mode: str = "rope",
                           mrope_sections: tuple[int, ...] = (),
                           quant: str = "none", compute_dtype=jnp.bfloat16,
                           table: Optional[jax.Array] = None):
    """A contiguous S-token decode block in one call (speculative verify).

    x: [B, S, d]; pos: [B] int32 start positions — token i of a row sits at
    ``pos + i``.  All S writes land *before* attention, and the causal mask
    hides keys past ``pos + i`` from query i, so output position i is
    bit-identical to what S sequential :func:`decode_attention` calls would
    produce (same einsum contractions, per-row independent reductions —
    the chunked-prefill differentials' invariant).

    Only the full-length (non-rolling) cache layout: SWA rings are excluded
    from speculative rounds by the engine's eligibility check.  Negative
    ``pos`` rows (free slots) clamp their writes into their own row / the
    null page and keep every key masked, exactly like single-token decode.
    """
    B, S = x.shape[:2]
    paged = table is not None
    T = table.shape[1] * cache_k.shape[1] if paged else cache_k.shape[1]
    q = _proj_qkv(p, "wq", x, B, S, n_heads, head_dim, quant, compute_dtype)
    k = _proj_qkv(p, "wk", x, B, S, n_kv, head_dim, quant, compute_dtype)
    v = _proj_qkv(p, "wv", x, B, S, n_kv, head_dim, quant, compute_dtype)
    posv = _pos_vec(pos, B)
    q_pos = posv[:, None] + jnp.arange(S, dtype=jnp.int32)[None]   # [B,S]
    if rope_mode == "mrope":
        mpos = jnp.broadcast_to(q_pos[..., None], (B, S, 3))
        q = apply_mrope(q, mpos, mrope_sections, rope_theta)
        k = apply_mrope(k, mpos, mrope_sections, rope_theta)
    elif rope_mode == "rope":
        q = apply_rope(q, q_pos, rope_theta)
        k = apply_rope(k, q_pos, rope_theta)
    if paged:
        # S sequential table writes (deterministic, and unmapped/free rows
        # collapse into the null page exactly like single-token decode)
        for i in range(S):
            slot = jnp.clip(posv + i, 0, T - 1)
            cache_k = paged_write(cache_k, table, slot, k[:, i:i + 1])
            cache_v = paged_write(cache_v, table, slot, v[:, i:i + 1])
        dense_k = paged_gather(cache_k, table)
        dense_v = paged_gather(cache_v, table)
    else:
        cache_k = dense_k = _write_kv_block(cache_k, k, posv)
        cache_v = dense_v = _write_kv_block(cache_v, v, posv)
    # free rows keep posv < 0 so every key stays masked for them
    k_pos = decode_kv_positions(jnp.where(posv >= 0, posv + (S - 1), posv),
                                T, rolling=False)
    out = full_attention(q, dense_k, dense_v, q_pos, k_pos, causal=True,
                         window=None, logit_softcap=logit_softcap)
    y = _proj_out(p, out.astype(compute_dtype), B, S, n_heads, head_dim,
                  quant, compute_dtype)
    return y, cache_k, cache_v


# ---------------------------------------------------------------------------
# int8-quantized KV cache decode (beyond-paper: the paper's integer-MAC idea
# applied to the decode bottleneck — KV bytes halve vs bf16, QK^T and PV run
# as int8 MACs with fp32 rescale; per-token-per-head scales)
# ---------------------------------------------------------------------------

def quantize_kv(x: jax.Array):
    """x: [B, T, H, D] -> (int8 codes, scales [B, T, H])."""
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1),
                        1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def int8_kv_attention(q: jax.Array, k_q: jax.Array, k_scale: jax.Array,
                      v_q: jax.Array, v_scale: jax.Array,
                      q_pos: jax.Array, k_pos: jax.Array, *,
                      window: Optional[int] = None,
                      logit_softcap: Optional[float] = None) -> jax.Array:
    """Decode attention over an int8 cache. q: [B,1,Hq,D] float."""
    B, S, Hq, D = q.shape
    Hkv = k_q.shape[2]
    G = Hq // Hkv
    # integer QK^T: quantize q per (b, head) row
    qg = q.reshape(B, S, Hkv, G, D)
    q_scale = jnp.maximum(jnp.max(jnp.abs(qg.astype(jnp.float32)), axis=-1),
                          1e-8) / 127.0
    q_int = jnp.clip(jnp.round(qg.astype(jnp.float32) / q_scale[..., None]),
                     -127, 127).astype(jnp.int8)
    s_int = jnp.einsum("bshgd,bkhd->bshgk", q_int, k_q,
                       preferred_element_type=jnp.int32)
    # scale[b,s,h,g,t] = q_scale[b,s,h,g] * k_scale[b,t,h]
    scale = q_scale[..., None] \
        * jnp.moveaxis(k_scale, 1, -1)[:, None, :, None, :]
    s = s_int.astype(jnp.float32) * scale / math.sqrt(D)
    if logit_softcap is not None:
        s = logit_softcap * stable_tanh(s / logit_softcap)
    keep = _mask(q_pos, k_pos, True, window)
    s = jnp.where(keep[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # integer PV: fold the per-key v_scale into p (exact), then quantize the
    # effective probabilities to int8 rows
    vs = jnp.moveaxis(v_scale, 1, -1)[:, None, :, None, :]   # [B,1,Hkv,1,T]
    p_eff = p * vs
    p_scale = jnp.maximum(jnp.max(jnp.abs(p_eff), axis=-1), 1e-12) / 127.0
    p_int = jnp.round(p_eff / p_scale[..., None]).astype(jnp.int8)
    o_int = jnp.einsum("bshgk,bkhd->bshgd", p_int, v_q,
                       preferred_element_type=jnp.int32)
    o = o_int.astype(jnp.float32) * p_scale[..., None]
    return o.reshape(B, S, Hq, D)


def decode_attention_int8(p: Params, x: jax.Array, cache: dict,
                          pos: jax.Array, *, n_heads: int, n_kv: int,
                          head_dim: int, window: Optional[int] = None,
                          logit_softcap: Optional[float] = None,
                          rope_theta: float = 10000.0, rope_mode: str = "rope",
                          mrope_sections: tuple[int, ...] = (),
                          quant: str = "none", compute_dtype=jnp.bfloat16,
                          table: Optional[jax.Array] = None):
    """One decode step over an int8-quantized cache.

    cache: {"k": s8[B,T,Hkv,D], "v": s8, "k_scale": f32[B,T,Hkv],
            "v_scale": f32[B,T,Hkv]}.  pos: scalar or per-sequence [B].
    ``table`` switches the four cache leaves to paged pools
    ([P, page_size, ...] — int8 codes AND their per-token-per-head scales
    page together, so every page carries its own scales).
    """
    B = x.shape[0]
    paged = table is not None
    T = table.shape[1] * cache["k"].shape[1] if paged else cache["k"].shape[1]
    q = _proj_qkv(p, "wq", x, B, 1, n_heads, head_dim, quant, compute_dtype)
    k = _proj_qkv(p, "wk", x, B, 1, n_kv, head_dim, quant, compute_dtype)
    v = _proj_qkv(p, "wv", x, B, 1, n_kv, head_dim, quant, compute_dtype)
    posv = _pos_vec(pos, B)
    posb = posv[:, None]
    if rope_mode == "rope":
        q = apply_rope(q, posb, rope_theta)
        k = apply_rope(k, posb, rope_theta)
    elif rope_mode == "mrope":
        mpos = jnp.broadcast_to(posb[..., None], (B, 1, 3))
        q = apply_mrope(q, mpos, mrope_sections, rope_theta)
        k = apply_mrope(k, mpos, mrope_sections, rope_theta)
    k_new, ks_new = quantize_kv(k)
    v_new, vs_new = quantize_kv(v)
    slot = jnp.clip(posv, 0, T - 1)
    cache = dict(cache)
    write = ((lambda c, n: paged_write(c, table, slot, n)) if paged
             else (lambda c, n: _write_kv_slot(c, n, slot)))
    cache["k"] = write(cache["k"], k_new)
    cache["v"] = write(cache["v"], v_new)
    cache["k_scale"] = write(cache["k_scale"], ks_new)
    cache["v_scale"] = write(cache["v_scale"], vs_new)
    dense = ((lambda c: paged_gather(c, table)) if paged else (lambda c: c))
    k_pos = decode_kv_positions(posv, T, rolling=False)
    out = int8_kv_attention(q, dense(cache["k"]), dense(cache["k_scale"]),
                            dense(cache["v"]), dense(cache["v_scale"]),
                            posb, k_pos, window=window,
                            logit_softcap=logit_softcap)
    y = _proj_out(p, out.astype(compute_dtype), B, 1, n_heads, head_dim,
                  quant, compute_dtype)
    return y, cache


def cross_attention(p: Params, x: jax.Array, enc: jax.Array, *,
                    n_heads: int, n_kv: int, head_dim: int,
                    quant: str = "none", compute_dtype=jnp.bfloat16):
    """Encoder-decoder cross attention (Whisper decoder)."""
    B, S, _ = x.shape
    T = enc.shape[1]
    q = linear(p["wq"], x, quant, compute_dtype).reshape(B, S, -1, head_dim)
    k = linear(p["wk"], enc, quant, compute_dtype).reshape(B, T, -1, head_dim)
    v = linear(p["wv"], enc, quant, compute_dtype).reshape(B, T, -1, head_dim)
    q_pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    k_pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    out = full_attention(q, k, v, q_pos, k_pos, causal=False)
    return linear(p["wo"], out.reshape(B, S, -1).astype(compute_dtype),
                  quant, compute_dtype)
