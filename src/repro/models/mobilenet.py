"""MobileNetV2 — the paper's evaluation network — in pure JAX, quantizable.

Convolutions lower to im2col + matmul (the paper's "convolution generator"
feeds a matrix-vector multiplication kernel the same way, Sec. 3.4), so the
LUT-multiplication path applies unchanged.  The streamlined inference path
(BN + scales absorbed into multi-threshold units, integer-only datapath) is in
:func:`streamlined_forward` and validated against the float path.

Width multiplier + resolution are configurable; ``smoke`` configs use width
0.25 at 32x32 input.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantization import A4, A8, W4, W8, fake_quant
from repro.core.fpga_model import ConvLayer

# (expansion t, out channels c, repeats n, stride s) — Sandler et al. Table 2
INVERTED_RESIDUAL_CFG = [
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
]


@dataclasses.dataclass(frozen=True)
class MobileNetConfig:
    name: str = "mobilenetv2"
    width: float = 1.0
    resolution: int = 224
    n_classes: int = 1000
    quant: str = "none"              # none | qat
    first_last_bits: int = 8         # paper: 8-bit first/last layers
    inner_bits: int = 4


def _c(ch: float, width: float) -> int:
    v = max(8, int(ch * width + 4) // 8 * 8)
    return v


def _conv_shapes(cfg: MobileNetConfig):
    """Yields (name, cin, cout, k, stride, depthwise, h_in)."""
    layers = []
    res = cfg.resolution
    cin = 3
    cout = _c(32, cfg.width)
    layers.append(("stem", cin, cout, 3, 2, False, res))
    res //= 2
    cin = cout
    for bi, (t, c, n, s) in enumerate(INVERTED_RESIDUAL_CFG):
        cout = _c(c, cfg.width)
        for i in range(n):
            stride = s if i == 0 else 1
            exp = cin * t
            if t != 1:
                layers.append((f"b{bi}_{i}_expand", cin, exp, 1, 1, False, res))
            layers.append((f"b{bi}_{i}_dw", exp, exp, 3, stride, True, res))
            res = res // stride
            layers.append((f"b{bi}_{i}_project", exp, cout, 1, 1, False, res))
            cin = cout
    head = max(_c(1280, cfg.width), 1280 if cfg.width >= 1.0 else _c(1280, cfg.width))
    layers.append(("head", cin, head, 1, 1, False, res))
    return layers, res, head


def fpga_layer_table(cfg: MobileNetConfig) -> list[ConvLayer]:
    """The dataflow-model view used by core/fpga_model (Table 2 reproduction)."""
    layers, _, _ = _conv_shapes(cfg)
    out = []
    for (name, cin, cout, k, s, dw, h_in) in layers:
        h_out = h_in // s
        bits = 8 if name in ("stem", "head") else 4
        out.append(ConvLayer(name=name, cin=cin, cout=cout, k=k, h_out=h_out,
                             w_out=h_out, stride=s, depthwise=dw, bits=bits))
    return out


def init_params(key, cfg: MobileNetConfig) -> dict:
    layers, res, head = _conv_shapes(cfg)
    params = {}
    keys = jax.random.split(key, len(layers) + 1)
    for kk, (name, cin, cout, k, s, dw, _) in zip(keys, layers):
        fan_in = k * k * (1 if dw else cin)
        params[name] = {
            "w": jax.random.normal(kk, (k, k, 1 if dw else cin, cout),
                                   jnp.float32) / jnp.sqrt(fan_in),
            "bn_gamma": jnp.ones((cout,)), "bn_beta": jnp.zeros((cout,)),
            "bn_mean": jnp.zeros((cout,)), "bn_var": jnp.ones((cout,)),
        }
    params["fc"] = {
        "w": jax.random.normal(keys[-1], (head, cfg.n_classes), jnp.float32)
        * 0.01,
        "b": jnp.zeros((cfg.n_classes,)),
    }
    return params


def _conv(p, x, k, stride, depthwise, quant_bits: Optional[int], train_qat: bool):
    w = p["w"]
    if train_qat and quant_bits:
        wcfg = W4 if quant_bits == 4 else W8
        w = fake_quant(w, dataclasses.replace(wcfg, channel_axis=-1))
    dn = jax.lax.conv_dimension_numbers(x.shape, w.shape,
                                        ("NHWC", "HWIO", "NHWC"))
    pad = "SAME"
    y = jax.lax.conv_general_dilated(
        x, w.astype(x.dtype), (stride, stride), pad,
        dimension_numbers=dn,
        feature_group_count=x.shape[-1] if depthwise else 1)
    return y


def _bn_relu6(p, x, quant_bits: Optional[int], train_qat: bool):
    inv = p["bn_gamma"] / jnp.sqrt(p["bn_var"] + 1e-5)
    y = x * inv + (p["bn_beta"] - p["bn_mean"] * inv)
    y = jnp.clip(y, 0.0, 6.0)
    if train_qat and quant_bits:
        acfg = A4 if quant_bits == 4 else A8
        y = fake_quant(y, acfg)
    return y


def _bn_only(p, x):
    inv = p["bn_gamma"] / jnp.sqrt(p["bn_var"] + 1e-5)
    return x * inv + (p["bn_beta"] - p["bn_mean"] * inv)


def forward(params: dict, cfg: MobileNetConfig, x: jax.Array,
            train_qat: Optional[bool] = None) -> jax.Array:
    """x: [B, H, W, 3] -> logits [B, n_classes]."""
    train_qat = cfg.quant == "qat" if train_qat is None else train_qat
    fb, ib = cfg.first_last_bits, cfg.inner_bits
    x = _conv(params["stem"], x, 3, 2, False, fb, train_qat)
    x = _bn_relu6(params["stem"], x, fb, train_qat)
    for bi, (t, c, n, s) in enumerate(INVERTED_RESIDUAL_CFG):
        for i in range(n):
            stride = s if i == 0 else 1
            inp = x
            h = x
            if t != 1:
                name = f"b{bi}_{i}_expand"
                h = _bn_relu6(params[name],
                              _conv(params[name], h, 1, 1, False, ib, train_qat),
                              ib, train_qat)
            name = f"b{bi}_{i}_dw"
            h = _bn_relu6(params[name],
                          _conv(params[name], h, 3, stride, True, ib, train_qat),
                          ib, train_qat)
            name = f"b{bi}_{i}_project"
            h = _bn_only(params[name],
                         _conv(params[name], h, 1, 1, False, ib, train_qat))
            if stride == 1 and inp.shape == h.shape:   # inverted residual
                h = h + inp
            x = h
    x = _bn_relu6(params["head"], _conv(params["head"], x, 1, 1, False, fb,
                                        train_qat), fb, train_qat)
    x = jnp.mean(x, axis=(1, 2))
    return x @ params["fc"]["w"] + params["fc"]["b"]


def loss_fn(params: dict, cfg: MobileNetConfig, batch: dict) -> jax.Array:
    logits = forward(params, cfg, batch["images"]).astype(jnp.float32)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)
