"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings [B, enc_seq, d_model]; the encoder is 32 layers
of bidirectional attention + GELU MLP (LayerNorm, sinusoidal positions), the
decoder is causal self-attention + cross-attention to the encoder output.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.models import attention as attn_lib
from repro.models.layers import (init_embedding, init_mlp, layer_norm,
                                 linear, mlp)
from repro.models.transformer import ModelConfig


def sinusoids(length: int, d: int) -> jnp.ndarray:
    log_timescale = math.log(10000.0) / (d // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(d // 2, dtype=jnp.float32))
    t = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(t), jnp.cos(t)], axis=1)


def _init_enc_block(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": {"scale": jnp.ones((cfg.d_model,), cfg.pdtype),
                "bias": jnp.zeros((cfg.d_model,), cfg.pdtype)},
        "attn": attn_lib.init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv,
                                        cfg.head_dim, True, cfg.pdtype),
        "ln2": {"scale": jnp.ones((cfg.d_model,), cfg.pdtype),
                "bias": jnp.zeros((cfg.d_model,), cfg.pdtype)},
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, "gelu", cfg.pdtype),
    }


def _init_dec_block(key, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    def ln():
        return {"scale": jnp.ones((cfg.d_model,), cfg.pdtype),
                "bias": jnp.zeros((cfg.d_model,), cfg.pdtype)}
    return {
        "ln1": ln(),
        "self_attn": attn_lib.init_attention(k1, cfg.d_model, cfg.n_heads,
                                             cfg.n_kv, cfg.head_dim, True,
                                             cfg.pdtype),
        "ln_x": ln(),
        "cross_attn": attn_lib.init_attention(k2, cfg.d_model, cfg.n_heads,
                                              cfg.n_kv, cfg.head_dim, True,
                                              cfg.pdtype),
        "ln2": ln(),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, "gelu", cfg.pdtype),
    }


def init_params(key, cfg: ModelConfig) -> dict:
    nE, nD = cfg.n_enc_layers, cfg.n_layers
    keys = jax.random.split(key, nE + nD + 3)
    enc = [ _init_enc_block(keys[i], cfg) for i in range(nE) ]
    dec = [ _init_dec_block(keys[nE + i], cfg) for i in range(nD) ]
    def stack(blocks):
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)

    def ln():
        return {"scale": jnp.ones((cfg.d_model,), cfg.pdtype),
                "bias": jnp.zeros((cfg.d_model,), cfg.pdtype)}
    return {
        "enc_blocks": stack(enc),
        "dec_blocks": stack(dec),
        "enc_ln": ln(),
        "dec_ln": ln(),
        "embed": init_embedding(keys[-1], cfg.vocab, cfg.d_model, cfg.pdtype),
    }


def encode(params: dict, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames: [B, T_enc, d_model] (stub frontend output)."""
    cd = cfg.cdtype
    B, T, _ = frames.shape
    x = frames.astype(cd) + sinusoids(T, cfg.d_model).astype(cd)[None]
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    q = cfg.quant

    def body(x, bp):
        h = layer_norm(bp["ln1"], x)
        x = x + attn_lib.attention(bp["attn"], h, pos, n_heads=cfg.n_heads,
                                   n_kv=cfg.n_kv, head_dim=cfg.head_dim,
                                   causal=False, rope_mode="none",
                                   kv_block=cfg.kv_block, quant=q,
                                   compute_dtype=cd)
        h = layer_norm(bp["ln2"], x)
        x = x + mlp(bp["mlp"], h, "gelu", q, cd)
        return constrain(x, "batch", "seq", None), None

    body_fn = body
    if cfg.remat != "none":
        body_fn = jax.checkpoint(body, prevent_cse=False)
    from repro.models.transformer import maybe_scan
    x, _ = maybe_scan(body_fn, x, params["enc_blocks"], cfg.unroll_groups)
    return layer_norm(params["enc_ln"], x)


def dec_forward(params: dict, cfg: ModelConfig, tokens: jax.Array,
                enc_out: jax.Array, return_cache: bool = False):
    cd = cfg.cdtype
    B, S = tokens.shape
    x = params["embed"]["emb"].astype(cd)[tokens]
    x = x + sinusoids(S, cfg.d_model).astype(cd)[None]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    q = cfg.quant

    def body(x, bp):
        h = layer_norm(bp["ln1"], x)
        y, (k, v) = attn_lib.attention(bp["self_attn"], h, pos,
                                       n_heads=cfg.n_heads, n_kv=cfg.n_kv,
                                       head_dim=cfg.head_dim, causal=True,
                                       rope_mode="none", kv_block=cfg.kv_block,
                                       quant=q, compute_dtype=cd,
                                       return_kv=True)
        x = x + y
        h = layer_norm(bp["ln_x"], x)
        x = x + attn_lib.cross_attention(bp["cross_attn"], h, enc_out,
                                         n_heads=cfg.n_heads, n_kv=cfg.n_kv,
                                         head_dim=cfg.head_dim, quant=q,
                                         compute_dtype=cd)
        h = layer_norm(bp["ln2"], x)
        x = x + mlp(bp["mlp"], h, "gelu", q, cd)
        x = constrain(x, "batch", "seq", None)
        return x, ((k.astype(cd), v.astype(cd)) if return_cache else None)

    body_fn = body
    if cfg.remat != "none":
        body_fn = jax.checkpoint(body, prevent_cse=False)
    from repro.models.transformer import maybe_scan
    x, kv = maybe_scan(body_fn, x, params["dec_blocks"], cfg.unroll_groups)
    x = layer_norm(params["dec_ln"], x)
    logits = x @ params["embed"]["emb"].astype(cd).T
    logits = constrain(logits, "batch", "seq", "vocab")
    if return_cache:
        return logits, kv
    return logits


def prefill(params: dict, cfg: ModelConfig, frames: jax.Array,
            tokens: jax.Array):
    """Encode + decoder prefill. Returns (last-token logits, cache)."""
    enc_out = encode(params, cfg, frames)
    logits, (ks, vs) = dec_forward(params, cfg, tokens, enc_out,
                                   return_cache=True)
    cache = {"k": ks, "v": vs}
    cache = precompute_cross_kv(params, cfg, enc_out, cache)
    return logits[:, -1].astype(jnp.float32), cache


def forward(params: dict, cfg: ModelConfig, frames: jax.Array,
            tokens: jax.Array) -> jax.Array:
    return dec_forward(params, cfg, tokens, encode(params, cfg, frames))


def loss_fn(params: dict, cfg: ModelConfig, batch: dict) -> jax.Array:
    logits = forward(params, cfg, batch["frames"], batch["tokens"])
    logits = logits.astype(jnp.float32)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# serving: decoder KV-cache decode with precomputed cross-attention K/V
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    cd = cfg.cdtype
    L = cfg.n_layers
    return {
        "k": jnp.zeros((L, batch, max_len, cfg.n_kv, cfg.head_dim), cd),
        "v": jnp.zeros((L, batch, max_len, cfg.n_kv, cfg.head_dim), cd),
        "xk": jnp.zeros((L, batch, cfg.enc_seq, cfg.n_kv, cfg.head_dim), cd),
        "xv": jnp.zeros((L, batch, cfg.enc_seq, cfg.n_kv, cfg.head_dim), cd),
    }


def init_paged_cache(cfg: ModelConfig, batch: int, max_len: int,
                     num_pages: int, page_size: int) -> dict:
    """Paged form of :func:`init_cache`: the decoder self-attention K/V
    become shared ``[L, num_pages, page_size, n_kv, head_dim]`` page pools
    addressed through a per-slot page table (see ``serve.paged``).  The
    cross-attention K/V stay dense — they are precomputed once per request
    at full encoder length (``enc_seq``) and never grow."""
    cd = cfg.cdtype
    L = cfg.n_layers
    if max_len % page_size:
        raise ValueError(f"page_size ({page_size}) must divide max_len "
                         f"({max_len})")
    return {
        "k": jnp.zeros((L, num_pages, page_size, cfg.n_kv, cfg.head_dim), cd),
        "v": jnp.zeros((L, num_pages, page_size, cfg.n_kv, cfg.head_dim), cd),
        "xk": jnp.zeros((L, batch, cfg.enc_seq, cfg.n_kv, cfg.head_dim), cd),
        "xv": jnp.zeros((L, batch, cfg.enc_seq, cfg.n_kv, cfg.head_dim), cd),
    }


def precompute_cross_kv(params: dict, cfg: ModelConfig, enc_out: jax.Array,
                        cache: dict) -> dict:
    cd = cfg.cdtype
    B, T, _ = enc_out.shape

    def per_layer(bp):
        k = linear(bp["cross_attn"]["wk"], enc_out, cfg.quant, cd)
        v = linear(bp["cross_attn"]["wv"], enc_out, cfg.quant, cd)
        return (k.reshape(B, T, -1, cfg.head_dim),
                v.reshape(B, T, -1, cfg.head_dim))

    xk, xv = jax.vmap(per_layer)(params["dec_blocks"])
    return {**cache, "xk": xk.astype(cd), "xv": xv.astype(cd)}


def decode_step(params: dict, cfg: ModelConfig, token: jax.Array,
                cache: dict, pos: jax.Array, tables=None):
    """token: [B]; pos: scalar or per-sequence [B] int32.
    Returns (logits [B, V], cache).

    ``tables`` (paged serving): ``(full_table [B, E], _)`` — the decoder
    self-attention K/V leaves are then page pools (see ``init_paged_cache``)
    and every write/read goes through the per-slot page-table row."""
    cd = cfg.cdtype
    B = token.shape[0]
    x = params["embed"]["emb"].astype(cd)[token][:, None, :]
    full_t = tables[0] if tables is not None else None
    T = (full_t.shape[1] * cache["k"].shape[2] if full_t is not None
         else cache["k"].shape[2])
    posv = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(pos, jnp.int32)), (B,))
    pe = jnp.take(sinusoids(T, cfg.d_model).astype(cd),
                  jnp.clip(posv, 0, T - 1), axis=0)       # [B, d]
    x = x + pe[:, None, :]
    q = cfg.quant

    def body(carry, scanned):
        x, = carry
        bp, ck, cv, xk, xv = scanned
        h = layer_norm(bp["ln1"], x)
        y, ck, cv = attn_lib.decode_attention(
            bp["self_attn"], h, ck, cv, posv, n_heads=cfg.n_heads,
            n_kv=cfg.n_kv, head_dim=cfg.head_dim, rope_mode="none",
            quant=q, compute_dtype=cd, table=full_t)
        x = x + y
        h = layer_norm(bp["ln_x"], x)
        qh = linear(bp["cross_attn"]["wq"], h, q, cd).reshape(
            B, 1, -1, cfg.head_dim)
        pos_q = jnp.zeros((B, 1), jnp.int32)
        pos_k = jnp.broadcast_to(jnp.arange(xk.shape[1], dtype=jnp.int32)[None],
                                 (B, xk.shape[1]))
        o = attn_lib.full_attention(qh, xk, xv, pos_q, pos_k, causal=False)
        x = x + linear(bp["cross_attn"]["wo"],
                       o.reshape(B, 1, -1).astype(cd), q, cd)
        h = layer_norm(bp["ln2"], x)
        x = x + mlp(bp["mlp"], h, "gelu", q, cd)
        return (x,), (ck, cv)

    from repro.models.transformer import maybe_scan
    (x,), (ks, vs) = maybe_scan(
        body, (x,), (params["dec_blocks"], cache["k"], cache["v"],
                     cache["xk"], cache["xv"]), cfg.unroll_groups)
    cache = {**cache, "k": ks, "v": vs}
    x = layer_norm(params["dec_ln"], x)
    logits = (x[:, 0] @ params["embed"]["emb"].astype(cd).T).astype(jnp.float32)
    return logits, cache
