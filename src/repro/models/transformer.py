"""Decoder-LM assembly: pattern-based blocks, scanned over repeated groups.

A model is ``embed -> scan(groups) -> final_norm -> lm_head`` where one group
is one repetition of ``cfg.pattern`` (e.g. Gemma-2: (local, global) x 13;
Zamba2: (mamba x 6 + shared attn at position 0) x 9; RWKV6: (rwkv,) x 24).
Scanning over groups keeps the HLO small (critical for 512-device dry-run
compiles) and makes remat policies uniform.

All block params for one pattern position are stacked along a leading G axis.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.models import attention as attn_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (init_embedding, init_linear, init_mlp,
                                 init_norm, layer_norm, mlp, rms_norm,
                                 softcap)
from repro.models.moe import MoEConfig, init_moe, moe_ffn


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockSpec:
    kind: str = "attn"              # attn | mamba2 | rwkv6
    attn_type: str = "global"       # global | local
    mlp: str = "swiglu"             # swiglu | geglu | gelu | moe | rwkv_cm | none
    shared_attn: bool = False       # prepend the shared attention block (zamba2)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    vocab: int
    pattern: tuple[BlockSpec, ...] = (BlockSpec(),)
    window: Optional[int] = None
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    rope_theta: float = 10000.0
    rope_mode: str = "rope"         # rope | mrope | none
    mrope_sections: tuple[int, ...] = ()
    qkv_bias: bool = False
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    gemma_norms: bool = False       # zero-centered scale + post-block norms
    tie_embeddings: bool = False
    embed_scale: bool = False
    moe: Optional[MoEConfig] = None
    # ssm / rwkv
    d_inner: int = 0
    d_state: int = 0
    ssm_heads: int = 0
    rwkv_heads: int = 0
    # enc-dec (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500
    frontend: str = "none"          # none | audio | vision (stubs)
    # execution
    quant: str = "none"             # none|qat|w4a4_lut|w4a4_mxu|w8a8|
                                    # w{1,2,3,4}a{4,8}[_tmac]|
                                    # ternary_a{4,8}[_tmac] (tmac bitplanes)
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "full"             # full | dots | none
    kv_block: int = 1024
    split_head_params: bool = False  # store QKV/O as [d,H,dh] (3D) — head
                                     # sharding without reshape straddling
    rwkv_chunk: int = 32            # WKV chunk length (memory-term lever)
    kv_quant: str = "none"          # none | int8 — quantized decode KV cache
    unroll_groups: bool = False     # dry-run: unroll the group scan so
                                    # cost_analysis counts every layer
    long_context_ok: bool = False   # sub-quadratic family -> long_500k runs

    @property
    def n_groups(self) -> int:
        assert self.n_layers % len(self.pattern) == 0
        return self.n_layers // len(self.pattern)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, spec: BlockSpec) -> dict:
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {}
    dt = cfg.pdtype
    if spec.kind == "attn":
        p["ln1"] = init_norm(cfg.d_model, dt)
        p["attn"] = attn_lib.init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                            cfg.n_kv, cfg.head_dim,
                                            cfg.qkv_bias, dt,
                                            split_heads=cfg.split_head_params)
        if cfg.gemma_norms:
            p["post_attn_ln"] = init_norm(cfg.d_model, dt)
    elif spec.kind == "mamba2":
        p["ln1"] = init_norm(cfg.d_model, dt)
        p["mamba"] = ssm_lib.init_mamba2(ks[0], cfg.d_model, cfg.d_inner,
                                         cfg.d_state, cfg.ssm_heads, dtype=dt)
    elif spec.kind == "rwkv6":
        p["ln1"] = init_norm(cfg.d_model, dt)
        p["tmix"] = ssm_lib.init_rwkv6(ks[0], cfg.d_model, cfg.rwkv_heads,
                                       dtype=dt)
    else:
        raise ValueError(spec.kind)
    if spec.mlp == "moe":
        p["ln2"] = init_norm(cfg.d_model, dt)
        p["moe"] = init_moe(ks[1], cfg.d_model, cfg.moe, dt)
    elif spec.mlp == "rwkv_cm":
        p["ln2"] = init_norm(cfg.d_model, dt)
        p["cmix"] = ssm_lib.init_rwkv6_chanmix(ks[1], cfg.d_model, cfg.d_ff, dt)
    elif spec.mlp != "none":
        p["ln2"] = init_norm(cfg.d_model, dt)
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, spec.mlp, dt)
        if cfg.gemma_norms:
            p["post_mlp_ln"] = init_norm(cfg.d_model, dt)
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, cfg.n_layers + 4)
    G, P = cfg.n_groups, len(cfg.pattern)
    # stack per pattern-position
    blocks = []
    for pi, spec in enumerate(cfg.pattern):
        per_group = [
            _init_block(keys[g * P + pi], cfg, spec) for g in range(G)
        ]
        blocks.append(jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *per_group))
    params = {
        "embed": init_embedding(keys[-1], cfg.vocab, cfg.d_model, cfg.pdtype),
        "blocks": tuple(blocks),
        "final_norm": init_norm(cfg.d_model, cfg.pdtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_linear(keys[-2], cfg.d_model, cfg.vocab,
                                        dtype=cfg.pdtype)
    if any(s.shared_attn for s in cfg.pattern):
        params["shared_attn"] = {
            "ln": init_norm(cfg.d_model, cfg.pdtype),
            "attn": attn_lib.init_attention(keys[-3], cfg.d_model, cfg.n_heads,
                                            cfg.n_kv, cfg.head_dim,
                                            cfg.qkv_bias, cfg.pdtype),
            "mlp_ln": init_norm(cfg.d_model, cfg.pdtype),
            "mlp": init_mlp(keys[-4], cfg.d_model, cfg.d_ff, "swiglu",
                            cfg.pdtype),
        }
    return params


# ---------------------------------------------------------------------------
# forward (full sequence: train / prefill)
# ---------------------------------------------------------------------------

def _norm(pnorm, x, cfg: ModelConfig):
    if cfg.norm == "layernorm":
        return layer_norm(pnorm, x)
    return rms_norm(pnorm, x, zero_centered=cfg.gemma_norms)


def _block_fwd(bp: dict, spec: BlockSpec, cfg: ModelConfig, x: jax.Array,
               positions: jax.Array, shared_p: Optional[dict],
               mrope_positions=None, aux_acc=None):
    cd = cfg.cdtype
    if spec.shared_attn and shared_p is not None:
        h = _norm(shared_p["ln"], x, cfg)
        x = x + attn_lib.attention(
            shared_p["attn"], h, positions, n_heads=cfg.n_heads,
            n_kv=cfg.n_kv, head_dim=cfg.head_dim, causal=True,
            rope_theta=cfg.rope_theta, rope_mode=cfg.rope_mode,
            kv_block=cfg.kv_block, quant=_infer_quant(cfg),
            compute_dtype=cd)
        h = _norm(shared_p["mlp_ln"], x, cfg)
        x = x + mlp(shared_p["mlp"], h, "swiglu", _infer_quant(cfg), cd)
    h = _norm(bp["ln1"], x, cfg)
    if spec.kind == "attn":
        window = cfg.window if spec.attn_type == "local" else None
        y = attn_lib.attention(
            bp["attn"], h, positions, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
            head_dim=cfg.head_dim, causal=True, window=window,
            logit_softcap=cfg.attn_softcap, rope_theta=cfg.rope_theta,
            rope_mode=cfg.rope_mode, mrope_sections=cfg.mrope_sections,
            mrope_positions=mrope_positions, kv_block=cfg.kv_block,
            quant=_infer_quant(cfg), compute_dtype=cd)
        if cfg.gemma_norms:
            y = _norm(bp["post_attn_ln"], y, cfg)
        x = x + y
    elif spec.kind == "mamba2":
        x = x + ssm_lib.mamba2(bp["mamba"], h, d_inner=cfg.d_inner,
                               d_state=cfg.d_state, n_heads=cfg.ssm_heads,
                               quant=_infer_quant(cfg), compute_dtype=cd)
    elif spec.kind == "rwkv6":
        x = x + ssm_lib.rwkv6_timemix(bp["tmix"], h, n_heads=cfg.rwkv_heads,
                                      chunk=cfg.rwkv_chunk,
                                      quant=_infer_quant(cfg), compute_dtype=cd)
    if spec.mlp == "moe":
        h = _norm(bp["ln2"], x, cfg)
        y, aux = moe_ffn(bp["moe"], h, cfg.moe, quant=_infer_quant(cfg),
                         compute_dtype=cd)
        x = x + y
        if aux_acc is not None:
            aux_acc = aux_acc + aux
    elif spec.mlp == "rwkv_cm":
        h = _norm(bp["ln2"], x, cfg)
        h_prev = jnp.pad(h, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        x = x + ssm_lib.rwkv6_chanmix(bp["cmix"], h, h_prev,
                                      quant=_infer_quant(cfg), compute_dtype=cd)
    elif spec.mlp != "none":
        h = _norm(bp["ln2"], x, cfg)
        y = mlp(bp["mlp"], h, spec.mlp, quant=_infer_quant(cfg),
                compute_dtype=cd)
        if cfg.gemma_norms:
            y = _norm(bp["post_mlp_ln"], y, cfg)
        x = x + y
    x = constrain(x, "batch", "seq", None)
    return x, aux_acc


def _infer_quant(cfg: ModelConfig) -> str:
    return cfg.quant


def _remat_policy(cfg: ModelConfig):
    if cfg.remat == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return None


def maybe_scan(body, carry, xs, unroll: bool):
    """lax.scan, or an unrolled python loop (dry-run cost accounting)."""
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    G = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for g in range(G):
        xg = jax.tree_util.tree_map(lambda a: a[g], xs)
        carry, y = body(carry, xg)
        ys.append(y)
    if all(y is None for y in ys):
        return carry, None
    return carry, jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)


def _lm_head(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Final projection; handles tied embeddings and pre-quantized heads."""
    if cfg.tie_embeddings:
        return x @ params["embed"]["emb"].T.astype(x.dtype)
    lh = params["lm_head"]
    if "w_q" in lh:
        from repro.dist.tp import leaf_tp_mode
        from repro.kernels.lutmul import ops as lut_ops
        return lut_ops.prequant_matmul(x, lh["w_q"], lh["w_scale"],
                                       mode=cfg.quant, compute_dtype=x.dtype,
                                       tp=leaf_tp_mode(lh))
    return x @ lh["w"].astype(x.dtype)


def forward(params: dict, cfg: ModelConfig, tokens: jax.Array,
            embeddings: Optional[jax.Array] = None,
            mrope_positions: Optional[jax.Array] = None) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (logits, aux_loss).

    ``embeddings`` (if given) bypasses the token embed — the stub modality
    frontend path for [audio]/[vlm] archs.
    """
    cd = cfg.cdtype
    if embeddings is not None:
        x = embeddings.astype(cd)
        B, S = x.shape[:2]
    else:
        B, S = tokens.shape
        x = params["embed"]["emb"].astype(cd)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cd)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = constrain(x, "batch", "seq", None)
    shared_p = params.get("shared_attn")

    def group_body(carry, group_params):
        x, aux = carry
        for bp, spec in zip(group_params, cfg.pattern):
            x, aux = _block_fwd(bp, spec, cfg, x, positions, shared_p,
                                mrope_positions, aux)
        return (x, aux), None

    body = group_body
    if cfg.remat != "none":
        body = jax.checkpoint(group_body, policy=_remat_policy(cfg),
                              prevent_cse=False)
    (x, aux), _ = maybe_scan(body, (x, jnp.zeros((), jnp.float32)),
                             params["blocks"], cfg.unroll_groups)
    x = _norm(params["final_norm"], x, cfg)
    logits = _lm_head(params, cfg, x.astype(cd))
    logits = constrain(logits, "batch", "seq", "vocab")
    if cfg.final_softcap:
        logits = softcap(logits, cfg.final_softcap)
    return logits, aux


def loss_fn(params: dict, cfg: ModelConfig, batch: dict) -> jax.Array:
    """Causal LM loss (mean NLL) + MoE aux. batch: tokens [B,S+1] or
    (tokens, labels)."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    embeddings = batch.get("embeddings")
    logits, aux = forward(params, cfg, tokens, embeddings=embeddings,
                          mrope_positions=batch.get("mrope_positions"))
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = jnp.mean(logz - gold)
    return nll + aux


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode with typed caches
# ---------------------------------------------------------------------------

def _roll_local(k: jax.Array, S: int, W: int) -> jax.Array:
    """Last-W slice arranged so slot i holds the token with abs_pos % W == i
    (matches decode_attention's ring-buffer addressing)."""
    tail = k[:, max(0, S - W):]
    if S < W:
        tail = jnp.pad(tail, ((0, 0), (0, W - S)) + ((0, 0),) * (k.ndim - 2))
        return tail
    return jnp.roll(tail, S % W, axis=1)


def _block_prefill(bp, cache_tmpl, spec: BlockSpec, cfg: ModelConfig,
                   x, positions, shared_p, mrope_positions=None,
                   full_kv: bool = False):
    """Like _block_fwd but also emits the cache entry for decode handoff.

    ``full_kv=True`` keeps local/SWA layers' K/V at full sequence length
    instead of rolling them into a window-size ring — the serving scheduler
    stitches the ring itself from the true (traced) prompt length, so padded
    prompt buckets never leak junk into ring slots.
    """
    cd = cfg.cdtype
    q = _infer_quant(cfg)
    S = x.shape[1]
    cache = {}
    if spec.shared_attn and shared_p is not None:
        h = _norm(shared_p["ln"], x, cfg)
        y, (sk, sv) = attn_lib.attention(
            shared_p["attn"], h, positions, n_heads=cfg.n_heads,
            n_kv=cfg.n_kv, head_dim=cfg.head_dim, causal=True,
            rope_theta=cfg.rope_theta, rope_mode=cfg.rope_mode,
            kv_block=cfg.kv_block, quant=q, compute_dtype=cd, return_kv=True)
        x = x + y
        h = _norm(shared_p["mlp_ln"], x, cfg)
        x = x + mlp(shared_p["mlp"], h, "swiglu", q, cd)
        cache["shared_k"], cache["shared_v"] = sk.astype(cd), sv.astype(cd)
    h = _norm(bp["ln1"], x, cfg)
    if spec.kind == "attn":
        window = cfg.window if spec.attn_type == "local" else None
        y, (k, v) = attn_lib.attention(
            bp["attn"], h, positions, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
            head_dim=cfg.head_dim, causal=True, window=window,
            logit_softcap=cfg.attn_softcap, rope_theta=cfg.rope_theta,
            rope_mode=cfg.rope_mode, mrope_sections=cfg.mrope_sections,
            mrope_positions=mrope_positions, kv_block=cfg.kv_block,
            quant=q, compute_dtype=cd, return_kv=True)
        if cfg.gemma_norms:
            y = _norm(bp["post_attn_ln"], y, cfg)
        x = x + y
        if (spec.attn_type == "local" and cfg.window and cfg.window < S
                and not full_kv):
            cache["k"] = _roll_local(k.astype(cd), S, cfg.window)
            cache["v"] = _roll_local(v.astype(cd), S, cfg.window)
        else:
            cache["k"], cache["v"] = k.astype(cd), v.astype(cd)
    elif spec.kind == "mamba2":
        y, st = ssm_lib.mamba2(bp["mamba"], h, d_inner=cfg.d_inner,
                               d_state=cfg.d_state, n_heads=cfg.ssm_heads,
                               quant=q, compute_dtype=cd, return_state=True)
        x = x + y
        cache["h"], cache["conv"] = st.h, st.conv.astype(cd)
    elif spec.kind == "rwkv6":
        y, (Sf, xlast) = ssm_lib.rwkv6_timemix(
            bp["tmix"], h, n_heads=cfg.rwkv_heads, chunk=cfg.rwkv_chunk,
            quant=q, compute_dtype=cd, return_state=True)
        x = x + y
        cache["S"], cache["xt"] = Sf, xlast.astype(cd)
    if spec.mlp == "moe":
        h = _norm(bp["ln2"], x, cfg)
        y, _ = moe_ffn(bp["moe"], h, cfg.moe, quant=q, compute_dtype=cd)
        x = x + y
    elif spec.mlp == "rwkv_cm":
        h = _norm(bp["ln2"], x, cfg)
        h_prev = jnp.pad(h, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        x = x + ssm_lib.rwkv6_chanmix(bp["cmix"], h, h_prev, quant=q,
                                      compute_dtype=cd)
        cache["xc"] = h[:, -1:].astype(cd)
    elif spec.mlp != "none":
        h = _norm(bp["ln2"], x, cfg)
        y = mlp(bp["mlp"], h, spec.mlp, quant=q, compute_dtype=cd)
        if cfg.gemma_norms:
            y = _norm(bp["post_mlp_ln"], y, cfg)
        x = x + y
    x = constrain(x, "batch", "seq", None)
    return x, cache


def prefill(params: dict, cfg: ModelConfig, tokens: jax.Array,
            embeddings: Optional[jax.Array] = None,
            mrope_positions: Optional[jax.Array] = None,
            full_kv: bool = False, length: Optional[jax.Array] = None):
    """Full-sequence forward that also returns the decode cache.

    Returns (last_token_logits [B, V], cache) — cache layout matches
    ``init_cache`` per pattern position (attn K/V sized S, or window for
    local/rolling layers; SSM/RWKV final states).

    ``full_kv=True`` keeps local-layer K/V at full length (the serving
    scheduler arranges the ring at stitch time).  ``length`` ([B] or scalar
    int32) selects the logits position for right-padded prompt buckets:
    logits are taken at ``length - 1`` instead of the last position (pad
    tokens sit after the prompt, so causal masking keeps them out of every
    real token's attention).
    """
    cd = cfg.cdtype
    if embeddings is not None:
        x = embeddings.astype(cd)
        B, S = x.shape[:2]
    else:
        B, S = tokens.shape
        x = params["embed"]["emb"].astype(cd)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cd)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = constrain(x, "batch", "seq", None)
    shared_p = params.get("shared_attn")

    def group_body(x, group_params):
        caches = []
        for bp, spec in zip(group_params, cfg.pattern):
            x, c = _block_prefill(bp, None, spec, cfg, x, positions, shared_p,
                                  mrope_positions, full_kv=full_kv)
            caches.append(c)
        return x, tuple(caches)

    body = group_body
    if cfg.remat != "none":
        body = jax.checkpoint(group_body, policy=_remat_policy(cfg),
                              prevent_cse=False)
    x, cache = maybe_scan(body, x, params["blocks"], cfg.unroll_groups)
    x = _norm(params["final_norm"], x, cfg)
    if length is None:
        xl = x[:, -1]
    else:
        last = jnp.clip(jnp.broadcast_to(
            jnp.atleast_1d(jnp.asarray(length, jnp.int32)), (B,)) - 1,
            0, S - 1)
        xl = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0]
    logits = _lm_head(params, cfg, xl.astype(cd)).astype(jnp.float32)
    if cfg.final_softcap:
        logits = softcap(logits, cfg.final_softcap)
    return logits, cache

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> tuple:
    """Per-pattern-position stacked caches (leading G dim)."""
    G = cfg.n_groups
    caches = []
    cd = cfg.cdtype
    for spec in cfg.pattern:
        if spec.kind == "attn":
            is_local = spec.attn_type == "local" and cfg.window
            T = min(max_len, cfg.window) if is_local else max_len
            if cfg.kv_quant == "int8" and not is_local:
                c = {"k": jnp.zeros((G, batch, T, cfg.n_kv, cfg.head_dim),
                                    jnp.int8),
                     "v": jnp.zeros((G, batch, T, cfg.n_kv, cfg.head_dim),
                                    jnp.int8),
                     "k_scale": jnp.zeros((G, batch, T, cfg.n_kv),
                                          jnp.float32),
                     "v_scale": jnp.zeros((G, batch, T, cfg.n_kv),
                                          jnp.float32)}
            else:
                c = {"k": jnp.zeros((G, batch, T, cfg.n_kv, cfg.head_dim), cd),
                     "v": jnp.zeros((G, batch, T, cfg.n_kv, cfg.head_dim), cd)}
            if spec.shared_attn:
                c["shared_k"] = jnp.zeros((G, batch, max_len, cfg.n_kv,
                                           cfg.head_dim), cd)
                c["shared_v"] = jnp.zeros((G, batch, max_len, cfg.n_kv,
                                           cfg.head_dim), cd)
        elif spec.kind == "mamba2":
            P = cfg.d_inner // cfg.ssm_heads
            c = {"h": jnp.zeros((G, batch, cfg.ssm_heads, cfg.d_state, P),
                                jnp.float32),
                 "conv": jnp.zeros((G, batch, 3, cfg.d_inner + 2 * cfg.d_state),
                                   cd)}
            if spec.shared_attn:
                c["shared_k"] = jnp.zeros((G, batch, max_len, cfg.n_kv,
                                           cfg.head_dim), cd)
                c["shared_v"] = jnp.zeros((G, batch, max_len, cfg.n_kv,
                                           cfg.head_dim), cd)
        elif spec.kind == "rwkv6":
            K = cfg.d_model // cfg.rwkv_heads
            c = {"S": jnp.zeros((G, batch, cfg.rwkv_heads, K, K), jnp.float32),
                 "xt": jnp.zeros((G, batch, 1, cfg.d_model), cd),
                 "xc": jnp.zeros((G, batch, 1, cfg.d_model), cd)}
        caches.append(c)
    return tuple(caches)


def init_paged_cache(cfg: ModelConfig, batch: int, max_len: int,
                     num_pages: int, page_size: int) -> tuple:
    """Paged form of :func:`init_cache`: every attention K/V leaf (incl.
    int8-KV scale planes and zamba2's shared-attention K/V) becomes a shared
    ``[G, num_pages, page_size, ...]`` page pool — per-slot addressing lives
    in the scheduler's page tables, not here.  SWA ring layers use the same
    pool shape (their pages are addressed through the ring table).
    Recurrent (mamba2 / rwkv6) states have no sequence axis and stay dense
    per-slot buffers of ``batch`` rows."""
    G = cfg.n_groups
    sds = jax.eval_shape(lambda: init_cache(cfg, batch, max_len))
    caches = []
    for spec, c in zip(cfg.pattern, sds):
        out = {}
        for key, leaf in c.items():
            if key in ("k", "v", "shared_k", "shared_v"):
                out[key] = jnp.zeros(
                    (G, num_pages, page_size) + leaf.shape[3:], leaf.dtype)
            elif key in ("k_scale", "v_scale"):
                out[key] = jnp.zeros((G, num_pages, page_size, cfg.n_kv),
                                     jnp.float32)
            else:
                out[key] = jnp.zeros(leaf.shape, leaf.dtype)
        caches.append(out)
    return tuple(caches)


def _block_decode(bp: dict, cache: dict, spec: BlockSpec, cfg: ModelConfig,
                  x: jax.Array, pos: jax.Array, shared_p: Optional[dict],
                  tables=None):
    cd = cfg.cdtype
    q = _infer_quant(cfg)
    # paged decode: attn cache leaves are [pages, page_size, ...] pools;
    # full-length layers index through tables[0], SWA rings through
    # tables[1] (exclusively-owned page-aligned windows)
    full_t = tables[0] if tables is not None else None
    if spec.shared_attn and shared_p is not None:
        h = _norm(shared_p["ln"], x, cfg)
        y, ck, cv = attn_lib.decode_attention(
            shared_p["attn"], h, cache["shared_k"], cache["shared_v"], pos,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta, rope_mode=cfg.rope_mode,
            quant=q, compute_dtype=cd, table=full_t)
        x = x + y
        h = _norm(shared_p["mlp_ln"], x, cfg)
        x = x + mlp(shared_p["mlp"], h, "swiglu", q, cd)
        cache = {**cache, "shared_k": ck, "shared_v": cv}
    h = _norm(bp["ln1"], x, cfg)
    if spec.kind == "attn":
        window = cfg.window if spec.attn_type == "local" else None
        is_local = spec.attn_type == "local" and cfg.window is not None
        if tables is not None:
            rolling = is_local
            attn_t = tables[1] if is_local else full_t
        else:
            rolling = is_local and cache["k"].shape[1] <= cfg.window
            attn_t = None
        if "k_scale" in cache:
            y, c8 = attn_lib.decode_attention_int8(
                bp["attn"], h, cache, pos, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv, head_dim=cfg.head_dim, window=window,
                logit_softcap=cfg.attn_softcap, rope_theta=cfg.rope_theta,
                rope_mode=cfg.rope_mode, mrope_sections=cfg.mrope_sections,
                quant=q, compute_dtype=cd, table=attn_t)
            if cfg.gemma_norms:
                y = _norm(bp["post_attn_ln"], y, cfg)
            x = x + y
            cache = {**cache, **{kk: c8[kk] for kk in
                                 ("k", "v", "k_scale", "v_scale")}}
            return _finish_block_decode(bp, cache, spec, cfg, x, q, cd)
        y, ck, cv = attn_lib.decode_attention(
            bp["attn"], h, cache["k"], cache["v"], pos,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.head_dim,
            window=window, logit_softcap=cfg.attn_softcap,
            rope_theta=cfg.rope_theta, rope_mode=cfg.rope_mode,
            mrope_sections=cfg.mrope_sections, rolling=rolling,
            quant=q, compute_dtype=cd, table=attn_t)
        if cfg.gemma_norms:
            y = _norm(bp["post_attn_ln"], y, cfg)
        x = x + y
        cache = {**cache, "k": ck, "v": cv}
    elif spec.kind == "mamba2":
        st = ssm_lib.Mamba2State(h=cache["h"], conv=cache["conv"])
        y, st = ssm_lib.mamba2_decode(bp["mamba"], h, st, d_inner=cfg.d_inner,
                                      d_state=cfg.d_state,
                                      n_heads=cfg.ssm_heads, quant=q,
                                      compute_dtype=cd)
        x = x + y
        cache = {**cache, "h": st.h, "conv": st.conv}
    elif spec.kind == "rwkv6":
        st = ssm_lib.RWKVState(S=cache["S"], x_prev_t=cache["xt"],
                               x_prev_c=cache["xc"])
        y, st = ssm_lib.rwkv6_timemix_decode(bp["tmix"], h, st,
                                             n_heads=cfg.rwkv_heads, quant=q,
                                             compute_dtype=cd)
        x = x + y
        cache = {**cache, "S": st.S, "xt": st.x_prev_t}
    return _finish_block_decode(bp, cache, spec, cfg, x, q, cd)


def _finish_block_decode(bp, cache, spec, cfg, x, q, cd):
    """MLP / MoE / channel-mix tail of a decode block."""
    if spec.mlp == "moe":
        h = _norm(bp["ln2"], x, cfg)
        det_cap = None
        if cfg.moe.dispatch == "global":
            det_cap = max(1, int(x.shape[0] * cfg.moe.top_k
                                 / cfg.moe.n_experts
                                 * cfg.moe.capacity_factor) + 1)
        y, _ = moe_ffn(bp["moe"], h, cfg.moe, quant=q, compute_dtype=cd,
                       deterministic_capacity=det_cap)
        x = x + y
    elif spec.mlp == "rwkv_cm":
        h = _norm(bp["ln2"], x, cfg)
        x = x + ssm_lib.rwkv6_chanmix(bp["cmix"], h, cache["xc"], quant=q,
                                      compute_dtype=cd)
        cache = {**cache, "xc": h}
    elif spec.mlp != "none":
        h = _norm(bp["ln2"], x, cfg)
        y = mlp(bp["mlp"], h, spec.mlp, quant=q, compute_dtype=cd)
        if cfg.gemma_norms:
            y = _norm(bp["post_mlp_ln"], y, cfg)
        x = x + y
    return x, cache


def _block_verify(bp: dict, cache: dict, spec: BlockSpec, cfg: ModelConfig,
                  x: jax.Array, pos: jax.Array, tables=None):
    """S-token decode block for the speculative verify forward.

    Only chunk-eligible attention stacks reach here (the engine's
    spec_decode eligibility raises for recurrent / MoE / int8-KV / SWA /
    shared-attention patterns at construction)."""
    cd = cfg.cdtype
    q = _infer_quant(cfg)
    if (spec.kind != "attn" or spec.shared_attn
            or (spec.attn_type == "local" and cfg.window)
            or spec.mlp in ("moe", "rwkv_cm") or "k_scale" in cache):
        raise ValueError(
            f"verify_step cannot run block spec {spec} (kv_quant="
            f"{cfg.kv_quant!r}): speculative decoding supports plain "
            "full-length attention blocks only")
    full_t = tables[0] if tables is not None else None
    h = _norm(bp["ln1"], x, cfg)
    y, ck, cv = attn_lib.decode_attention_multi(
        bp["attn"], h, cache["k"], cache["v"], pos,
        n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.head_dim,
        logit_softcap=cfg.attn_softcap, rope_theta=cfg.rope_theta,
        rope_mode=cfg.rope_mode, mrope_sections=cfg.mrope_sections,
        quant=q, compute_dtype=cd, table=full_t)
    if cfg.gemma_norms:
        y = _norm(bp["post_attn_ln"], y, cfg)
    x = x + y
    cache = {**cache, "k": ck, "v": cv}
    return _finish_block_decode(bp, cache, spec, cfg, x, q, cd)


def verify_step(params: dict, cfg: ModelConfig, tokens: jax.Array,
                cache: tuple, pos: jax.Array,
                tables=None) -> tuple[jax.Array, tuple]:
    """S tokens for the whole batch in ONE forward (speculative verify).

    tokens: [B, S] int32 — token i of a row logically sits at ``pos + i``;
    pos: [B] int32 start positions (negative marks a free slot).  Returns
    (logits [B, S, V], cache): ``logits[:, i]`` conditions on
    ``tokens[:, :i+1]`` plus the cache history, bit-identical to S
    sequential :func:`decode_step` calls, because every KV write lands
    before attention and the causal mask hides keys past ``pos + i`` from
    query i.  The batched [B*S] matmuls are where the verify step beats S
    sequential target steps."""
    cd = cfg.cdtype
    x = params["embed"]["emb"].astype(cd)[tokens]               # [B,S,d]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cd)

    def group_body(carry, scanned):
        x, = carry
        gp, gc = scanned
        out_caches = []
        for bp, c, spec in zip(gp, gc, cfg.pattern):
            x, c = _block_verify(bp, c, spec, cfg, x, pos, tables=tables)
            out_caches.append(c)
        return (x,), tuple(out_caches)

    (x,), cache = maybe_scan(group_body, (x,),
                             (params["blocks"], cache), cfg.unroll_groups)
    x = _norm(params["final_norm"], x, cfg)
    logits = _lm_head(params, cfg, x.astype(cd)).astype(jnp.float32)
    if cfg.final_softcap:
        logits = softcap(logits, cfg.final_softcap)
    return logits, cache


def decode_step(params: dict, cfg: ModelConfig, token: jax.Array,
                cache: tuple, pos: jax.Array,
                tables=None) -> tuple[jax.Array, tuple]:
    """One token for the whole batch. token: [B] int32; pos: scalar int32 or
    per-sequence [B] int32 (continuous batching — each slot at its own depth;
    negative marks a free slot whose keys stay masked).

    ``tables`` (paged serving): a ``(full_table [B, E], ring_table [B, Er])``
    pair of int32 page tables — the attention cache leaves are then shared
    page pools instead of per-slot dense buffers (see ``serve.paged``)."""
    cd = cfg.cdtype
    x = params["embed"]["emb"].astype(cd)[token][:, None, :]    # [B,1,d]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cd)
    shared_p = params.get("shared_attn")

    # scan over groups per pattern position jointly (tables are
    # scan-invariant: every group indexes the same per-slot page rows)
    def group_body(carry, scanned):
        x, = carry
        gp, gc = scanned                 # tuple(params), tuple(cache)
        out_caches = []
        for bp, c, spec in zip(gp, gc, cfg.pattern):
            x, c = _block_decode(bp, c, spec, cfg, x, pos, shared_p,
                                 tables=tables)
            out_caches.append(c)
        return (x,), tuple(out_caches)

    (x,), cache = maybe_scan(group_body, (x,),
                             (params["blocks"], cache), cfg.unroll_groups)
    x = _norm(params["final_norm"], x, cfg)
    logits = _lm_head(params, cfg, x[:, 0].astype(cd)).astype(jnp.float32)
    if cfg.final_softcap:
        logits = softcap(logits, cfg.final_softcap)
    return logits, cache
