"""Shared layer primitives: quantizable linears, norms, rotary embeddings, MLPs.

Everything is a pure function over an explicit param pytree (no flax).  Param
initializers return nested dicts; apply functions take (params, x, cfg).

The paper's technique enters through :func:`linear`: every dense projection can
run in one of four modes (selected per-config, the LUTMUL feature being
first-class):

  * ``none``     — bf16/fp32 matmul (the unquantized baseline)
  * ``qat``      — fake-quant W4A4 straight-through (training path, Sec. 3.6)
  * ``w4a4_lut`` — table-lookup integer matmul (kernels/lutmul; faithful path)
  * ``w4a4_mxu`` — int4-weight/int4-act matmul on the MXU with int32
                   accumulation (the TPU performance embodiment)
  * ``w8a8``     — the "DSP packing" analogue baseline
  * tmac family  — ``w{1,2,3,4}a{4,8}_tmac`` / ``ternary_a{4,8}_tmac``:
                   weight-bitplane x activation-group-table kernel whose
                   cost is linear in the weight bit count (kernels/lutmul
                   docstring); suffix-free sub-4 modes ("w2a4") let the
                   formulation autotuner pick tmac vs one-hot per shape
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.quantization import A4, W4, fake_quant

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def init_linear(key, d_in: int, d_out: int, bias: bool = False,
                dtype=jnp.float32, scale: float | None = None) -> Params:
    std = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * std}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def init_norm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def init_embedding(key, vocab: int, d: int, dtype=jnp.float32) -> Params:
    return {"emb": jax.random.normal(key, (vocab, d), dtype) * 0.02}


# ---------------------------------------------------------------------------
# quantizable linear
# ---------------------------------------------------------------------------

def linear(p: Params, x: jax.Array, quant: str = "none",
           compute_dtype=jnp.bfloat16) -> jax.Array:
    """Dense projection with selectable quantization mode (see module doc).

    If the param leaf carries pre-quantized serving codes (``w_q`` +
    ``w_scale``, produced by serve/quantize.py), the integer path is used
    regardless of ``quant`` — weights are read from HBM as codes.
    """
    if "w_q" in p:
        from repro.dist.tp import leaf_tp_mode
        from repro.kernels.lutmul import ops as lut_ops
        mode = quant
        if "w_tmac" in p:
            # tmac bitplane leaf: the leaf's own width (plane count +
            # ternary marker — static pytree structure) overrides the
            # global mode's, so mixed-bit plans Just Work; activation bits
            # follow the global mode
            try:
                abits = lut_ops.parse_mode(quant)[2]
            except ValueError:
                abits = 4
            if "w_tern" in p:
                mode = f"ternary_a{abits}_tmac"
            else:
                mode = f"w{p['w_q'].shape[0]}a{abits}_tmac"
        y = lut_ops.prequant_matmul(x, p["w_q"], p["w_scale"], mode=mode,
                                    compute_dtype=compute_dtype,
                                    tp=leaf_tp_mode(p))
        if "b" in p:
            y = y + p["b"].astype(y.dtype)
        return y
    w = p["w"]
    if quant == "none":
        y = x.astype(compute_dtype) @ w.astype(compute_dtype)
    elif quant == "qat":
        wq = fake_quant(w.astype(jnp.float32), W4)
        xq = fake_quant(jax.nn.relu(x.astype(jnp.float32)), A4) + (
            x.astype(jnp.float32) - jax.nn.relu(x.astype(jnp.float32)))
        # weights fake-quantized; activations fake-quantized on the positive
        # part (threshold units emit unsigned codes), negative part passes for
        # gradient flow on pre-activation values.
        y = (xq @ wq).astype(compute_dtype)
    else:
        from repro.kernels.lutmul import ops as lut_ops
        lut_ops.parse_mode(quant)   # raises with the mode grammar on typos
        y = lut_ops.quantized_matmul(x, w, mode=quant,
                                     compute_dtype=compute_dtype)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(p: Params, x: jax.Array, eps: float = 1e-6,
             zero_centered: bool = False) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    scale = p["scale"].astype(jnp.float32)
    if zero_centered:          # gemma-style (1 + scale)
        scale = 1.0 + scale
    return (xf * scale).astype(x.dtype)


def layer_norm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = xf * p["scale"].astype(jnp.float32)
    if "bias" in p:
        out = out + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0
               ) -> jax.Array:
    """x: [B, S, H, D]; positions: [B, S] int32."""
    freqs = rope_freqs(x.shape[-1], theta)                     # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs   # [B, S, D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, sections: tuple[int, ...],
                theta: float = 1_000_000.0) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    positions: [B, S, 3] (temporal, height, width) position ids; ``sections``
    splits the D/2 frequency channels among the three components (e.g.
    (16, 24, 24) for head_dim 128).  Text tokens carry identical t/h/w ids, in
    which case M-RoPE degenerates to standard RoPE (tested property).
    """
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)                                # [D/2]
    # build a per-channel position by selecting the t/h/w id per section
    sec_ids = jnp.repeat(jnp.arange(len(sections)),
                         jnp.array(sections), total_repeat_length=D // 2)
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),                           # [B, S, 3]
        jnp.broadcast_to(sec_ids, positions.shape[:2] + (D // 2,)).astype(jnp.int32) % 3,
        axis=-1)                                                 # [B, S, D/2]
    angles = pos * freqs
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, d_ff: int, kind: str = "swiglu",
             dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {"wi": init_linear(k1, d, d_ff, dtype=dtype),
                "wg": init_linear(k2, d, d_ff, dtype=dtype),
                "wo": init_linear(k3, d_ff, d, dtype=dtype)}
    return {"wi": init_linear(k1, d, d_ff, dtype=dtype),
            "wo": init_linear(k2, d_ff, d, dtype=dtype)}


def mlp(p: Params, x: jax.Array, kind: str = "swiglu", quant: str = "none",
        compute_dtype=jnp.bfloat16) -> jax.Array:
    if kind == "swiglu":
        h = jax.nn.silu(linear(p["wg"], x, quant, compute_dtype)) \
            * linear(p["wi"], x, quant, compute_dtype)
    elif kind == "geglu":
        h = jax.nn.gelu(linear(p["wg"], x, quant, compute_dtype),
                        approximate=True) \
            * linear(p["wi"], x, quant, compute_dtype)
    elif kind == "gelu":
        h = jax.nn.gelu(linear(p["wi"], x, quant, compute_dtype),
                        approximate=True)
    elif kind == "relu_sq":                  # rwkv channel-mix style
        h = jnp.square(jax.nn.relu(linear(p["wi"], x, quant, compute_dtype)))
    else:
        raise ValueError(kind)
    return linear(p["wo"], h, quant, compute_dtype)


def stable_tanh(x: jax.Array) -> jax.Array:
    """tanh with a bit-stable lowering across tensor shapes.

    XLA:CPU lowers ``jnp.tanh`` through a vectorized rational approximation
    whose last-ulp rounding depends on the buffer shape it was compiled for,
    so the SAME input values can produce different bits in a [B, S, ...]
    prefill tensor vs a [B, 1, ...] decode tensor.  Serving needs the two
    paths bit-identical (chunked prefill replays prompts through the decode
    step).  exp IS shape-stable on every backend this repo targets — the
    padded-bucket admission invariance already leans on that — so route
    tanh through exp: tanh(x) = sign(x) * (1 - e^(-2|x|)) / (1 + e^(-2|x|)),
    numerically safe for all x (the exponent is always <= 0) and within
    1 ulp of the libm value.
    """
    e = jnp.exp(-2.0 * jnp.abs(x))
    return jnp.sign(x) * (1.0 - e) / (1.0 + e)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    return (cap * stable_tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# weight-code caching
# ---------------------------------------------------------------------------

class QuantizedLinear:
    """A linear layer that quantizes + packs its weight codes ONCE.

    ``quantized_matmul`` re-derives integer codes from the float weight on
    every call — fine for QAT experiments, wasteful for inference, where the
    weight never changes.  This wrapper converts the param leaf to the
    serving layout ({"w_q", "w_scale"}) at construction; every forward call
    then takes the pre-quantized path (``prequant_matmul``) and performs no
    weight quantization or packing (the invariant
    ``tests`` assert via ``ops.WEIGHT_QUANT_COUNT``).

    >>> qlin = QuantizedLinear(p, mode="w4a4_lut")   # quantize + pack once
    >>> y = qlin(x)                                  # codes reused
    """

    def __init__(self, p: Params, mode: str = "w4a4_mxu"):
        from repro.kernels.lutmul import ops as lut_ops
        if mode in ("none", "qat"):
            raise ValueError(
                f"unsupported quant mode {mode!r}: QuantizedLinear caches "
                "integer serving codes; float/QAT paths use layers.linear")
        lut_ops.parse_mode(mode)             # raises on unknown modes
        self.mode = mode
        if "w_q" in p:                       # already serving codes
            self.p = dict(p)
        else:
            from repro.serve.quantize import quantize_leaf_mode
            self.p = quantize_leaf_mode(p["w"], mode)
            if "b" in p:
                self.p["b"] = p["b"]

    @property
    def params(self) -> Params:
        """The cached serving leaf ({"w_q", "w_scale"[, "b"]})."""
        return self.p

    def __call__(self, x: jax.Array,
                 compute_dtype=jnp.bfloat16) -> jax.Array:
        return linear(self.p, x, quant=self.mode, compute_dtype=compute_dtype)
