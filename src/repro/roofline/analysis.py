"""Three-term roofline from compiled artifacts (TPU v5e constants).

  compute    = HLO_FLOPs_per_device / peak_FLOPs
  memory     = HLO_bytes_per_device / HBM_bw
  collective = sum over collective ops of ring-model per-device link bytes / link_bw

``cost_analysis()`` on the CPU SPMD backend reports *per-partition* flops/bytes
(verified empirically in tests), so no division by chip count is applied.
Collective bytes are parsed from the partitioned HLO text; shapes there are
already per-device.  Ring formulas (B = per-device payload bytes, n = group
size): all-reduce 2(n-1)/n*B, all-gather (n-1)/n*B_result, reduce-scatter
(n-1)*B_result (= (n-1)/n * input), all-to-all (n-1)/n*B, collective-permute B.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

# TPU v5e, from the assignment
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        body = m.group(1).strip()
        return len(body.split(",")) if body else 1
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        n_groups, = (int(m.group(1)),)
        size = int(m.group(2))
        return size
    return 1


@dataclasses.dataclass
class Collective:
    op: str
    result_bytes: int
    group_size: int
    line: str

    @property
    def link_bytes(self) -> float:
        """Per-device ring-model bytes over the link."""
        n, b = self.group_size, self.result_bytes
        if self.op == "collective-permute":
            return float(b)
        if n <= 1:
            return 0.0
        if self.op == "all-reduce":
            return 2 * (n - 1) / n * b
        if self.op == "all-gather":
            return (n - 1) / n * b
        if self.op == "reduce-scatter":
            return (n - 1) * b          # input = n * result
        if self.op == "all-to-all":
            return (n - 1) / n * b
        return 0.0


def parse_collectives(hlo_text: str) -> list[Collective]:
    out = []
    for line in hlo_text.splitlines():
        s = line.strip()
        if not s or "=" not in s:
            continue
        m = re.search(
            r"=\s*(.*?)\s(all-reduce|all-gather|reduce-scatter|all-to-all|"
            r"collective-permute)(?:-start|-done)?\(", s)
        if not m:
            continue
        if "-done(" in s:     # avoid double counting start/done pairs
            continue
        result_type, op = m.group(1), m.group(2)
        out.append(Collective(op=op, result_bytes=_shape_bytes(result_type),
                              group_size=_group_size(s), line=s[:160]))
    return out


def roofline_terms(cost: dict, hlo_text: str) -> dict:
    """Returns the three terms (seconds) + supporting detail."""
    if isinstance(cost, (list, tuple)):   # jax<0.5 returns [dict] per program
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    hbm_bytes = float(cost.get("bytes accessed", 0.0))
    colls = parse_collectives(hlo_text)
    coll_bytes = sum(c.link_bytes for c in colls)
    per_op = {}
    for c in colls:
        d = per_op.setdefault(c.op, {"count": 0, "link_bytes": 0.0})
        d["count"] += 1
        d["link_bytes"] += c.link_bytes
    top = sorted(colls, key=lambda c: -c.link_bytes)[:8]
    return {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": hbm_bytes / HBM_BW,
        "collective_s": coll_bytes / LINK_BW,
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": hbm_bytes,
        "collective_link_bytes": coll_bytes,
        "collectives": per_op,
        "n_collectives": len(colls),
        "top_collectives": [
            {"op": c.op, "link_bytes": c.link_bytes, "n": c.group_size,
             "line": c.line[:140]} for c in top],
    }


def extrapolate_terms(t1g: dict, t2g: dict, n_groups: int) -> dict:
    """Per-group linear extrapolation: total = t1g + (G-1) * (t2g - t1g).

    The 1-group and 2-group programs share embed/head/loss/optimizer terms,
    so the delta isolates one group's cost exactly; collectives extrapolate
    per op type the same way.
    """
    g = n_groups
    out = {}
    for k in ("compute_s", "memory_s", "collective_s",
              "hlo_flops_per_device", "hlo_bytes_per_device",
              "collective_link_bytes"):
        out[k] = t1g[k] + (g - 1) * (t2g[k] - t1g[k])
    colls = {}
    ops = set(t1g["collectives"]) | set(t2g["collectives"])
    for op in ops:
        c1 = t1g["collectives"].get(op, {"count": 0, "link_bytes": 0.0})
        c2 = t2g["collectives"].get(op, {"count": 0, "link_bytes": 0.0})
        colls[op] = {
            "count": c1["count"] + (g - 1) * (c2["count"] - c1["count"]),
            "link_bytes": c1["link_bytes"]
            + (g - 1) * (c2["link_bytes"] - c1["link_bytes"]),
        }
    out["collectives"] = colls
    out["n_collectives"] = int(t1g["n_collectives"]
                               + (g - 1) * (t2g["n_collectives"]
                                            - t1g["n_collectives"]))
    out["extrapolated_from"] = "1g/2g delta"
    return out


def dominant(terms: dict) -> str:
    vals = {"compute": terms["compute_s"], "memory": terms["memory_s"],
            "collective": terms["collective_s"]}
    return max(vals, key=vals.get)


# ---------------------------------------------------------------------------
# MODEL_FLOPS (6ND / 2ND) accounting
# ---------------------------------------------------------------------------

def count_params(params_sds, moe_top_k: Optional[int] = None,
                 n_experts: Optional[int] = None) -> dict:
    """Returns {"total": N, "active": N_active} from an eval_shape'd tree."""
    import jax
    import numpy as np
    total = 0
    expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_sds)[0]:
        n = int(np.prod(leaf.shape))
        total += n
        name = jax.tree_util.keystr(path)
        if re.search(r"\['moe'\]\['w[igo]'\]", name):
            expert += n
    active = total
    if expert and moe_top_k and n_experts:
        active = total - expert + expert * moe_top_k / n_experts
    return {"total": total, "active": active}


def model_flops(kind: str, n_active: float, global_batch: int,
                seq_len: int) -> float:
    if kind == "train":
        return 6.0 * n_active * global_batch * seq_len
    if kind == "prefill":
        return 2.0 * n_active * global_batch * seq_len
    return 2.0 * n_active * global_batch          # decode: one token / seq


# ---------------------------------------------------------------------------
# mixed per-layer weight bit widths (tmac serving family)
# ---------------------------------------------------------------------------

# demotion ladder: width spec -> effective bits per weight
_BITS_LADDER = ((4, 4.0), (3, 3.0), (2, 2.0), ("ternary", 1.58), (1, 1.0))


def plan_mixed_bits(params, target_bits: float, abits: int = 4,
                    attn_floor: float = 2.0,
                    mlp_floor: float = 1.0) -> dict:
    """Choose per-leaf tmac weight widths hitting a target average bit width.

    The roofline says decode GEMVs are memory-bound (at M = batch tokens,
    ``memory_s = weight_bytes / HBM_BW`` dwarfs ``compute_s`` until M is in
    the hundreds), so decode latency IS weight bytes and the tmac kernel's
    cost is linear in the plane count either way — minimizing total weight
    bits minimizes both terms at once.  Greedy: repeatedly demote the leaf
    with the largest byte saving one ladder step (4 -> 3 -> 2 -> ternary ->
    1) until the parameter-weighted average reaches ``target_bits``, subject
    to floors (attention projections keep >= ``attn_floor`` bits — their
    quantization error feeds every downstream token through the KV cache;
    MLP >= ``mlp_floor``).  Embedding and lm_head are outside the plan
    entirely (the serving walk pins them 8-bit, the paper's first/last-layer
    rule).

    Returns ``{path: mode}`` keyed by the same ``"...['wq']['w']"`` path
    strings ``serve.quantize.quantize_params_for_serving`` builds — pass it
    as that function's ``bits_plan`` (or via ``ServeConfig.bits_plan``).
    Deterministic: ties break on path order.
    """
    import numpy as np
    from repro.serve.quantize import _INNER_W

    leaves: list[list] = []       # [path, n_params, is_attn, ladder_idx]

    def walk(tree, path=""):
        if isinstance(tree, dict):
            for k, v in tree.items():
                sub = f"{path}['{k}']"
                if isinstance(v, dict) and "w" in v and _INNER_W.search(
                        sub + "['w']") and getattr(v["w"], "ndim", 0) >= 2:
                    leaves.append([sub + "['w']",
                                   int(np.prod(v["w"].shape)),
                                   "['attn']" in sub, 0])
                else:
                    walk(v, sub)
        elif isinstance(tree, (tuple, list)):
            for i, v in enumerate(tree):
                walk(v, f"{path}[{i}]")

    walk(params)
    if not leaves:
        return {}
    total = sum(n for _, n, _, _ in leaves)

    def avg() -> float:
        return sum(n * _BITS_LADDER[i][1] for _, n, _, i in leaves) / total

    while avg() > target_bits:
        best, best_save = None, 0.0
        for leaf in leaves:
            _, n, is_attn, i = leaf
            if i + 1 >= len(_BITS_LADDER):
                continue
            floor = attn_floor if is_attn else mlp_floor
            if _BITS_LADDER[i + 1][1] < floor:
                continue
            save = n * (_BITS_LADDER[i][1] - _BITS_LADDER[i + 1][1])
            if save > best_save:
                best, best_save = leaf, save
        if best is None:          # every leaf at its floor
            break
        best[3] += 1

    def mode(spec) -> str:
        return (f"ternary_a{abits}_tmac" if spec == "ternary"
                else f"w{spec}a{abits}_tmac")

    return {path: mode(_BITS_LADDER[i][0]) for path, _, _, i in leaves}
