"""Analytic FPGA roofline + throughput model (paper Eq. 1-2, Table 1/2, Fig. 1).

This module reproduces the paper's *quantitative claims* that do not require
FPGA hardware: the DSP-vs-LUT peak-performance rooflines, the U280/V100
comparison table, and the MobileNetV2 dataflow throughput model that predicts
the paper's 1627 FPS / 978.6 GOPS result from folding factors.
"""
from __future__ import annotations

import dataclasses
import math

from .lut import luts_per_multiply


@dataclasses.dataclass(frozen=True)
class FPGASpec:
    name: str
    luts: int
    dsps: int
    bram36: int
    freq_hz: float
    hbm_bw: float           # bytes/s
    ddr_bw: float = 0.0
    power_w: float = 0.0


# AMD Xilinx Alveo U280 (paper Table 1)
U280 = FPGASpec(name="Alveo U280", luts=1_303_680, dsps=9024, bram36=2016,
                freq_hz=333e6, hbm_bw=460e9, ddr_bw=38e9, power_w=100.0)

# NVIDIA V100 PCIe (paper Table 1) — for the comparison rows only.
V100_PEAK_FP16_TENSOR = 112e12
V100_HBM_BW = 900e9


def dsp_packing_factor(bits: int) -> int:
    """p in Eq. (1): 1 for 16-bit, 2 for 8-bit, 4 for 4-bit MACs."""
    if bits <= 4:
        return 4
    if bits <= 8:
        return 2
    return 1


def dsp_peak_ops(spec: FPGASpec, bits: int = 4, frac: float = 1.0) -> float:
    """Eq. (1): peak = p * PEs * 2 * f  (ops/s) for DSP-based accelerators."""
    return dsp_packing_factor(bits) * (spec.dsps * frac) * 2 * spec.freq_hz


def lutmul_peak_ops(spec: FPGASpec, bits: int = 4, frac: float = 1.0,
                    lut_overhead: float = 1.0) -> float:
    """LUTMUL peak: (#LUTs / LUTs-per-multiplier) parallel MACs * 2 * f.

    ``lut_overhead`` > 1 accounts for adder-tree/control LUTs per multiplier
    (Fig. 6 shows roughly one adder LUT per ROM LUT after Vivado opt).
    """
    mults = (spec.luts * frac) / (luts_per_multiply(bits) * lut_overhead)
    return mults * 2 * spec.freq_hz


def memory_bound_ops(bw_bytes: float, ctc_ratio: float) -> float:
    """Eq. (2): attainable ops limited by bandwidth x compute-to-communication."""
    return bw_bytes * ctc_ratio


def roofline(spec: FPGASpec, bits: int = 4, frac: float = 1.0,
             lut_overhead: float = 2.0):
    """Returns the Fig. 1 curves: (arithmetic intensity -> attainable ops/s)."""
    dsp_peak = dsp_peak_ops(spec, bits, frac)
    lut_peak = lutmul_peak_ops(spec, bits, frac, lut_overhead)
    bw = spec.hbm_bw * frac

    def attainable(intensity_ops_per_byte: float, peak: float) -> float:
        return min(peak, bw * intensity_ops_per_byte)

    return {
        "dsp_peak_ops": dsp_peak,
        "lutmul_peak_ops": lut_peak,
        "bandwidth": bw,
        "dsp_attainable": lambda i: attainable(i, dsp_peak),
        "lutmul_attainable": lambda i: attainable(i, lut_peak),
        "dsp_ridge_intensity": dsp_peak / bw,
        "lutmul_ridge_intensity": lut_peak / bw,
    }


# ---------------------------------------------------------------------------
# Dataflow throughput model (Table 2): II=1 pixel pipeline + per-layer folding
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    """One conv layer of the dataflow pipeline."""
    name: str
    cin: int
    cout: int
    k: int               # kernel size
    h_out: int
    w_out: int
    stride: int = 1
    depthwise: bool = False
    bits: int = 4

    @property
    def mults(self) -> int:
        """Spatial multiplier count when fully unrolled (COUT x CIN x K^2)."""
        if self.depthwise:
            return self.cout * self.k * self.k
        return self.cout * self.cin * self.k * self.k

    @property
    def macs(self) -> int:
        return self.mults * self.h_out * self.w_out

    @property
    def ops(self) -> int:
        return 2 * self.macs


def layer_cycles(layer: ConvLayer, fold: int) -> int:
    """Pipeline initiation cycles for one frame: pixels x fold (II=1/pixel/fold)."""
    return layer.h_out * layer.w_out * fold


def layer_luts(layer: ConvLayer, fold: int, lut_overhead: float = 2.0) -> float:
    """LUT cost of one folded layer: (mults/fold) multipliers, Eq. (3) each,
    plus adder/control overhead (Fig. 6 calibration: ~1 extra LUT per ROM LUT)."""
    parallel_mults = layer.mults / fold
    return parallel_mults * luts_per_multiply(layer.bits) * lut_overhead


def pipeline_fps(layers: list[ConvLayer], folds: list[int], freq_hz: float) -> float:
    """Dataflow throughput = f / max_layer_cycles (steady-state, II-limited)."""
    bottleneck = max(layer_cycles(lyr, f) for lyr, f in zip(layers, folds))
    return freq_hz / bottleneck


def balance_folding(layers: list[ConvLayer], lut_budget: float,
                    freq_hz: float, lut_overhead: float = 2.0,
                    full_parallel_prefix: int = 0):
    """Choose per-layer folds to maximize FPS under a LUT budget.

    Strategy (matches the paper's design): layers in the fully-parallel prefix
    get fold=1; remaining layers get the smallest power-of-two fold such that
    the total LUT cost fits, balanced so every stage has similar cycle count.
    Binary-search the target cycle count; fold_l = ceil(target / pixels_l)
    capped to [1, mults_l].
    """
    def cost_at(target_cycles: float) -> tuple[float, list[int]]:
        folds = []
        for i, lyr in enumerate(layers):
            if i < full_parallel_prefix:
                folds.append(1)
                continue
            pixels = lyr.h_out * lyr.w_out
            fold = max(1, min(lyr.mults, math.ceil(target_cycles / pixels)))
            folds.append(fold)
        total = sum(layer_luts(lyr, f, lut_overhead)
                    for lyr, f in zip(layers, folds))
        return total, folds

    lo, hi = 1.0, 1e9
    best = None
    for _ in range(64):
        mid = math.sqrt(lo * hi)
        total, folds = cost_at(mid)
        if total <= lut_budget:
            best = (mid, folds, total)
            hi = mid
        else:
            lo = mid
    if best is None:
        raise ValueError("LUT budget too small even at maximum folding")
    target, folds, total = best
    return {
        "folds": folds,
        "total_luts": total,
        "fps": pipeline_fps(layers, folds, freq_hz),
        "bottleneck_cycles": max(layer_cycles(lyr, f)
                                 for lyr, f in zip(layers, folds)),
    }
