"""Quantization primitives — paper Eq. (4)/(5) — plus QAT fake-quant with STE.

The paper quantizes weights to signed int4 (symmetric, per-channel) and
activations to unsigned uint4 (the threshold units emit unsigned codes), with
8-bit first/last layers.  ``quantize``/``dequantize`` implement Eq. (4)/(5)
verbatim; ``fake_quant`` is the straight-through-estimator used during QAT;
``project_params`` is the post-update weight projection the paper describes in
Sec. 3.6 ("model parameters are quantized after each gradient update").
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Static description of one quantizer (weights or activations)."""

    bits: int = 4
    signed: bool = True            # weights: int4; activations: uint4
    per_channel: bool = True
    channel_axis: int = -1         # axis that keeps its own scale
    narrow_range: bool = False     # use [-(2^{b-1}-1), 2^{b-1}-1] when True

    @property
    def qmin(self) -> int:
        if not self.signed:
            return 0
        return -(2 ** (self.bits - 1)) + (1 if self.narrow_range else 0)

    @property
    def qmax(self) -> int:
        return (2 ** (self.bits - 1) - 1) if self.signed else (2 ** self.bits - 1)

    @property
    def n_levels(self) -> int:
        return self.qmax - self.qmin + 1


W4 = QuantConfig(bits=4, signed=True)
A4 = QuantConfig(bits=4, signed=False)
W8 = QuantConfig(bits=8, signed=True)
A8 = QuantConfig(bits=8, signed=False)


def _reduce_axes(x: jax.Array, cfg: QuantConfig) -> tuple[int, ...]:
    axis = cfg.channel_axis % x.ndim
    return tuple(a for a in range(x.ndim) if a != axis)


def compute_scale(x: jax.Array, cfg: QuantConfig, eps: float = 1e-8) -> jax.Array:
    """Max-abs (symmetric) scale; per-channel when configured.

    Keeps the reduced dims so the scale broadcasts against ``x``.
    """
    if cfg.per_channel and x.ndim > 1:
        amax = jnp.max(jnp.abs(x), axis=_reduce_axes(x, cfg), keepdims=True)
    else:
        amax = jnp.max(jnp.abs(x))
    # Unsigned quantizers map [0, amax] onto [0, qmax]; signed map [-amax, amax].
    denom = cfg.qmax if not cfg.signed else (2 ** (cfg.bits - 1) - 1)
    return jnp.maximum(amax, eps) / denom


def quantize(x: jax.Array, scale: jax.Array, zero_point: jax.Array | int,
             cfg: QuantConfig) -> jax.Array:
    """Paper Eq. (4): clamp(round(x / s + z), qmin, qmax) (round-to-even)."""
    q = jnp.round(x / scale + zero_point)
    return jnp.clip(q, cfg.qmin, cfg.qmax).astype(jnp.int8 if cfg.bits <= 8 else jnp.int32)


def dequantize(q: jax.Array, scale: jax.Array, zero_point: jax.Array | int = 0
               ) -> jax.Array:
    """Paper Eq. (5): s * (y - z)."""
    return (q.astype(scale.dtype if hasattr(scale, "dtype") else jnp.float32)
            - zero_point) * scale


def fake_quant(x: jax.Array, cfg: QuantConfig,
               scale: Optional[jax.Array] = None) -> jax.Array:
    """Straight-through-estimator fake quantization for QAT.

    Forward: dequantize(quantize(x)); backward: identity (gradients flow in
    floating point, per Sec. 3.6 of the paper).
    """
    if scale is None:
        scale = compute_scale(x, cfg)
    xq = dequantize(quantize(x, scale, 0, cfg), scale, 0)
    return x + jax.lax.stop_gradient(xq - x)


def quantize_pair(x: jax.Array, cfg: QuantConfig):
    """Returns (q, scale) with a freshly computed scale."""
    scale = compute_scale(x, cfg)
    return quantize(x, scale, 0, cfg), scale


def project_params(params, spec) -> object:
    """Post-update projection of weights onto the quantization grid.

    ``spec`` is a pytree-prefix of ``QuantConfig`` (or None to skip a leaf),
    matching the paper's QAT recipe: update in fp32, then snap weights to the
    quantized grid so the *forward* always sees representable weights.
    """
    def _proj(leaf, cfg):
        if cfg is None:
            return leaf
        return fake_quant(leaf, cfg)
    return jax.tree_util.tree_map(_proj, params, spec,
                                  is_leaf=lambda x: x is None)


def quant_error(x: jax.Array, cfg: QuantConfig) -> jax.Array:
    """Mean-squared quantization error (used by the Fig. 2 style sweep)."""
    scale = compute_scale(x, cfg)
    xq = dequantize(quantize(x, scale, 0, cfg), scale, 0)
    return jnp.mean((x - xq) ** 2)
