"""Multi-threshold activation units + FINN-style streamlining (paper Sec. 3.2/3.6).

The paper absorbs per-channel scaling factors and batch-norm into the
activation function, turning ``dequant -> BN -> act -> requant`` into a bank of
integer comparisons ("multi-threshold unit"):

    q_out = sum_k [ acc >= T[c, k] ],    k = 1 .. 2^bits - 1

where ``acc`` is the int32 accumulator coming out of the LUT multiplication
kernel.  This file derives the thresholds from (accumulator scale, BN params,
output activation scale) and provides both the float-reference and the
integer-threshold evaluation so tests can assert exact equivalence.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .quantization import QuantConfig


@dataclasses.dataclass(frozen=True)
class BNParams:
    """Inference-time batch-norm: y = gamma * (x - mean) / sqrt(var+eps) + beta."""
    gamma: jax.Array
    beta: jax.Array
    mean: jax.Array
    var: jax.Array
    eps: float = 1e-5

    def affine(self) -> tuple[jax.Array, jax.Array]:
        """Returns (A, B) with y = A*x + B."""
        inv = self.gamma / jnp.sqrt(self.var + self.eps)
        return inv, self.beta - self.mean * inv


def make_thresholds(acc_scale: jax.Array, bn: BNParams | None,
                    out_cfg: QuantConfig, out_scale: jax.Array) -> jax.Array:
    """Integer thresholds T[c, k] such that

        q_out(acc) = popcount(acc >= T)  ==  quantize(relu_clip(BN(acc*acc_scale)))

    with round-half-up semantics.  ``acc_scale`` is the per-channel product of
    weight and activation scales (shape broadcastable to channels), ``out_scale``
    the next layer's activation scale.  Thresholds are float64-derived then
    ceil'ed onto the integer accumulator grid (FINN streamlining).

    For negative BN slope the comparison flips; we encode that by negating both
    thresholds and accumulator sign per channel (returned thresholds carry a
    leading sign row; see :func:`apply_thresholds`).
    """
    n_steps = out_cfg.qmax - out_cfg.qmin  # number of thresholds = levels - 1
    if bn is not None:
        A, B = bn.affine()
    else:
        A = jnp.ones_like(out_scale)
        B = jnp.zeros_like(out_scale)
    A = A * acc_scale  # y = A * acc + B in float
    # q transitions at y = out_scale * (k - 0.5), k = qmin+1 .. qmax (uint: 1..qmax)
    ks = jnp.arange(1, n_steps + 1, dtype=jnp.float32) + float(out_cfg.qmin)
    y_t = out_scale[..., None] * (ks - 0.5)            # [C, K]
    # solve A*acc + B >= y_t  ->  acc >= (y_t - B)/A   (A>0)
    #                         ->  acc <= (y_t - B)/A   (A<0)
    t = (y_t - B[..., None]) / A[..., None]
    sign = jnp.sign(A)
    # Encode flipped channels by negating acc and thresholds: acc' = sign*acc.
    t = t * sign[..., None]
    t_int = jnp.ceil(t)  # acc' >= ceil(t) <=> acc' >= t for integer acc'
    return t_int.astype(jnp.float32), sign


def apply_thresholds(acc: jax.Array, thresholds: jax.Array, sign: jax.Array,
                     out_cfg: QuantConfig) -> jax.Array:
    """Evaluate the multi-threshold unit on integer accumulators.

    acc: [..., C] int32;  thresholds: [C, K];  returns uint codes in
    [qmin, qmax] (uint4: 0..15).
    """
    acc_f = acc.astype(jnp.float32) * sign
    q = jnp.sum(acc_f[..., None] >= thresholds, axis=-1).astype(jnp.int32)
    return q + out_cfg.qmin


def float_reference(acc: jax.Array, acc_scale: jax.Array, bn: BNParams | None,
                    out_cfg: QuantConfig, out_scale: jax.Array) -> jax.Array:
    """The float path the threshold unit must match exactly on integer accs."""
    x = acc.astype(jnp.float32) * acc_scale
    if bn is not None:
        A, B = bn.affine()
        x = A * x + B
    q = jnp.floor(x / out_scale + 0.5)  # round-half-up == threshold at k-0.5
    return jnp.clip(q, out_cfg.qmin, out_cfg.qmax).astype(jnp.int32)
