"""Streamlining transform (paper Sec. 3.2 / FINN [27]): turn a float
``conv -> BN -> ReLU6 -> quantize`` stage into an integer-only
``int conv (LUT kernel) -> multi-threshold`` stage.

The resulting stage consumes uint4 activation codes and int4 weight codes and
emits uint4 codes for the next layer — the exact datapath the paper deploys,
with all scales/BN folded into per-channel integer thresholds.

``streamline_stage``/``integer_stage_forward`` are validated against the
float reference to exact code equality (tests/test_streamline.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantization import (A4, W4, QuantConfig, compute_scale,
                                     dequantize, quantize)
from repro.core.thresholds import BNParams, apply_thresholds, make_thresholds


@dataclasses.dataclass
class StreamlinedStage:
    """Integer-only stage: weights as int4 codes + threshold bank."""
    w_codes: jax.Array          # [K, N] int8 (int4 codes)
    thresholds: jax.Array       # [N, levels-1]
    sign: jax.Array             # [N] BN-slope sign
    act_scale_out: jax.Array    # [N] output activation scale (for the next
                                # stage / final dequant)
    relu6_cap_code: jax.Array   # [N] max code representing clip at 6.0


def streamline_stage(w: jax.Array, bn: BNParams, act_scale_in: jax.Array,
                     out_cfg: QuantConfig = A4) -> StreamlinedStage:
    """w: [K, N] float weights; act_scale_in: scalar input activation scale.

    Derivation: acc = sum_k w_q[k,n] * a_q[k]; float pre-act
    x = (w_scale[n] * act_scale_in) * acc; y = BN(x); act = clip(y, 0, 6);
    q = round(act / out_scale). The (round . clip . BN . scale) chain is
    monotone per channel -> a threshold bank (paper Sec. 3.2).
    """
    w_scale = compute_scale(w, W4)                       # [1, N]
    w_codes = quantize(w, w_scale, 0, W4)                # int4 codes
    acc_scale = (w_scale[0] * act_scale_in)              # [N]
    # output scale: fixed so that 6.0 (the ReLU6 cap) == qmax
    out_scale = jnp.full(acc_scale.shape, 6.0 / out_cfg.qmax)
    thresholds, sign = make_thresholds(acc_scale, bn, out_cfg, out_scale)
    cap = jnp.full(acc_scale.shape, out_cfg.qmax, jnp.int32)
    return StreamlinedStage(w_codes=w_codes, thresholds=thresholds, sign=sign,
                            act_scale_out=out_scale, relu6_cap_code=cap)


def integer_stage_forward(stage: StreamlinedStage, a_codes: jax.Array,
                          out_cfg: QuantConfig = A4,
                          backend: Optional[str] = None) -> jax.Array:
    """a_codes: [M, K] uint4 codes -> [M, N] uint4 codes; integer-only.

    The matmul runs through the LUT kernel (kernels/lutmul); the activation
    through the threshold bank. No floating point in the datapath.
    """
    from repro.core.lut import pack_int4
    from repro.kernels.lutmul import ops
    w_packed = pack_int4(stage.w_codes.T).T
    acc = ops.lutmul(a_codes.astype(jnp.uint8) & 0xF, w_packed,
                     a_signed=False, backend=backend)
    q = apply_thresholds(acc, stage.thresholds, stage.sign, out_cfg)
    return jnp.clip(q, 0, stage.relu6_cap_code[None, :])


def float_stage_reference(w: jax.Array, bn: BNParams,
                          act_scale_in: jax.Array, a_codes: jax.Array,
                          out_cfg: QuantConfig = A4) -> jax.Array:
    """The float path the integer stage must match code-for-code."""
    w_scale = compute_scale(w, W4)
    w_q = dequantize(quantize(w, w_scale, 0, W4), w_scale)
    x = (a_codes.astype(jnp.float32) * act_scale_in) @ w_q
    A, B = bn.affine()
    y = A * x + B
    act = jnp.clip(y, 0.0, 6.0)
    out_scale = 6.0 / out_cfg.qmax
    return jnp.floor(act / out_scale + 0.5).astype(jnp.int32)
