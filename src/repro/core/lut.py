"""LUT-based efficient multiplication — the paper's core mechanism (Sec. 3.5).

Two deliverables live here:

1. **Bit-exact FPGA export** (:func:`lut6_2_init_words`): the 64-bit INIT words
   for Xilinx LUT6_2 primitives that embed *two* int4 weights as constant
   multipliers, exactly as Fig. 5 of the paper.  Input wiring (MSB→LSB):
   ``{I5=1, I4=WS (weight select), I3..I0=uint4 activation}``.  Each LUT6_2
   contributes two product bits: LUT ``j`` (j=0 most significant) emits product
   bit ``7-2j`` on O6 (INIT[32 + 16*WS + a]) and bit ``6-2j`` on O5
   (INIT[16*WS + a]).  Validated bit-for-bit against the four constants the
   paper prints for weights {+1, -3}.

2. **TPU product tables** (:func:`product_table`): the same weight-stationary
   multiplication expressed as a 2^w × 2^a int8 gather table — the VMEM-resident
   analogue the Pallas ``lutmul`` kernel consumes.  ``table[w & 0xF, a] == w*a``
   for int4 ``w`` / uint4 ``a``; both the kernel and the FPGA INIT generator are
   derived from :func:`_int_product`, so the TPU path and the bitstream path
   cannot drift apart.

Also: Eq. (3) LUT cost model and int4 pack/unpack helpers shared by kernels.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# shared integer product (two's complement), the single source of truth
# ---------------------------------------------------------------------------


def _int_product(weight: int, activation: int, out_bits: int = 8) -> int:
    """Two's-complement ``weight * activation`` truncated to ``out_bits``."""
    p = int(weight) * int(activation)
    mask = (1 << out_bits) - 1
    return p & mask


# ---------------------------------------------------------------------------
# 1. FPGA export — LUT6_2 INIT words (Fig. 5)
# ---------------------------------------------------------------------------


def lut6_2_init_words(w0: int, w1: int, act_bits: int = 4,
                      out_bits: int = 8) -> list[int]:
    """64-bit INIT words for the 4 LUT6_2 embedding weights ``(w0, w1)``.

    ``w0`` is selected by WS=0, ``w1`` by WS=1 (paper Fig. 5 uses w0=+1,
    w1=-3).  Returns ``out_bits // 2`` words, most-significant bit-pair first,
    matching the order the paper lists them.
    """
    if act_bits != 4:
        raise ValueError("LUT6_2 packing is defined for 4-bit activations")
    n_luts = out_bits // 2
    words = []
    for j in range(n_luts):
        hi_bit = out_bits - 1 - 2 * j   # emitted on O6 (upper 32 INIT bits)
        lo_bit = out_bits - 2 - 2 * j   # emitted on O5 (lower 32 INIT bits)
        init = 0
        for ws, w in ((0, w0), (1, w1)):
            for a in range(2 ** act_bits):
                p = _int_product(w, a, out_bits)
                if (p >> hi_bit) & 1:
                    init |= 1 << (32 + 16 * ws + a)
                if (p >> lo_bit) & 1:
                    init |= 1 << (16 * ws + a)
        words.append(init)
    return words


# The paper's published constants for weights (+1, -3) — used by tests/benches.
PAPER_FIG5_INIT_WORDS = (
    0xFFFE_0000_FFFE_0000,
    0x07FE_0000_F83E_0000,
    0x39C6_FF00_5A5A_F0F0,
    0xCCCC_CCCC_AAAA_AAAA,
)


def lut6_read(init: int, i5: int, i4: int, a: int) -> tuple[int, int]:
    """Read a LUT6_2: returns (O6, O5) for input {i5, i4, a[3:0]}."""
    idx6 = (i5 << 5) | (i4 << 4) | a
    idx5 = (i4 << 4) | a
    return (init >> idx6) & 1, (init >> idx5) & 1


def multiply_via_lut6(w0: int, w1: int, ws: int, a: int, out_bits: int = 8) -> int:
    """Evaluate the LUT6_2 bank like the FPGA would; returns signed product."""
    words = lut6_2_init_words(w0, w1, out_bits=out_bits)
    p = 0
    for j, init in enumerate(words):
        o6, o5 = lut6_read(init, 1, ws, a)
        p |= o6 << (out_bits - 1 - 2 * j)
        p |= o5 << (out_bits - 2 - 2 * j)
    if p >= 1 << (out_bits - 1):          # two's complement decode
        p -= 1 << out_bits
    return p


# ---------------------------------------------------------------------------
# 2. TPU product tables (consumed by kernels/lutmul)
# ---------------------------------------------------------------------------


def product_table(w_bits: int = 4, a_bits: int = 4, w_signed: bool = True,
                  a_signed: bool = False) -> np.ndarray:
    """Dense product lookup table ``T[w_code, a_code] -> int32 product``.

    ``w_code`` indexes the two's-complement bit pattern of the weight (so
    ``T[(w + 2**w_bits) % 2**w_bits, a] == w * a``), matching how the Pallas
    kernel addresses it with raw unpacked nibbles.
    """
    ws = np.arange(2 ** w_bits)
    if w_signed:
        wvals = np.where(ws >= 2 ** (w_bits - 1), ws - 2 ** w_bits, ws)
    else:
        wvals = ws
    As = np.arange(2 ** a_bits)
    avals = np.where(As >= 2 ** (a_bits - 1), As - 2 ** a_bits, As) if a_signed else As
    return (wvals[:, None] * avals[None, :]).astype(np.int32)


def flat_product_table(w_bits: int = 4, a_bits: int = 4, **kw) -> np.ndarray:
    """Flattened table addressed by ``(w_code << a_bits) | a_code``."""
    return product_table(w_bits, a_bits, **kw).reshape(-1)


def contraction_table(a_signed: bool = False) -> np.ndarray:
    """[16, 16] product table laid out for the one-hot contraction kernel.

    Row = weight code, column = activation code — so a [*, 16] one-hot of
    weight codes right-multiplied by this table yields each position's
    16-entry product row, and a one-hot of activation codes then selects
    within it (kernels/lutmul/kernel.py).  All entries fit int8
    ([-56, 64] for w4a4), which is what lets both contraction stages run as
    int8 MXU dots.
    """
    t = product_table(w_signed=True, a_signed=a_signed)
    assert t.min() >= -128 and t.max() <= 127, "table must fit int8"
    return t


# ---------------------------------------------------------------------------
# Eq. (3) — LUT cost model
# ---------------------------------------------------------------------------


def luts_per_multiply(n_bits: int) -> float:
    """Paper Eq. (3): #LUT6 = (2n * 2^n) / (1 * 2^6) for an n:2n LUT multiply."""
    return (2 * n_bits * 2 ** n_bits) / 64.0


def luts_per_multiply_general(n_bits: int) -> tuple[int, int]:
    """(min, max) LUT6 count for a *general* n-bit multiplier (paper: 13-28
    for 4-bit; Fig. 5 caption: 6-14x more than LUTMUL's 2)."""
    return 13 if n_bits <= 4 else 13 * (n_bits // 4) ** 2, \
           28 if n_bits <= 4 else 28 * (n_bits // 4) ** 2


# ---------------------------------------------------------------------------
# sub-4-bit weight specs + bitplane decomposition (the T-MAC formulation)
# ---------------------------------------------------------------------------
#
# ``lutmul_tmac`` stores weights as *bitplanes*: B binary [K, N] planes plus a
# static integer coefficient per plane (and an optional constant), so
#
#     w[k, n] = sum_b coeff_b * plane_b[k, n] + const
#
# and the matmul decomposes into B binary contractions whose cost is linear
# in the weight bit width — the move that makes w2 half the MXU work of w4.
# A weight-bits *spec* is an int in {1, 2, 3, 4} or the string "ternary"
# (BitNet b1.58's {-1, 0, +1}, ~1.58 bits).

WEIGHT_BITS_SPECS = (1, "ternary", 2, 3, 4)


def validate_weight_bits(spec) -> None:
    """Raise an actionable error for anything outside the supported family."""
    if spec not in WEIGHT_BITS_SPECS:
        raise ValueError(
            f"unsupported weight bit width {spec!r}: the tmac formulation "
            f"supports {WEIGHT_BITS_SPECS} (ints are two's-complement widths;"
            " 'ternary' is the BitNet-b1.58 {-1,0,+1} coding at ~1.58 bits)")


def weight_bits(spec) -> float:
    """Effective bits for cost/memory accounting (ternary ~= log2(3))."""
    validate_weight_bits(spec)
    return 1.58 if spec == "ternary" else float(spec)


def plane_decomposition(spec) -> tuple[int, tuple[int, ...], int]:
    """(n_planes, per-plane coeffs, additive const) for a weight-bits spec.

    * ints B in {2, 3, 4}: two's-complement planes — coeffs
      ``(1, 2, .., 2^(B-2), -2^(B-1))``, const 0; codes span
      ``[-2^(B-1), 2^(B-1)-1]`` exactly like the nibble format.
    * ``"ternary"``: a +1 plane and a -1 plane — coeffs ``(1, -1)``, const 0.
    * ``1``: BitNet-b1-style binary ±1 — one plane with ``w = 2*p - 1``
      (coeff 2, const -1; the const turns into a per-row activation-sum
      correction in the kernel).
    """
    validate_weight_bits(spec)
    if spec == "ternary":
        return 2, (1, -1), 0
    if spec == 1:
        return 1, (2,), -1
    b = int(spec)
    return b, tuple([1 << i for i in range(b - 1)] + [-(1 << (b - 1))]), 0


def truncate_plane_spec(spec, keep: int) -> tuple[int, int]:
    """Plane-suffix truncation: ``(kept_spec, scale_mult)`` for a drafter.

    For an int spec ``B`` the plane order is LSB-first with the sign plane
    last, so the *top* ``keep`` planes are the suffix slice
    ``planes[..., B-keep:, :, :]`` and their coefficients
    ``(2^(B-keep), .., 2^(B-2), -2^(B-1))`` factor as
    ``2^(B-keep) * plane_decomposition(keep)[1]`` — i.e. the suffix IS a
    valid ``keep``-bit plane stack once the weight scale absorbs the
    ``2^(B-keep)`` multiplier.  Truncation drops the low planes, so the
    approximation error per code is in ``[0, 2^(B-keep) - 1]`` (sign kept).

    Only int specs with ``2 <= keep < B`` truncate; ``ternary``/``w1`` have
    no positional planes to drop and raise.
    """
    validate_weight_bits(spec)
    if spec in ("ternary", 1):
        raise ValueError(
            f"weight spec {spec!r} has no truncatable plane prefix: its "
            "planes are not positional powers of two")
    b = int(spec)
    if not 2 <= keep < b:
        raise ValueError(
            f"draft plane count must satisfy 2 <= keep < {b} for a w{b} "
            f"weight, got keep={keep}")
    n, coeffs, const = plane_decomposition(b)
    kn, kcoeffs, kconst = plane_decomposition(keep)
    mult = 1 << (b - keep)
    assert coeffs[b - keep:] == tuple(c * mult for c in kcoeffs) and not const \
        and not kconst
    return keep, mult


def planes_from_codes(codes, spec) -> jnp.ndarray:
    """Integer weight codes [..., K, N] -> {0,1} uint8 planes [..., P, K, N].

    Inverse of ``sum_b coeff_b * plane_b + const`` for codes in the spec's
    range (two's-complement values for int specs, {-1,0,1} for ternary,
    {-1,+1} for binary).
    """
    n_planes, _, _ = plane_decomposition(spec)
    c = jnp.asarray(codes).astype(jnp.int32)
    if spec == "ternary":
        planes = [(c == 1), (c == -1)]
    elif spec == 1:
        planes = [(c > 0)]
    else:
        u = c & ((1 << int(spec)) - 1)
        planes = [((u >> b) & 1).astype(bool) for b in range(n_planes)]
    return jnp.stack([p.astype(jnp.uint8) for p in planes], axis=-3)


def decode_planes(planes, spec) -> jnp.ndarray:
    """{0,1} planes [..., P, K, N] -> int32 weight codes [..., K, N]."""
    _, coeffs, const = plane_decomposition(spec)
    co = jnp.asarray(coeffs, jnp.int32).reshape(-1, 1, 1)
    return jnp.sum(planes.astype(jnp.int32) * co, axis=-3) + const


def pack_bitplanes(planes) -> jnp.ndarray:
    """{0,1} planes [..., K, N] (K % 8 == 0) -> uint8 [..., K//8, N].

    k-major within each byte: bit i of byte j is plane row ``8*j + i`` —
    the layout both the Pallas tmac kernel and ``unpack_bitplanes`` assume.
    """
    planes = jnp.asarray(planes)
    K = planes.shape[-2]
    if K % 8:
        raise ValueError(
            f"bitplane packing needs K % 8 == 0, got K={K}; pad the "
            "contraction dim to a multiple of 8 before packing")
    x = planes.astype(jnp.uint8).reshape(*planes.shape[:-2], K // 8, 8,
                                         planes.shape[-1])
    shifts = jnp.arange(8, dtype=jnp.uint8).reshape(1, 8, 1)
    return jnp.sum(x << shifts, axis=-2).astype(jnp.uint8)


def unpack_bitplanes(packed) -> jnp.ndarray:
    """uint8 [..., K//8, N] -> {0,1} uint8 planes [..., K, N]."""
    packed = jnp.asarray(packed)
    shifts = jnp.arange(8, dtype=jnp.uint8).reshape(8, 1)
    bits = (packed[..., :, None, :] >> shifts) & 1
    return bits.reshape(*packed.shape[:-2], packed.shape[-2] * 8,
                        packed.shape[-1])


# ---------------------------------------------------------------------------
# int4 packing helpers (shared by kernels + checkpoints)
# ---------------------------------------------------------------------------


def pack_int4(x) -> jnp.ndarray:
    """Pack int4 values (last axis even) into uint8 nibble pairs.

    ``out[..., i] = (x[..., 2i+1] & 0xF) << 4 | (x[..., 2i] & 0xF)``
    """
    x = jnp.asarray(x)
    if x.shape[-1] % 2:
        raise ValueError("last axis must be even to pack nibbles")
    lo = x[..., 0::2].astype(jnp.uint8) & 0xF
    hi = x[..., 1::2].astype(jnp.uint8) & 0xF
    return (hi << 4) | lo


def unpack_int4(packed: jnp.ndarray, signed: bool = True) -> jnp.ndarray:
    """Inverse of :func:`pack_int4`; returns int8 (sign-extended if signed)."""
    lo = (packed & 0xF).astype(jnp.int8)
    hi = (packed >> 4).astype(jnp.int8)
    x = jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)
    if signed:
        x = jnp.where(x >= 8, x - 16, x)
    return x
