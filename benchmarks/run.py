"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``us_per_call`` is the median
wall-time of the benchmarked callable on this host (CPU); ``derived`` carries
the paper-comparable quantity (GOPS, FPS, LUT counts, accuracy, ...).

``--json PATH`` additionally writes a machine-readable record per row
(op name, median ms, GOP/s when derivable, the derived string) so successive
PRs can diff kernel baselines::

    python -m benchmarks.run --only kernel_bench --json BENCH_kernels.json

``--diff BASELINE.json`` prints per-benchmark deltas of this run against a
committed baseline (median ms and GOP/s, with new/missing rows flagged) so
later PRs can check regressions mechanically; ``--fail-on-regress PCT``
turns the diff into a gate (exit 1 on any benchmark > PCT% slower than the
baseline or missing from the run) — the CI invocation::

    python -m benchmarks.run --only kernel_bench --diff BENCH_kernels.json \
        --fail-on-regress 25
"""
from __future__ import annotations

import argparse
import json
import re
import statistics
import sys
import time


def _time_rows(rows: list, repeats: int) -> dict[str, float]:
    """us-per-call medians for every callable row, sampled ROUND-ROBIN.

    Two defenses against noisy (2-core CI) hosts, where naive per-row
    timing swings +-50%:

      * short calls are batched so each timing sample covers >= ~100ms —
        millisecond calls are otherwise dominated by scheduler jitter;
      * sample r of EVERY row is taken before sample r+1 of any, so a host
        slow phase (GC, cron, a neighbor VM) lands on the same round of
        every benchmark instead of swallowing one row's entire window; the
        per-row median then drops the bad rounds for all rows alike.
    """
    plan, samples = [], {}
    for name, fn, _ in rows:
        if not callable(fn):
            continue
        fn()                   # warmup / compile
        t0 = time.perf_counter()
        fn()
        probe = time.perf_counter() - t0
        plan.append((name, fn, max(1, min(256, int(0.1 / max(probe,
                                                             1e-9))))))
        samples[name] = []
    for _ in range(repeats):
        for name, fn, inner in plan:
            t0 = time.perf_counter()
            for _ in range(inner):
                fn()
            samples[name].append((time.perf_counter() - t0) / inner * 1e6)
    return {name: statistics.median(v) for name, v in samples.items()}


def _gops(derived: str, us: float | None):
    """GOP/s from a ``gop_per_call=X`` annotation + measured wall time."""
    m = re.search(r"gop_per_call=([0-9.eE+-]+)", derived)
    if not m or not us:
        return None
    return float(m.group(1)) / (us / 1e6)


def diff_records(records: list[dict], baseline_path: str,
                 normalize: str | None = None) -> list[dict]:
    """Per-benchmark deltas vs a committed ``--json`` baseline.

    Prints the delta CSV and returns one entry per benchmark in the union of
    run and baseline: ``{"name", "status": "ok"|"new"|"missing",
    "delta_ms_pct": float|None}``.  Benchmarks present in the baseline but
    absent from the run are reported (and returned) as ``missing`` — a
    silently dropped benchmark must never diff clean — and count as
    regressions under ``--fail-on-regress``.

    ``normalize`` rescales every baseline median by a host-speed factor
    before the delta, so uniform speed differences (CI runner vs the
    machine that committed the baseline) cancel and only *relative*
    slowdowns trip the gate.  ``"median"`` (what CI uses) takes the median
    run/baseline ratio over all shared rows — robust to any single noisy or
    genuinely-regressed row; any other value names one calibration
    benchmark whose speed is independent of the code under test (e.g. the
    plain-XLA ``kernel_bf16_matmul_baseline``).
    """
    with open(baseline_path) as f:
        base = {r["name"]: r for r in json.load(f)["rows"]}
    speed = None
    if normalize == "median":
        ratios = sorted(
            r["median_ms"] / base[r["name"]]["median_ms"] for r in records
            if r["name"] in base and base[r["name"]]["median_ms"])
        if not ratios:
            raise SystemExit("--normalize median: no benchmarks shared "
                             "between the run and the baseline")
        speed = ratios[len(ratios) // 2]
        print(f"normalizing by the median of {len(ratios)} run/baseline "
              f"ratios: this host runs {speed:.2f}x the baseline host's "
              "time", file=sys.stderr)
    elif normalize is not None:
        run_cal = next((r for r in records if r["name"] == normalize), None)
        base_cal = base.get(normalize)
        if not run_cal or not base_cal or not base_cal["median_ms"]:
            raise SystemExit(
                f"--normalize: calibration benchmark {normalize!r} must "
                "exist in both the run and the baseline")
        speed = run_cal["median_ms"] / base_cal["median_ms"]
        print(f"normalizing by {normalize}: this host runs "
              f"{speed:.2f}x the baseline host's time", file=sys.stderr)
    if speed is not None:
        # gops ~ 1/time: rescale it too so both delta columns agree
        base = {k: dict(v, median_ms=v["median_ms"] * speed,
                        gops=(v["gops"] / speed if v.get("gops") else
                              v.get("gops")))
                for k, v in base.items()}
    print(f"\ndiff vs {baseline_path}", file=sys.stderr)
    print("name,base_ms,new_ms,delta_ms_pct,base_gops,new_gops,delta_gops_pct")
    out = []
    seen = set()
    for r in records:
        seen.add(r["name"])
        b = base.get(r["name"])
        if b is None:
            print(f"{r['name']},NEW,{r['median_ms']},,,{r['gops'] or ''},")
            out.append({"name": r["name"], "status": "new",
                        "delta_ms_pct": None})
            continue
        dms = (r["median_ms"] / b["median_ms"] - 1) * 100 \
            if b["median_ms"] else float("nan")
        dg = ""
        if r.get("gops") and b.get("gops"):
            dg = f"{(r['gops'] / b['gops'] - 1) * 100:+.1f}"
        print(f"{r['name']},{b['median_ms']},{r['median_ms']},{dms:+.1f},"
              f"{b.get('gops') or ''},{r.get('gops') or ''},{dg}")
        out.append({"name": r["name"], "status": "ok", "delta_ms_pct": dms})
    for name in base:
        if name not in seen:
            print(f"{name},MISSING (in baseline, not in this run),,,,,")
            out.append({"name": name, "status": "missing",
                        "delta_ms_pct": None})
    return out


def gate_regressions(diffs: list[dict], threshold_pct: float) -> list[str]:
    """Failures under ``--fail-on-regress``: slower than the baseline by
    more than ``threshold_pct`` percent, or missing from the run entirely.
    NEW benchmarks never fail the gate (they have no baseline yet)."""
    bad = []
    for d in diffs:
        if d["status"] == "missing":
            bad.append(f"{d['name']}: missing from this run")
        elif (d["status"] == "ok" and d["delta_ms_pct"] is not None
                and d["delta_ms_pct"] == d["delta_ms_pct"]   # not NaN
                and d["delta_ms_pct"] > threshold_pct):
            bad.append(f"{d['name']}: {d['delta_ms_pct']:+.1f}% slower "
                       f"(threshold +{threshold_pct:g}%)")
    return bad


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="write machine-readable results to this path")
    ap.add_argument("--diff", default=None, metavar="BASELINE.json",
                    help="print per-benchmark deltas vs a committed baseline")
    ap.add_argument("--fail-on-regress", type=float, default=None,
                    metavar="PCT",
                    help="with --diff: exit 1 when any benchmark runs more "
                         "than PCT%% slower than the baseline, or is missing "
                         "from this run (the CI kernel-bench gate)")
    ap.add_argument("--normalize", default=None, metavar="NAME|median",
                    help="with --diff: rescale baseline medians by a "
                         "host-speed factor so uniform speed differences "
                         "cancel — 'median' (CI default) uses the median "
                         "run/baseline ratio over all shared rows; any "
                         "other value names one calibration benchmark")
    ap.add_argument("--only", action="append", default=None,
                    help="run only these benchmark modules (by name)")
    ap.add_argument("--repeats", type=int, default=5)
    args = ap.parse_args(argv)
    if args.fail_on_regress is not None and not args.diff:
        ap.error("--fail-on-regress requires --diff BASELINE.json")

    from benchmarks import (fpga_roofline, kernel_bench, lut_cost, lut_init,
                            qat_accuracy, resource_breakdown, serving_bench,
                            throughput_table2)
    mods = [lut_init, lut_cost, fpga_roofline, throughput_table2,
            resource_breakdown, kernel_bench, qat_accuracy, serving_bench]
    if args.only:
        mods = [m for m in mods if m.__name__.split(".")[-1] in args.only]
    records = []
    print("name,us_per_call,derived")
    for mod in mods:
        rows = list(mod.run())
        timed = _time_rows(rows, args.repeats)
        for name, fn, derived in rows:
            us = timed[name] if callable(fn) else float(fn)
            print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()
            records.append({
                "name": name,
                "median_ms": round(us / 1e3, 4),
                "gops": _gops(derived, us),
                "derived": derived,
            })
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": records}, f, indent=1)
        print(f"wrote {args.json} ({len(records)} rows)", file=sys.stderr)
    if args.diff:
        diffs = diff_records(records, args.diff, normalize=args.normalize)
        if args.fail_on_regress is not None:
            bad = gate_regressions(diffs, args.fail_on_regress)
            if bad:
                print("REGRESSION GATE FAILED:", file=sys.stderr)
                for line in bad:
                    print(f"  {line}", file=sys.stderr)
                sys.exit(1)
            print(f"regression gate ok (threshold "
                  f"+{args.fail_on_regress:g}%)", file=sys.stderr)


if __name__ == "__main__":
    main()
