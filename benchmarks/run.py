"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``us_per_call`` is the
wall-time of the benchmarked callable on this host (CPU); ``derived`` carries
the paper-comparable quantity (GOPS, FPS, LUT counts, accuracy, ...).
"""
from __future__ import annotations

import sys
import time


def _timeit(fn, n=3):
    fn()                       # warmup / compile
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def main() -> None:
    from benchmarks import (fpga_roofline, kernel_bench, lut_cost, lut_init,
                            qat_accuracy, resource_breakdown, serving_bench,
                            throughput_table2)
    mods = [lut_init, lut_cost, fpga_roofline, throughput_table2,
            resource_breakdown, kernel_bench, qat_accuracy, serving_bench]
    print("name,us_per_call,derived")
    for mod in mods:
        for row in mod.run():
            name, fn, derived = row
            us = _timeit(fn) if callable(fn) else float(fn)
            print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()


if __name__ == "__main__":
    main()
