"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``us_per_call`` is the median
wall-time of the benchmarked callable on this host (CPU); ``derived`` carries
the paper-comparable quantity (GOPS, FPS, LUT counts, accuracy, ...).

``--json PATH`` additionally writes a machine-readable record per row
(op name, median ms, GOP/s when derivable, the derived string) so successive
PRs can diff kernel baselines::

    python -m benchmarks.run --only kernel_bench --json BENCH_kernels.json
"""
from __future__ import annotations

import argparse
import json
import re
import statistics
import sys
import time


def _median_us(fn, n=5) -> float:
    fn()                       # warmup / compile
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(ts)


def _gops(derived: str, us: float | None):
    """GOP/s from a ``gop_per_call=X`` annotation + measured wall time."""
    m = re.search(r"gop_per_call=([0-9.eE+-]+)", derived)
    if not m or not us:
        return None
    return float(m.group(1)) / (us / 1e6)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="write machine-readable results to this path")
    ap.add_argument("--only", action="append", default=None,
                    help="run only these benchmark modules (by name)")
    ap.add_argument("--repeats", type=int, default=5)
    args = ap.parse_args(argv)

    from benchmarks import (fpga_roofline, kernel_bench, lut_cost, lut_init,
                            qat_accuracy, resource_breakdown, serving_bench,
                            throughput_table2)
    mods = [lut_init, lut_cost, fpga_roofline, throughput_table2,
            resource_breakdown, kernel_bench, qat_accuracy, serving_bench]
    if args.only:
        mods = [m for m in mods if m.__name__.split(".")[-1] in args.only]
    records = []
    print("name,us_per_call,derived")
    for mod in mods:
        for row in mod.run():
            name, fn, derived = row
            us = _median_us(fn, args.repeats) if callable(fn) else float(fn)
            print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()
            records.append({
                "name": name,
                "median_ms": round(us / 1e3, 4),
                "gops": _gops(derived, us),
                "derived": derived,
            })
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": records}, f, indent=1)
        print(f"wrote {args.json} ({len(records)} rows)", file=sys.stderr)


if __name__ == "__main__":
    main()
