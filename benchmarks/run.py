"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``us_per_call`` is the median
wall-time of the benchmarked callable on this host (CPU); ``derived`` carries
the paper-comparable quantity (GOPS, FPS, LUT counts, accuracy, ...).

``--json PATH`` additionally writes a machine-readable record per row
(op name, median ms, GOP/s when derivable, the derived string) so successive
PRs can diff kernel baselines::

    python -m benchmarks.run --only kernel_bench --json BENCH_kernels.json

``--diff BASELINE.json`` prints per-benchmark deltas of this run against a
committed baseline (median ms and GOP/s, with new/missing rows flagged) so
later PRs can check regressions mechanically::

    python -m benchmarks.run --only kernel_bench --diff BENCH_kernels.json
"""
from __future__ import annotations

import argparse
import json
import re
import statistics
import sys
import time


def _median_us(fn, n=5) -> float:
    fn()                       # warmup / compile
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(ts)


def _gops(derived: str, us: float | None):
    """GOP/s from a ``gop_per_call=X`` annotation + measured wall time."""
    m = re.search(r"gop_per_call=([0-9.eE+-]+)", derived)
    if not m or not us:
        return None
    return float(m.group(1)) / (us / 1e6)


def diff_records(records: list[dict], baseline_path: str) -> None:
    """Per-benchmark deltas vs a committed ``--json`` baseline."""
    with open(baseline_path) as f:
        base = {r["name"]: r for r in json.load(f)["rows"]}
    print(f"\ndiff vs {baseline_path}", file=sys.stderr)
    print("name,base_ms,new_ms,delta_ms_pct,base_gops,new_gops,delta_gops_pct")
    seen = set()
    for r in records:
        seen.add(r["name"])
        b = base.get(r["name"])
        if b is None:
            print(f"{r['name']},NEW,{r['median_ms']},,,{r['gops'] or ''},")
            continue
        dms = (r["median_ms"] / b["median_ms"] - 1) * 100 \
            if b["median_ms"] else float("nan")
        dg = ""
        if r.get("gops") and b.get("gops"):
            dg = f"{(r['gops'] / b['gops'] - 1) * 100:+.1f}"
        print(f"{r['name']},{b['median_ms']},{r['median_ms']},{dms:+.1f},"
              f"{b.get('gops') or ''},{r.get('gops') or ''},{dg}")
    for name in base:
        if name not in seen:
            print(f"{name},MISSING (in baseline, not in this run),,,,,")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="write machine-readable results to this path")
    ap.add_argument("--diff", default=None, metavar="BASELINE.json",
                    help="print per-benchmark deltas vs a committed baseline")
    ap.add_argument("--only", action="append", default=None,
                    help="run only these benchmark modules (by name)")
    ap.add_argument("--repeats", type=int, default=5)
    args = ap.parse_args(argv)

    from benchmarks import (fpga_roofline, kernel_bench, lut_cost, lut_init,
                            qat_accuracy, resource_breakdown, serving_bench,
                            throughput_table2)
    mods = [lut_init, lut_cost, fpga_roofline, throughput_table2,
            resource_breakdown, kernel_bench, qat_accuracy, serving_bench]
    if args.only:
        mods = [m for m in mods if m.__name__.split(".")[-1] in args.only]
    records = []
    print("name,us_per_call,derived")
    for mod in mods:
        for row in mod.run():
            name, fn, derived = row
            us = _median_us(fn, args.repeats) if callable(fn) else float(fn)
            print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()
            records.append({
                "name": name,
                "median_ms": round(us / 1e3, 4),
                "gops": _gops(derived, us),
                "derived": derived,
            })
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": records}, f, indent=1)
        print(f"wrote {args.json} ({len(records)} rows)", file=sys.stderr)
    if args.diff:
        diff_records(records, args.diff)


if __name__ == "__main__":
    main()
