"""Sec. 3.5 — LUT-multiplication kernel microbenchmarks.

On this CPU host the Pallas kernels run in interpret mode (functional, not
peak-performant); the ``ref`` rows give the XLA-compiled integer-math path.
The headline A/B here is the one-hot/bitplane *contraction* kernel against
the retained serial *gather* kernel under identical tiling — the PR-gating
comparison (contraction must be >= 5x at M=K=N=256, bit-exact vs the
oracle).  The TPU-side roofline for these kernels comes from the dry-run
(§Roofline).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lut import pack_int4
from repro.kernels.lutmul import ops, ref

M, K, N = 256, 512, 256
# the contraction-vs-gather A/B runs at the acceptance shape
AB_M = AB_K = AB_N = 256


def run():
    rng = np.random.default_rng(0)
    a = rng.integers(-8, 8, size=(M, K)).astype(np.int8)
    w = rng.integers(-8, 8, size=(K, N)).astype(np.int8)
    a_codes = jnp.asarray(a.astype(np.uint8) & 0xF)
    w_packed = pack_int4(jnp.asarray(w).T).T
    a_j = jnp.asarray(a)
    w_j = jnp.asarray(w)
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.bfloat16)
    wf = jnp.asarray(rng.normal(size=(K, N)), jnp.bfloat16)

    gops = 2 * M * K * N / 1e9

    lut_ref = jax.jit(lambda a, w: ops.lutmul(a, w, backend="ref"))
    int_ref = jax.jit(lambda a, w: ops.int_matmul(a, w, backend="ref"))
    bf16 = jax.jit(lambda x, w: x @ w)

    yield ("kernel_lutmul_ref_int4", lambda: lut_ref(a_codes, w_packed)
           .block_until_ready(), f"gop_per_call={gops:.3f}")
    yield ("kernel_int_matmul_ref_int8", lambda: int_ref(a_j, w_j)
           .block_until_ready(), f"gop_per_call={gops:.3f}")
    yield ("kernel_bf16_matmul_baseline", lambda: bf16(x, wf)
           .block_until_ready(), f"gop_per_call={gops:.3f}")

    # ---- contraction vs gather A/B at the acceptance shape (interpret) ----
    ab = rng.integers(-8, 8, size=(AB_M, AB_K)).astype(np.int8)
    wb = rng.integers(-8, 8, size=(AB_K, AB_N)).astype(np.int8)
    ab_codes = jnp.asarray(ab.astype(np.uint8) & 0xF)
    wb_packed = pack_int4(jnp.asarray(wb).T).T
    want = ab.astype(np.int32) @ wb.astype(np.int32)
    ab_gops = 2 * AB_M * AB_K * AB_N / 1e9

    # the contraction benefits from taller M blocks — let the autotuner pick
    # (both impls sweep the same candidate set, so the A/B stays fair).  The
    # sweep needs concrete arrays, so run each op eagerly once to populate
    # the per-shape block cache before the jitted timing loops.
    # REPRO_LUTMUL_AUTOTUNE=0 pins the heuristic default blocks instead: the
    # timed sweep picks different winners run-to-run on noisy hosts, which
    # would make the CI --fail-on-regress gate compare different kernels.
    autotune = os.environ.get("REPRO_LUTMUL_AUTOTUNE", "1") != "0"
    if autotune:
        ops.set_autotune(True)
    ops.lutmul(ab_codes, wb_packed, backend="interpret", impl="onehot")
    ops.lutmul(ab_codes, wb_packed, backend="interpret", impl="gather")
    onehot = jax.jit(lambda a, w: ops.lutmul(a, w, backend="interpret",
                                             impl="onehot"))
    gather = jax.jit(lambda a, w: ops.lutmul(a, w, backend="interpret",
                                             impl="gather"))
    ref_want = ref.lutmul_ref(ab_codes, wb_packed, a_signed=True)
    got = np.asarray(onehot(ab_codes, wb_packed))
    exact = bool((got == np.asarray(ref_want)).all()
                 and (got == want).all())

    import time

    def _median_ms(fn, warm=3, n=9):
        """Consecutive runs (interleaving would thrash the shared cache);
        measured contraction-first so machine warm-up favors the baseline."""
        for _ in range(warm):
            fn()
        ts = []
        for _ in range(n):
            t0 = time.perf_counter()
            fn()
            ts.append((time.perf_counter() - t0) * 1e3)
        return float(np.median(ts))

    t_oh = _median_ms(lambda: onehot(ab_codes, wb_packed)
                      .block_until_ready())
    t_ga = _median_ms(lambda: gather(ab_codes, wb_packed)
                      .block_until_ready())
    yield ("kernel_lutmul_onehot_interpret_256", t_oh * 1e3,
           f"gop_per_call={ab_gops:.3f}")
    yield ("kernel_lutmul_gather_interpret_256", t_ga * 1e3,
           f"gop_per_call={ab_gops:.3f}")
    yield ("kernel_lutmul_onehot_vs_gather", t_oh * 1e3,
           f"speedup={t_ga / t_oh:.2f}x exact_vs_ref={exact}")

    # ---- dequant epilogue: fused vs unfused, winner recorded --------------
    # ``quantized_matmul`` consults ``pick_variant`` (cached per op/shape);
    # the bench seeds that cache with an explicit A/B under autotune so the
    # committed row's ``derived`` records which variant actually ran — on
    # interpret hosts the unfused epilogue wins (the fused kernel's VMEM
    # scratch + in-kernel epilogue cost more than the XLA-fused rescale),
    # on real pallas the fused path does.  Both are bit-identical.
    xq = jnp.asarray(rng.normal(size=(AB_M, AB_K)), jnp.float32)
    wq = jnp.asarray(rng.normal(size=(AB_K, AB_N)), jnp.float32)
    if autotune:
        aq, asc = ops.quantize_activations(xq, 4)
        wqq, wsc = ops.quantize_weights(wq, 4, pack=True)
        ops.pick_variant(
            "lutmul", AB_M, AB_K, AB_N, "interpret",
            bench_fns={
                "fused": lambda: ops._fused_lut(
                    aq.astype(jnp.uint8) & 0xF, wqq, asc, wsc, a_signed=True,
                    be="interpret",
                    out_dtype=jnp.float32).block_until_ready(),
                "unfused": lambda: (
                    ops.lutmul(aq.astype(jnp.uint8) & 0xF, wqq, a_signed=True,
                               backend="interpret").astype(jnp.float32)
                    * asc * wsc).block_until_ready(),
            })
    dequant = jax.jit(lambda x, w: ops.quantized_matmul(
        x, w, mode="w4a4_lut", backend="interpret",
        compute_dtype=jnp.float32))
    variant = ops.pick_variant("lutmul", AB_M, AB_K, AB_N, "interpret")
    yield ("kernel_lutmul_fused_dequant_interpret_256", lambda: dequant(
        xq, wq).block_until_ready(),
        f"gop_per_call={ab_gops:.3f} variant={variant}")

    # ---- cost-vs-bits curve: tmac scales with planes, one-hot is flat -----
    # tmac contracts P weight bitplanes against an activation-group table
    # (MAC cost ~ P * (2^g / g) * K), so w2 halves the w4 work and ternary
    # sits between w1 and w2; one-hot always contracts the full 4-bit
    # product table (cost ~ 16K/4 per code = flat in weight bits).  The
    # sub-4-bit codes are valid int4 codes, so the one-hot rows run the SAME
    # quantized weights nibble-packed — an apples-to-apples flat reference.
    from repro.core.lut import decode_planes, unpack_bitplanes
    ab_signed = jnp.asarray(ab)                   # tmac takes signed codes
    for spec in (4, 2, "ternary", 1):
        label = spec if spec == "ternary" else f"w{spec}"
        planes, _ = ops.quantize_weights_planes(wq, spec)
        ops.lutmul_tmac(ab_signed, planes, spec, abits=4,
                        backend="interpret")      # populate the block cache
        tmac_fn = jax.jit(lambda a, p, s=spec: ops.lutmul_tmac(
            a, p, s, abits=4, backend="interpret"))
        dec = decode_planes(unpack_bitplanes(planes), spec)
        packed = pack_int4((dec.astype(jnp.int8)).T).T
        oh_fn = jax.jit(lambda a, w: ops.lutmul(a, w, backend="interpret",
                                                impl="onehot"))
        n_planes = int(planes.shape[0])
        yield (f"kernel_lutmul_tmac_{label}_interpret_256",
               lambda f=tmac_fn, p=planes: f(ab_signed, p)
               .block_until_ready(),
               f"gop_per_call={ab_gops:.3f} planes={n_planes}")
        yield (f"kernel_lutmul_onehot_{label}_interpret_256",
               lambda f=oh_fn, w=packed: f(ab_codes, w)
               .block_until_ready(),
               f"gop_per_call={ab_gops:.3f} planes={n_planes}")

    if autotune:
        ops.set_autotune(None)
