"""Sec. 3.5 — LUT-multiplication kernel microbenchmarks.

On this CPU host the Pallas kernel runs in interpret mode (functional, not
performant); the ``ref`` rows give the XLA-compiled integer-math path.  The
TPU-side roofline for these kernels comes from the dry-run (§Roofline).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lut import pack_int4
from repro.kernels.lutmul import ops

M, K, N = 256, 512, 256


def run():
    rng = np.random.default_rng(0)
    a = rng.integers(-8, 8, size=(M, K)).astype(np.int8)
    w = rng.integers(-8, 8, size=(K, N)).astype(np.int8)
    a_codes = jnp.asarray(a.astype(np.uint8) & 0xF)
    w_packed = pack_int4(jnp.asarray(w).T).T
    a_j = jnp.asarray(a)
    w_j = jnp.asarray(w)
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.bfloat16)
    wf = jnp.asarray(rng.normal(size=(K, N)), jnp.bfloat16)

    gops = 2 * M * K * N / 1e9

    lut_ref = jax.jit(lambda a, w: ops.lutmul(a, w, backend="ref"))
    int_ref = jax.jit(lambda a, w: ops.int_matmul(a, w, backend="ref"))
    bf16 = jax.jit(lambda x, w: x @ w)

    yield ("kernel_lutmul_ref_int4", lambda: lut_ref(a_codes, w_packed)
           .block_until_ready(), f"gop_per_call={gops:.3f}")
    yield ("kernel_int_matmul_ref_int8", lambda: int_ref(a_j, w_j)
           .block_until_ready(), f"gop_per_call={gops:.3f}")
    yield ("kernel_bf16_matmul_baseline", lambda: bf16(x, wf)
           .block_until_ready(), f"gop_per_call={gops:.3f}")

    # interpret-mode correctness check of the real Pallas kernel body
    def interp():
        out = ops.lutmul(a_codes[:64, :128], w_packed[:64, :128],
                         backend="interpret")
        return out.block_until_ready()
    want = a[:64, :128].astype(np.int32) @ w[:128, :128].astype(np.int32)
    got = np.asarray(interp())
    yield ("kernel_lutmul_pallas_interpret_64x128x128", interp,
           f"exact_match={bool((got == want).all())}")
