"""Sec. 3.6 / Fig. 2 — QAT vs post-training quantization accuracy trend.

ImageNet/420-epoch training is out of scope on this host; the *mechanism* is
reproduced on a separable synthetic image task: fp32 training, then (a)
post-training 4-bit quantization of weights (accuracy drops), (b) QAT
fine-tune at 4-bit (accuracy recovers) — the qualitative Fig. 2 story.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data import pipeline
from repro.models import mobilenet
from repro.train.step import TrainConfig, init_state, make_train_step


def _accuracy(params, cfg, dcfg, n=4):
    hits = tot = 0
    for step in range(100, 100 + n):
        b = pipeline.image_batch(dcfg, step)
        logits = mobilenet.forward(params, cfg, jnp.asarray(b["images"]),
                                   train_qat=(cfg.quant == "qat"))
        hits += int((np.asarray(jnp.argmax(logits, -1)) == b["labels"]).sum())
        tot += len(b["labels"])
    return hits / tot


def run():
    cfg_fp = dataclasses.replace(configs.get_config("mobilenetv2", smoke=True),
                                 quant="none")
    cfg_q = dataclasses.replace(cfg_fp, quant="qat")
    dcfg = pipeline.DataConfig(seed=0, global_batch=32)
    params = mobilenet.init_params(jax.random.PRNGKey(0), cfg_fp)

    step_fp = jax.jit(make_train_step(cfg_fp, TrainConfig(
        peak_lr=2e-3, warmup=5, total_steps=60)))
    state = init_state(params)
    for s in range(60):
        b = pipeline.image_batch(dcfg, s)
        state, m = step_fp(state, {"images": jnp.asarray(b["images"]),
                                   "labels": jnp.asarray(b["labels"])})
    acc_fp = _accuracy(state["params"], cfg_fp, dcfg)

    # post-training quantization: evaluate the fp32 weights through the
    # 4-bit fake-quant forward without retraining
    acc_ptq = _accuracy(state["params"], cfg_q, dcfg)

    # QAT fine-tune
    step_q = jax.jit(make_train_step(cfg_q, TrainConfig(
        peak_lr=5e-4, warmup=2, total_steps=40, qat_project=False)))
    qstate = init_state(state["params"])
    for s in range(60, 100):
        b = pipeline.image_batch(dcfg, s)
        qstate, m = step_q(qstate, {"images": jnp.asarray(b["images"]),
                                    "labels": jnp.asarray(b["labels"])})
    acc_qat = _accuracy(qstate["params"], cfg_q, dcfg)

    yield ("fig2_qat_accuracy_recovery",
           lambda: _accuracy(state["params"], cfg_fp, dcfg, n=1),
           f"fp32_acc={acc_fp:.3f};ptq_w4a4_acc={acc_ptq:.3f};"
           f"qat_w4a4_acc={acc_qat:.3f};"
           f"recovered={acc_qat >= acc_ptq}")
