"""Fig. 5 — LUT6_2 INIT word generation, validated bit-exactly against the
four constants printed in the paper for weights (+1, -3)."""
from repro.core import lut


def run():
    def gen():
        return lut.lut6_2_init_words(1, -3)

    words = gen()
    match = tuple(words) == tuple(lut.PAPER_FIG5_INIT_WORDS)
    yield ("fig5_lut6_init_words", gen,
           f"bit_exact_vs_paper={match};words="
           + "|".join(f"{w:016x}" for w in words))

    # full-bank generation cost for one conv layer (1024 weights -> 512 banks)
    def layer():
        return [lut.lut6_2_init_words(w0, w1)
                for w0, w1 in zip(range(-8, 8), range(7, -9, -1))]
    yield ("fig5_init_bank_16weights", layer, "banks=8;luts=32")
