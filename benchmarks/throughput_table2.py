"""Table 2 — MobileNetV2 dataflow throughput model on U280 @333 MHz.

The paper implements the first 15 conv layers fully parallel and folds the
rest, reporting 1627 FPS / 978.6 GOPS in 529k LUTs.  We reproduce that
operating point from the analytic folding model: balance the pipeline under
the paper's LUT budget and report modeled FPS/GOPS.
"""
from repro.core import fpga_model as F
from repro.models.mobilenet import MobileNetConfig, fpga_layer_table

PAPER_FPS = 1627.0
PAPER_GOPS = 978.6
PAPER_LUTS = 529_242


def run():
    layers = fpga_layer_table(MobileNetConfig())
    total_ops = sum(lyr.ops for lyr in layers)

    def model():
        return F.balance_folding(layers, lut_budget=PAPER_LUTS,
                                 freq_hz=F.U280.freq_hz, lut_overhead=3.24,
                                 full_parallel_prefix=15)

    res = model()
    fps = res["fps"]
    gops = fps * total_ops / 1e9
    yield ("table2_idealized_balanced_folding", model,
           f"modeled_fps={fps:.0f};paper_fps={PAPER_FPS:.0f};"
           f"headroom={fps/PAPER_FPS:.2f}x;modeled_gops={gops:.1f};"
           f"paper_gops={PAPER_GOPS};luts_used={res['total_luts']:.0f};"
           f"ops_per_frame={total_ops/1e9:.3f}GOP")

    # calibration: solve for the effective MAC-LUT budget that reproduces the
    # paper's 1627 FPS — the remainder of the 529k LUTs is conv generators,
    # FIFOs, width converters and control (the paper's Fig. 4 datapath), plus
    # divisor-constrained (non-ideal) folding.
    def calibrate():
        lo, hi = 1e3, float(PAPER_LUTS)
        for _ in range(40):
            mid = (lo * hi) ** 0.5
            r = F.balance_folding(layers, lut_budget=mid,
                                  freq_hz=F.U280.freq_hz, lut_overhead=3.24,
                                  full_parallel_prefix=0)
            if r["fps"] > PAPER_FPS:
                hi = mid
            else:
                lo = mid
        return mid
    eff = calibrate()
    yield ("table2_calibrated_operating_point", calibrate,
           f"effective_mac_lut_budget={eff:.0f};"
           f"fraction_of_paper_total={eff/PAPER_LUTS:.2f};"
           f"interpretation=MAC_datapath_share_vs_streaming_infra;"
           f"paper_fps_reproduced={PAPER_FPS:.0f}")

    # scaling: what the model predicts with the FULL U280 fabric
    def full():
        return F.balance_folding(layers, lut_budget=F.U280.luts * 0.8,
                                 freq_hz=F.U280.freq_hz, lut_overhead=3.24,
                                 full_parallel_prefix=15)
    r2 = full()
    yield ("table2_full_fabric_projection", full,
           f"fps={r2['fps']:.0f};gops={r2['fps']*total_ops/1e9:.1f};"
           f"luts={r2['total_luts']:.0f}")
