"""Fig. 6 — LUT resource breakdown of MobileNetV2's second conv layer
(1x1, 32 in / 32 out = 1024 weights).

Paper: 1829 LUTs as multiplication ROM after HLS (theory: Eq.3 gives
2048; Vivado dedups to 1829), 3277 ROM + 2645 adder/other = 5922 after
implementation.  We reproduce the theoretical terms and the calibrated
overhead factor the throughput model uses.
"""
from repro.core import lut

N_WEIGHTS = 1024
PAPER_HLS_ROM = 1829
PAPER_IMPL_ROM = 3277
PAPER_IMPL_ADDER = 2645
PAPER_IMPL_TOTAL = 5922


def adder_tree_luts(n_inputs: int, acc_bits: int = 8,
                    luts_per_bit: float = 0.28) -> float:
    """LUT estimate for the accumulation tree: (n-1) adders, width grows
    log2 with depth.  ``luts_per_bit`` calibrates Vivado's CARRY8 chains +
    ternary (3:1) adder packing + cross-channel resource sharing; 0.28 is
    fit to the paper's Fig. 6 measurement (2645 adder LUTs for 32 channels
    x 31 adds of ~9-bit average width)."""
    total = 0.0
    width = acc_bits
    n = n_inputs
    while n > 1:
        adds = n // 2
        total += adds * width * luts_per_bit
        width += 1
        n = (n + 1) // 2
    return total


def run():
    def theory():
        return N_WEIGHTS * lut.luts_per_multiply(4)

    rom_theory = theory()
    # per-output-channel adder tree over CIN=32 products
    adders = 32 * adder_tree_luts(32)
    total = rom_theory + adders
    overhead = PAPER_IMPL_TOTAL / PAPER_HLS_ROM
    yield ("fig6_resource_breakdown_conv2", theory,
           f"rom_theory_eq3={rom_theory:.0f};paper_hls_rom={PAPER_HLS_ROM};"
           f"adder_model={adders:.0f};paper_impl_adder={PAPER_IMPL_ADDER};"
           f"model_total={total:.0f};paper_total={PAPER_IMPL_TOTAL};"
           f"calibrated_overhead={overhead:.2f}x")
