"""Eq. 3 / Fig. 2 — LUTs per multiply vs bit-width, and the quantization-error
side of the trade-off that led the paper to choose 4-bit."""
import jax

from repro.core import lut
from repro.core.quantization import QuantConfig, quant_error


def run():
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 256))
    rows = []
    for bits in (1, 2, 3, 4, 5, 6, 8):
        luts = lut.luts_per_multiply(bits)
        err = float(quant_error(x, QuantConfig(bits=max(bits, 2))))
        rows.append((bits, luts, err))

    def calc():
        return [lut.luts_per_multiply(b) for b in (1, 2, 3, 4, 5, 6, 8)]

    derived = ";".join(f"b{b}:luts={c:.2f}:mse={e:.4f}" for b, c, e in rows)
    yield ("eq3_luts_per_multiply_vs_bits", calc, derived)
    # the paper's pick: 4-bit = 2 LUTs, general multiplier 13-28
    lo, hi = lut.luts_per_multiply_general(4)
    yield ("eq3_vs_general_multiplier", lambda: lut.luts_per_multiply(4),
           f"lutmul=2;general_min={lo};general_max={hi};saving={lo/2:.1f}-{hi/2:.1f}x")
