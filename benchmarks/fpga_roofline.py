"""Fig. 1 / Table 1 / Eq. 1-2 — roofline comparison: DSP-based peak vs the
LUTMUL peak on Alveo U280 (1/64 resources, like the paper's figure), plus the
V100 rows from Table 1."""
from repro.core import fpga_model as F


def run():
    def compute():
        return F.roofline(F.U280, bits=4, frac=1 / 64, lut_overhead=2.0)

    r = compute()
    yield ("fig1_roofline_1_64_u280", compute,
           f"dsp_peak={r['dsp_peak_ops']/1e9:.1f}GOPS;"
           f"lutmul_peak={r['lutmul_peak_ops']/1e9:.1f}GOPS;"
           f"speedup={r['lutmul_peak_ops']/r['dsp_peak_ops']:.2f}x;"
           f"dsp_ridge={r['dsp_ridge_intensity']:.1f}ops_per_byte;"
           f"lut_ridge={r['lutmul_ridge_intensity']:.1f}ops_per_byte")

    full = F.roofline(F.U280, bits=4, frac=1.0, lut_overhead=2.0)
    yield ("table1_u280_full_device", lambda: F.roofline(F.U280, bits=4),
           f"dsp_peak_4bit={full['dsp_peak_ops']/1e12:.2f}TOPS;"
           f"lutmul_peak_4bit={full['lutmul_peak_ops']/1e12:.2f}TOPS;"
           f"int8_dsp_peak={F.dsp_peak_ops(F.U280, 8)/1e12:.2f}TOPS")

    yield ("table1_v100_rows", lambda: F.V100_PEAK_FP16_TENSOR,
           f"v100_fp16_tensor={F.V100_PEAK_FP16_TENSOR/1e12:.0f}TFLOPS;"
           f"v100_bw={F.V100_HBM_BW/1e9:.0f}GBps;"
           f"u280_hbm_bw={F.U280.hbm_bw/1e9:.0f}GBps")
