"""Serving throughput on this host (smoke config).

Three sections:

  * static-batch quant sweep (unquantized vs W8A8 vs the W4A4 LUT path) —
    the end-to-end embodiment of the paper's technique on the LM pool.  The
    timed call and the reported tokens/s now come from the SAME invocation
    (the old harness timed a 2-token rerun while labelling it with a 16-token
    measurement).
  * dense vs paged KV on a shared-prefix workload: the same request stream
    through dense per-slot buffers and the paged pool (``serve.paged``) —
    tokens/s, capacity vs allocated-page KV bytes, chunk-lane padding waste
    (prefill/admitted tokens), slot occupancy, and the prefix-hit rate.
  * chunked-admission latency (``serve_p99_decode_round_while_admitting``
    and ``serve_chunked_padding_waste``): a 2048-token prompt admitted
    through the prefill-chunk lane while three slots keep decoding — the
    per-round latency stays flat (bounded by the fixed chunk budget) where
    the monolithic fallback stalls every decoder for one full-prompt
    prefill round, and the chunk lane's padding waste stays ~1.0.
  * overload QoS (``serve_overload_*``): a logical-clock arrival trace that
    outpaces a small paged pool — deterministic watermark shedding, deadline
    expiry, latency percentiles of the survivors, and the snapshot/replay
    recovery overhead under injected NaN faults.
  * Poisson-arrival continuous vs static batching: the same request stream
    (seeded exponential inter-arrivals, heterogeneous decode budgets) served
    by the slot Scheduler (admit-on-free-slot) vs grouped static batches
    that wait for their stragglers and pad every member to the group's max
    budget.  Useful-token throughput and request latency per policy.
  * sharded-engine scaling (``--mesh DxM``, or automatic when the process
    sees >1 device): the SAME fixed workload through ``ShardedEngine`` on
    each requested (data, model) mesh — the scaling curve for the
    tensor-parallel LUT matmul x data-parallel slot pool.  On a CPU host::

        XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
            python -m benchmarks.serving_bench --mesh 1x1 --mesh 2x2 --mesh 1x8

TPU-projected numbers live in EXPERIMENTS.md §Roofline."""
import random
import statistics
import time

import jax

from repro import configs
from repro.models import transformer as T
from repro.serve import Engine, Request, Scheduler, ServeConfig, make_engine


def _timed(fn, n=3) -> float:
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts)


def _quant_sweep():
    rows = []
    B, S, NEW = 4, 8, 16
    for quant in ("none", "w8a8", "w4a4_lut"):
        cfg = configs.get_config("qwen2-7b", smoke=True, quant=quant)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        eng = make_engine(params, cfg, ServeConfig(max_len=64))
        prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                     cfg.vocab)
        eng.generate(prompts, max_new_tokens=NEW)        # warmup/compile
        dt = _timed(lambda: eng.generate(prompts, max_new_tokens=NEW))
        rows.append((f"serve_smoke_{quant}", dt * 1e6,
                     f"tokens_per_s={B * NEW / dt:.1f};batch={B};"
                     f"new_tokens={NEW}"))
    return rows


def _zero_low_planes(tree, draft_planes=2):
    """Zero the low (B - draft_planes) bit-planes of every draftable tmac
    leaf, IN the already-quantized engine params.  A leaf whose low planes
    are all zero decodes to exactly ``mult`` x its top-plane code, so the
    truncated-plane drafter computes bit-identical logits to the target and
    every speculative round accepts all K drafts.  That is the accept-rate
    ~1.0 regime — the bench reports the measured rate honestly either way."""
    if isinstance(tree, dict):
        if "w_tmac" in tree and "w_tern" not in tree and \
                tree["w_q"].ndim >= 3 and tree["w_q"].shape[-3] > draft_planes:
            out = dict(tree)
            nlow = tree["w_q"].shape[-3] - draft_planes
            out["w_q"] = tree["w_q"].at[..., :nlow, :, :].set(0)
            return out
        return {k: _zero_low_planes(v, draft_planes) for k, v in tree.items()}
    if isinstance(tree, (tuple, list)):
        return type(tree)(_zero_low_planes(v, draft_planes) for v in tree)
    return tree


def _specdec_rows():
    """Self-speculative decoding (draft-k with the truncated-plane drafter)
    vs plain chunked decode on the SAME w4a4 tmac engine geometry.

    The measurement is the STEADY-STATE decode phase: all slots admitted and
    decoding, then a fixed window of scheduler rounds is timed and tokens/s
    is emitted-tokens / wall-clock over that window.  Decode rounds are what
    speculation accelerates — a round is K truncated-plane draft steps plus
    ONE batched (K+1)-token verify forward instead of ``chunk`` sequential
    full forwards, so the per-round compute drops by roughly
    (K*beta+gamma)/(K+1) with beta the draft/full cost ratio (~0.5-0.6:
    the tmac kernel is linear in the plane count) and gamma the batched
    verify cost in decode-step units (README §Self-speculative decoding).

    Engine params get the zero-low-planes surgery (see ``_zero_low_planes``)
    on BOTH rows, so the two engines serve bit-identical transcripts
    (asserted below) and the spec row operates at accept rate ~1.0 — the
    upper bound of the speedup model.  ``accept_rate`` in the derived column
    is the measured value over the timed window, not the assumption.

    Two speedup figures, both honest about what they measure:

      * ``speedup_vs_plain`` — measured wall-clock on THIS host.  The CPU
        ``ref`` lutmul backend decodes the bitplanes into a dense int
        matmul every call, so truncating 4 planes to 2 saves almost
        nothing here (w2 vs w4 decode rounds differ ~4%) and the measured
        ratio sits near 1.0x.  Same caveat as the paged rows above: CPU
        wall-clock of the smoke model is not the speed signal.
      * ``projected_speedup_weight_bound`` — (accept*K+1)/(K*beta+gamma)
        with beta read from the committed kernel baseline
        (``BENCH_kernels.json`` tmac w2/w4 rows — the cost-vs-planes curve
        IS linear where the kernel dominates) and gamma=1 (weight-bound
        verify: a (K+1)-token forward re-reads the planes once, the stock
        speculative-decoding premise).  This is the number the drafter's
        plane-sliced cost structure delivers when the tmac kernel, not the
        XLA op overhead, is the bottleneck."""
    SLOTS, CHUNK, S, BUDGET, K, ROUNDS = 4, 4, 8, 70, 7, 6
    rng = random.Random(0)
    cfg = configs.get_config("qwen2-7b", smoke=True, quant="w4a4_tmac")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [[rng.randrange(cfg.vocab) for _ in range(S)]
               for _ in range(SLOTS)]

    def build(**spec_kw):
        eng = make_engine(params, cfg,
                          ServeConfig(max_len=96, quant="w4a4_tmac",
                                      **spec_kw))
        eng.params = _zero_low_planes(eng.params)
        eng._step_fns = {}
        return eng

    def steady_decode(eng):
        """(median round time, tokens per round, stats delta) once every
        slot is past admission and decoding."""
        sched = Scheduler(eng, slots=SLOTS, chunk=CHUNK)
        for p in prompts:
            sched.submit(Request(prompt=p, max_new_tokens=BUDGET))
        sched.step()                         # admission round
        for _ in range(2):                   # settle + warm the decode lane
            sched.step()
        e0 = sched.stats["emitted_tokens"]
        s0 = dict(sched.stats)
        ts = []
        for _ in range(ROUNDS):
            t0 = time.perf_counter()
            sched.step()
            ts.append(time.perf_counter() - t0)
        emitted = sched.stats["emitted_tokens"] - e0
        delta = {k: sched.stats[k] - s0.get(k, 0)
                 for k in ("spec_rounds", "spec_drafted", "spec_accepted")}
        while sched.has_work:
            sched.step()
        transcript = sorted((tuple(r.prompt), tuple(r.tokens))
                            for r in sched.finished)
        return statistics.median(ts), emitted / ROUNDS, delta, transcript

    eng_plain = build()
    steady_decode(eng_plain)                         # warmup / compile
    dt_plain, tok_plain, _, want = steady_decode(eng_plain)
    tps_plain = tok_plain / dt_plain
    rows = [("serve_specdec_off_w4a4", dt_plain * 1e6,
             f"tokens_per_s={tps_plain:.1f};slots={SLOTS};chunk={CHUNK};"
             f"new_tokens={BUDGET};decode_rounds={ROUNDS}")]

    eng_spec = build(spec_decode=True, draft_planes=2, draft_k=K)
    steady_decode(eng_spec)                          # warmup / compile
    dt_spec, tok_spec, delta, got = steady_decode(eng_spec)
    assert got == want, "speculative transcripts diverged from plain decode"
    tps_spec = tok_spec / dt_spec
    accept = delta["spec_accepted"] / max(delta["spec_drafted"], 1)
    beta = _kernel_beta()
    projected = (accept * K + 1) / (K * beta + 1.0)
    rows.append(("serve_specdec_w4a4", dt_spec * 1e6,
                 f"tokens_per_s={tps_spec:.1f};accept_rate={accept:.2f};"
                 f"draft_k={K};draft_planes=2;"
                 f"spec_rounds={delta['spec_rounds']};"
                 f"speedup_vs_plain={tps_spec / tps_plain:.2f}x;"
                 f"draft_beta_kernel={beta:.2f};"
                 f"projected_speedup_weight_bound={projected:.2f}x"))
    return rows


def _kernel_beta(default=0.60):
    """Draft/target kernel cost ratio from the committed kernel baseline:
    median_ms of the tmac w2 row over the w4 row (the 2-of-4-plane slice
    the drafter runs).  Falls back to the plane-linear model's ~0.6 when
    BENCH_kernels.json is not present (e.g. bench run from a bare tree)."""
    import json
    import os
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_kernels.json")
    try:
        with open(path) as fh:
            rows = {r["name"]: r["median_ms"]
                    for r in json.load(fh)["rows"]}
        w2 = next(v for k, v in rows.items()
                  if "tmac_w2" in k and "onehot" not in k)
        w4 = next(v for k, v in rows.items()
                  if "tmac_w4" in k and "onehot" not in k)
        return w2 / w4
    except (OSError, KeyError, StopIteration, ValueError):
        return default


def _poisson_rows():
    """Continuous (slot scheduler) vs static batching on one arrival trace.

    Heavy-tailed decode budgets (most requests short, ~15% run to 40
    tokens): the realistic mix where static batching pays for straggler
    waits and for padding every group member to the service max, while the
    slot scheduler backfills freed slots immediately."""
    SLOTS, CHUNK, S, N = 4, 8, 8, 16
    rng = random.Random(0)
    cfg = configs.get_config("qwen2-7b", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = make_engine(params, cfg, ServeConfig(max_len=64))
    prompts = [[rng.randrange(cfg.vocab) for _ in range(S)] for _ in range(N)]
    budgets = [40 if rng.random() < 0.15 else rng.randint(2, 8)
               for _ in range(N)]
    new_max = max(budgets)

    # warm both paths (shared engine jit caches)
    batch = jax.numpy.asarray(prompts[:SLOTS], jax.numpy.int32)
    eng.generate(batch, max_new_tokens=new_max)
    Scheduler(eng, slots=SLOTS, chunk=CHUNK).run(
        [Request(prompt=prompts[0], max_new_tokens=4)])

    # arrival trace: exponential gaps, mean = 1/4 of a (warm) static batch —
    # moderate load: arrivals overlap decode, so static groups wait for
    # stragglers while the scheduler starts work the moment it lands
    t_batch = _timed(lambda: eng.generate(batch, max_new_tokens=new_max), n=2)
    arrivals, t = [], 0.0
    for _ in range(N):
        arrivals.append(t)
        t += rng.expovariate(4.0 / t_batch)

    # -- continuous: admit the moment a slot frees ---------------------------
    sched = Scheduler(eng, slots=SLOTS, chunk=CHUNK)
    reqs = [Request(prompt=p, max_new_tokens=b)
            for p, b in zip(prompts, budgets)]
    idx, t0 = 0, time.perf_counter()
    def clock():                                 # finish times stamp
        return time.perf_counter() - t0
    while idx < N or sched.has_work:             # post-chunk via the callable
        now = clock()
        while idx < N and arrivals[idx] <= now:
            sched.submit(reqs[idx], now=now)
            idx += 1
        if not sched.has_work:
            time.sleep(min(arrivals[idx] - now, 1e-3))
            continue
        sched.step(now=clock)
    makespan_c = time.perf_counter() - t0
    lat_c = [r.finish_time - r.arrival_time for r in reqs]
    tokens = sum(budgets)
    tps_c = tokens / makespan_c

    # -- static: group in arrival order, wait for stragglers, pad to the
    #    group max budget (one compiled shape: [SLOTS, S] x new_max) ---------
    virtual, lat_s = 0.0, []
    for g in range(0, N, SLOTS):
        group = list(range(g, min(g + SLOTS, N)))
        gp = [prompts[i] for i in group]
        gp += [gp[-1]] * (SLOTS - len(gp))               # pad the last group
        start = max(virtual, max(arrivals[i] for i in group))
        dt = _timed(lambda gp=gp: eng.generate(
            jax.numpy.asarray(gp, jax.numpy.int32), max_new_tokens=new_max),
            n=1)
        virtual = start + dt
        lat_s += [virtual - arrivals[i] for i in group]
    tps_s = tokens / virtual

    return [
        ("serve_poisson_continuous", makespan_c * 1e6,
         f"tokens_per_s={tps_c:.1f};mean_latency_s={statistics.mean(lat_c):.3f};"
         f"slots={SLOTS};chunk={CHUNK};requests={N};"
         f"speedup_vs_static={tps_c / tps_s:.2f}x"),
        ("serve_poisson_static", virtual * 1e6,
         f"tokens_per_s={tps_s:.1f};mean_latency_s={statistics.mean(lat_s):.3f};"
         f"batch={SLOTS};new_tokens={new_max};requests={N}"),
    ]


def _paged_rows():
    """Dense per-slot KV buffers vs the paged pool on a shared-prefix
    workload:

      * ``kv_bytes`` — dense row: max_len *capacity*; paged row: peak
        *allocated pages* (real residency — what actually scales with the
        traffic);
      * ``padding_waste`` — prefill_tokens / admitted_tokens of the chunk
        lane (~1.0 under backlog: chunk rounds pack real prompt tokens,
        padding only on the final partial round);
      * ``occupancy`` — mean fraction of live slots per decode round;
      * ``prefix_hit_rate`` — fraction of prompt pages served from already
        resident pages (paged only; nonzero on this workload by design).

    On CPU the two rows' tokens/s are one-shot wall-clock measurements of
    a tiny smoke model — run-to-run noise swamps the gather/scatter cost,
    so the speed columns are not the signal here.  The stable committed
    signal is the memory trade (allocated bytes vs capacity) + hit rate;
    the TPU speed story is the ROADMAP paged-TPU item (gather fusion).
    """
    SLOTS, CHUNK, N = 4, 8, 16
    rng = random.Random(0)
    cfg = configs.get_config("qwen2-7b", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    bases = [[rng.randrange(cfg.vocab) for _ in range(12)] for _ in range(3)]
    prompts = [list(rng.choice(bases))
               + [rng.randrange(cfg.vocab) for _ in range(rng.randint(0, 3))]
               for _ in range(N)]
    budgets = [24 if rng.random() < 0.15 else rng.randint(2, 8)
               for _ in range(N)]
    tokens = sum(budgets)
    rows = []
    for name, scfg in (
            ("serve_workload_dense", ServeConfig(max_len=64)),
            ("serve_workload_paged", ServeConfig(max_len=64, paged=True,
                                                 page_size=4))):
        eng = make_engine(params, cfg, scfg)

        def once():
            sched = Scheduler(eng, slots=SLOTS, chunk=CHUNK)
            sched.run([Request(prompt=p, max_new_tokens=b)
                       for p, b in zip(prompts, budgets)])
            return sched

        once()                                     # warmup / compile
        t0 = time.perf_counter()
        sched = once()
        dt = time.perf_counter() - t0
        derived = (f"tokens_per_s={tokens / dt:.1f};slots={SLOTS};"
                   f"chunk={CHUNK};requests={N};"
                   f"kv_bytes={eng.kv_cache_bytes(SLOTS)};"
                   f"padding_waste={sched.padding_waste:.2f};"
                   f"occupancy={sched.mean_occupancy:.2f}")
        if eng.paged:
            derived += (f";prefix_hit_rate={eng.pool.prefix_hit_rate:.2f};"
                        f"page_size={scfg.page_size};"
                        f"peak_pages={eng.pool.peak_pages};"
                        f"preemptions={eng.pool.preemptions}")
        rows.append((name, dt * 1e6, derived))
    return rows


def _overload_rows():
    """Deadline/priority QoS under sustained overload (serve.scheduler fault
    tolerance): arrivals outpace a deliberately small paged pool, so the
    watermark shedder and deadline expiry must do the dropping.

    The drive loop runs on a LOGICAL clock (one tick per scheduling round,
    two arrivals per tick) — every robustness decision (shed choice, expiry,
    preemption victim) is a pure function of that clock, so the row reports
    ``deterministic=1`` only after replaying the identical trace and getting
    identical per-request outcomes.  Latency percentiles are in ticks (flat
    p99 = survivors are served promptly *because* the excess was shed at
    admission instead of timing out in queue).  The companion
    ``serve_overload_faulted`` row reruns the trace with seeded NaN faults +
    per-round snapshots: the recovery-overhead measurement (wall-clock ratio
    + replay rounds) for the crash-recovery path."""
    from repro.serve.faults import Fault, FaultPlan

    SLOTS, CHUNK, S, N = 2, 4, 6, 24
    rng = random.Random(0)
    cfg = configs.get_config("qwen2-7b", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    # num_pages well under the worst-case auto-size: decode saturates the
    # pool, so the watermark shedder (not luck) does the dropping
    eng = make_engine(params, cfg, ServeConfig(max_len=32, paged=True,
                                           page_size=4, num_pages=13))
    prompts = [[rng.randrange(cfg.vocab) for _ in range(S)] for _ in range(N)]
    budgets = [rng.randint(4, 12) for _ in range(N)]
    prios = [rng.randint(0, 1) for _ in range(N)]
    # half the low-priority requests carry tight deadlines (arrival + 4
    # ticks): under overload they either get served quickly or expire
    arrivals = [i / 3.0 for i in range(N)]
    deadlines = [arrivals[i] + 4.0 if prios[i] == 0 and rng.random() < 0.5
                 else None for i in range(N)]

    def drive(**sched_kw):
        sched = Scheduler(eng, slots=SLOTS, chunk=CHUNK, shed_watermark=0.6,
                          overload_queue=3, **sched_kw)
        reqs = [Request(prompt=p, max_new_tokens=b, priority=pr, deadline=d)
                for p, b, pr, d in zip(prompts, budgets, prios, deadlines)]
        idx, t = 0, 0.0
        t0 = time.perf_counter()
        while idx < N or sched.has_work:
            while idx < N and arrivals[idx] <= t:
                sched.submit(reqs[idx], now=t)
                idx += 1
            sched.step(now=t)
            t += 1.0
            if t > 4096:
                raise RuntimeError("overload bench failed to drain")
        dt = time.perf_counter() - t0
        sched.check_drained()
        return sched, reqs, dt

    drive()                                          # warmup / compile
    sched, reqs, dt = drive()
    outcomes = [r.finish_reason for r in reqs]
    sched2, reqs2, _ = drive()                       # identical logical trace
    deterministic = int(outcomes == [r.finish_reason for r in reqs2]
                        and sched.stats["shed"] == sched2.stats["shed"])
    served = [r for r in reqs if r.finish_reason in ("eos", "length")]
    lats = sorted(r.finish_time - r.arrival_time for r in served)
    p50 = lats[len(lats) // 2]
    p99 = lats[min(len(lats) - 1, int(len(lats) * 0.99))]
    tokens = sum(len(r.tokens) for r in served)
    rows = [("serve_overload_shedding", dt * 1e6,
             f"tokens_per_s={tokens / dt:.1f};requests={N};slots={SLOTS};"
             f"served={len(served)};shed={sched.stats['shed']};"
             f"timed_out={sched.stats['timed_out']};"
             f"preemptions={sched.stats['preemptions']};"
             f"p50_latency_ticks={p50:.1f};p99_latency_ticks={p99:.1f};"
             f"deterministic={deterministic}")]

    # recovery overhead: the same trace with per-round snapshots and two
    # injected NaN rounds — the differential suites prove transcripts stay
    # token-identical; this row prices that guarantee
    plan = FaultPlan([Fault(site="decode", index=3, kind="nan_logits"),
                      Fault(site="decode", index=9, kind="nan_logits")])
    eng.set_fault_plan(plan)
    try:
        fsched, _, fdt = drive(snapshot_interval=1, max_retries=4)
    finally:
        eng.set_fault_plan(None)
    rows.append(
        ("serve_overload_faulted", fdt * 1e6,
         f"recoveries={fsched.stats['recoveries']};"
         f"rounds={fsched.stats['rounds']};clean_rounds={sched.stats['rounds']};"
         f"snapshot_overhead={fdt / dt:.2f}x;faults=2;"
         f"shed={fsched.stats['shed']};"
         f"timed_out={fsched.stats['timed_out']}"))
    return rows


def _sharded_workload(engine, slots: int, chunk: int, prompts, budgets):
    """Drain one fixed request set through a fresh Scheduler; makespan (s)."""
    sched = Scheduler(engine, slots=slots, chunk=chunk)
    reqs = [Request(prompt=p, max_new_tokens=b)
            for p, b in zip(prompts, budgets)]
    t0 = time.perf_counter()
    sched.run(reqs)
    return time.perf_counter() - t0


def _sharded_rows(meshes=None):
    """tokens/s of the sharded engine per (data, model) mesh.

    One fixed seeded workload (same prompts/budgets for every mesh) so the
    rows form a scaling curve.  Meshes that need more devices than the
    process has are skipped — run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to get the full
    curve into BENCH_serving.json.
    """
    from repro.launch.mesh import make_serving_mesh, parse_mesh

    explicit = meshes is not None
    if meshes is None:
        meshes = ["1x1", "2x2", "1x8", "8x1"]
    SLOTS, CHUNK, S, N = 8, 8, 8, 24
    rng = random.Random(0)
    cfg = configs.get_config("qwen2-7b", smoke=True, quant="w4a4_lut")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [[rng.randrange(cfg.vocab) for _ in range(S)] for _ in range(N)]
    budgets = [40 if rng.random() < 0.15 else rng.randint(2, 8)
               for _ in range(N)]
    tokens = sum(budgets)
    rows = []
    for spec in meshes:
        nd, nm = parse_mesh(spec)
        if SLOTS % nd:
            if explicit:
                raise ValueError(f"mesh {spec}: data axis must divide "
                                 f"slots={SLOTS}")
            continue
        if nd * nm > jax.device_count():
            if explicit:
                make_serving_mesh(spec)      # raises with the XLA_FLAGS recipe
            continue
        eng = make_engine(params, cfg,
                          ServeConfig(max_len=64, quant="w4a4_lut"),
                          mesh=make_serving_mesh(spec))
        _sharded_workload(eng, SLOTS, CHUNK, prompts, budgets)   # warmup
        dt = _sharded_workload(eng, SLOTS, CHUNK, prompts, budgets)
        # per-shard KV bytes make the head-sharding memory win visible next
        # to tokens/s: the data axis splits the slots and — when the head
        # counts divide the model axis — the model axis splits the KV heads
        rows.append((f"serve_sharded_{spec}", dt * 1e6,
                     f"tokens_per_s={tokens / dt:.1f};mesh={spec};"
                     f"slots={SLOTS};chunk={CHUNK};requests={N};"
                     f"tp_leaves={eng.n_tp_leaves};"
                     f"kv_bytes_per_shard={eng.kv_cache_bytes(SLOTS)};"
                     f"head_sharded={int(eng.head_sharded)}"))
    return rows


def _chunked_admission_rows():
    """Per-round latency while a 2048-token prompt admits through the
    prefill-chunk lane — the tentpole's bimodal-latency measurement.

    Three slots decode continuously; a 2048-token prompt is submitted into
    the fourth.  ``serve_p99_decode_round_while_admitting`` reports the p99
    wall-clock of the rounds between that submission and the prompt's first
    emitted token: with chunked admission every round carries at most
    ``prefill_chunk`` prompt tokens, so the p99 stays flat (bounded by the
    chunk budget, independent of prompt length), where the monolithic
    fallback pays the whole 2048-token prefill inside one round — the
    ``monolithic_admit_round_ms`` column prices exactly that stall on the
    same engine geometry.  ``serve_chunked_padding_waste`` commits the chunk
    lane's prefill/admitted ratio for the same trace (~1.0: chunk rounds
    pack real tokens back-to-back; only the final partial round pads)."""
    SLOTS, CHUNK, PREFILL, LONG = 4, 4, 128, 2048
    rng = random.Random(0)
    cfg = configs.get_config("qwen2-7b", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    scfg = ServeConfig(max_len=LONG + 128, prefill_chunk=PREFILL)
    long_prompt = [rng.randrange(cfg.vocab) for _ in range(LONG)]
    deco_prompts = [[rng.randrange(cfg.vocab) for _ in range(8)]
                    for _ in range(SLOTS - 1)]

    def admit_trace(eng):
        """(decode-round times while admitting, decode-only times, sched)."""
        sched = Scheduler(eng, slots=SLOTS, chunk=CHUNK)
        for p in deco_prompts:
            sched.submit(Request(prompt=p, max_new_tokens=120))
        big = Request(prompt=long_prompt, max_new_tokens=8)
        sched.submit(big)
        admit = []
        while not big.tokens:                # first token = admission done
            t0 = time.perf_counter()
            sched.step()
            admit.append(time.perf_counter() - t0)
            if len(admit) > 4 * (LONG // CHUNK):
                raise RuntimeError("long prompt failed to admit")
        base = []                            # steady decode-only rounds
        for _ in range(6):
            t0 = time.perf_counter()
            sched.step()
            base.append(time.perf_counter() - t0)
        while sched.has_work:
            sched.step()
        return admit, base, sched

    eng = make_engine(params, cfg, scfg)
    # warm both compiled signatures (prefill-chunk lane + decode-only)
    Scheduler(eng, slots=SLOTS, chunk=CHUNK).run(
        [Request(prompt=long_prompt[:PREFILL + 8], max_new_tokens=CHUNK)])
    admit, base, sched = admit_trace(eng)

    class _Mono(Engine):
        # force the batched-prefill fallback: the whole 2048-token prompt
        # lands in a single admission round
        requires_monolithic_admission = True

    meng = _Mono(cfg, params, scfg)
    admit_trace(meng)                        # warmup / compile
    m_admit, _, _ = admit_trace(meng)

    a = sorted(admit)
    p99 = a[min(len(a) - 1, int(len(a) * 0.99))]
    base_med = statistics.median(base)
    return [
        ("serve_p99_decode_round_while_admitting", p99 * 1e6,
         f"p99_round_ms={p99 * 1e3:.2f};"
         f"decode_only_round_ms={base_med * 1e3:.2f};"
         f"monolithic_admit_round_ms={max(m_admit) * 1e3:.2f};"
         f"admit_rounds={len(admit)};prompt_tokens={LONG};"
         f"prefill_chunk={PREFILL};slots={SLOTS};chunk={CHUNK}"),
        ("serve_chunked_padding_waste", sum(admit) * 1e6,
         f"padding_waste={sched.padding_waste:.3f};"
         f"prefill_tokens={sched.stats['prefill_tokens']};"
         f"admitted_tokens={sched.stats['admitted_tokens']};"
         f"admission_rounds={sched.stats['admission_rounds']}"),
    ]


def run():
    rows = (_quant_sweep() + _specdec_rows() + _poisson_rows() + _paged_rows()
            + _chunked_admission_rows() + _overload_rows())
    if jax.device_count() > 1:
        rows += _sharded_rows()
    else:
        # the committed BENCH_serving.json carries serve_sharded_* rows; a
        # single-device diff would report them missing (and fail a gate),
        # so say why they are absent
        import sys
        print("serving_bench: 1 device visible — serve_sharded_* rows "
              "skipped; set XLA_FLAGS=--xla_force_host_platform_device_"
              "count=8 to produce (and diff) the full scaling curve",
              file=sys.stderr)
    return rows


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(
        description="sharded-serving scaling curve (see module docstring)")
    ap.add_argument("--mesh", action="append", metavar="DxM",
                    help="(data, model) mesh to benchmark; repeatable. "
                         "Default: the full curve that fits this host.")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    for name, us, derived in _sharded_rows(args.mesh):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
