"""Serving throughput on this host (smoke config): unquantized vs the W4A4
LUT path vs W8A8 — the end-to-end embodiment of the paper's technique on the
LM pool.  TPU-projected numbers live in EXPERIMENTS.md §Roofline."""
import dataclasses
import time

import jax

from repro import configs
from repro.models import transformer as T
from repro.serve.engine import Engine, ServeConfig


def run():
    rows = []
    for quant in ("none", "w8a8", "w4a4_lut"):
        cfg = configs.get_config("qwen2-7b", smoke=True, quant=quant)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        eng = Engine(cfg, params, ServeConfig(max_len=64))
        prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0,
                                     cfg.vocab)
        out = eng.generate(prompts, max_new_tokens=4)   # warmup/compile
        t0 = time.perf_counter()
        out = eng.generate(prompts, max_new_tokens=16)
        dt = time.perf_counter() - t0
        tps = 4 * 16 / dt
        name = f"serve_smoke_{quant}"
        rows.append((name, lambda e=eng, p=prompts: e.generate(
            p, max_new_tokens=2), f"tokens_per_s={tps:.1f};batch=4"))
    return rows
