"""Serving throughput on this host (smoke config).

Two sections:

  * static-batch quant sweep (unquantized vs W8A8 vs the W4A4 LUT path) —
    the end-to-end embodiment of the paper's technique on the LM pool.  The
    timed call and the reported tokens/s now come from the SAME invocation
    (the old harness timed a 2-token rerun while labelling it with a 16-token
    measurement).
  * Poisson-arrival continuous vs static batching: the same request stream
    (seeded exponential inter-arrivals, heterogeneous decode budgets) served
    by the slot Scheduler (admit-on-free-slot) vs grouped static batches
    that wait for their stragglers and pad every member to the group's max
    budget.  Useful-token throughput and request latency per policy.

TPU-projected numbers live in EXPERIMENTS.md §Roofline."""
import random
import statistics
import time

import jax

from repro import configs
from repro.models import transformer as T
from repro.serve import Engine, Request, Scheduler, ServeConfig


def _timed(fn, n=3) -> float:
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts)


def _quant_sweep():
    rows = []
    B, S, NEW = 4, 8, 16
    for quant in ("none", "w8a8", "w4a4_lut"):
        cfg = configs.get_config("qwen2-7b", smoke=True, quant=quant)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        eng = Engine(cfg, params, ServeConfig(max_len=64))
        prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                     cfg.vocab)
        eng.generate(prompts, max_new_tokens=NEW)        # warmup/compile
        dt = _timed(lambda: eng.generate(prompts, max_new_tokens=NEW))
        rows.append((f"serve_smoke_{quant}", dt * 1e6,
                     f"tokens_per_s={B * NEW / dt:.1f};batch={B};"
                     f"new_tokens={NEW}"))
    return rows


def _poisson_rows():
    """Continuous (slot scheduler) vs static batching on one arrival trace.

    Heavy-tailed decode budgets (most requests short, ~15% run to 40
    tokens): the realistic mix where static batching pays for straggler
    waits and for padding every group member to the service max, while the
    slot scheduler backfills freed slots immediately."""
    SLOTS, CHUNK, S, N = 4, 8, 8, 16
    rng = random.Random(0)
    cfg = configs.get_config("qwen2-7b", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, ServeConfig(max_len=64))
    prompts = [[rng.randrange(cfg.vocab) for _ in range(S)] for _ in range(N)]
    budgets = [40 if rng.random() < 0.15 else rng.randint(2, 8)
               for _ in range(N)]
    new_max = max(budgets)

    # warm both paths (shared engine jit caches)
    batch = jax.numpy.asarray(prompts[:SLOTS], jax.numpy.int32)
    eng.generate(batch, max_new_tokens=new_max)
    Scheduler(eng, slots=SLOTS, chunk=CHUNK, prompt_bucket="pow2").run(
        [Request(prompt=prompts[0], max_new_tokens=4)])

    # arrival trace: exponential gaps, mean = 1/4 of a (warm) static batch —
    # moderate load: arrivals overlap decode, so static groups wait for
    # stragglers while the scheduler starts work the moment it lands
    t_batch = _timed(lambda: eng.generate(batch, max_new_tokens=new_max), n=2)
    arrivals, t = [], 0.0
    for _ in range(N):
        arrivals.append(t)
        t += rng.expovariate(4.0 / t_batch)

    # -- continuous: admit the moment a slot frees ---------------------------
    sched = Scheduler(eng, slots=SLOTS, chunk=CHUNK, prompt_bucket="pow2")
    reqs = [Request(prompt=p, max_new_tokens=b)
            for p, b in zip(prompts, budgets)]
    idx, t0 = 0, time.perf_counter()
    clock = lambda: time.perf_counter() - t0     # finish times stamp
    while idx < N or sched.has_work:             # post-chunk via the callable
        now = clock()
        while idx < N and arrivals[idx] <= now:
            sched.submit(reqs[idx], now=now)
            idx += 1
        if not sched.has_work:
            time.sleep(min(arrivals[idx] - now, 1e-3))
            continue
        sched.step(now=clock)
    makespan_c = time.perf_counter() - t0
    lat_c = [r.finish_time - r.arrival_time for r in reqs]
    tokens = sum(budgets)
    tps_c = tokens / makespan_c

    # -- static: group in arrival order, wait for stragglers, pad to the
    #    group max budget (one compiled shape: [SLOTS, S] x new_max) ---------
    virtual, lat_s = 0.0, []
    for g in range(0, N, SLOTS):
        group = list(range(g, min(g + SLOTS, N)))
        gp = [prompts[i] for i in group]
        gp += [gp[-1]] * (SLOTS - len(gp))               # pad the last group
        start = max(virtual, max(arrivals[i] for i in group))
        dt = _timed(lambda gp=gp: eng.generate(
            jax.numpy.asarray(gp, jax.numpy.int32), max_new_tokens=new_max),
            n=1)
        virtual = start + dt
        lat_s += [virtual - arrivals[i] for i in group]
    tps_s = tokens / virtual

    return [
        ("serve_poisson_continuous", makespan_c * 1e6,
         f"tokens_per_s={tps_c:.1f};mean_latency_s={statistics.mean(lat_c):.3f};"
         f"slots={SLOTS};chunk={CHUNK};requests={N};"
         f"speedup_vs_static={tps_c / tps_s:.2f}x"),
        ("serve_poisson_static", virtual * 1e6,
         f"tokens_per_s={tps_s:.1f};mean_latency_s={statistics.mean(lat_s):.3f};"
         f"batch={SLOTS};new_tokens={new_max};requests={N}"),
    ]


def run():
    return _quant_sweep() + _poisson_rows()
