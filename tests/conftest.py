import os
import sys

# tests run on 1 CPU device by design (the dry-run owns the 512-device env)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


try:
    import hypothesis  # noqa: F401
except ImportError:          # container without hypothesis: use the shim
    import _hypothesis_stub
    _hypothesis_stub.install(sys.modules)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration tests")
