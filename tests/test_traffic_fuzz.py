"""Randomized-traffic differential fuzz: ShardedEngine vs the single-device
Engine under the SAME scheduler, on the SAME seeded request stream.

Each fuzz stream draws prompts, decode budgets (including the legal 0),
EOS ids that may sit inside the prompt, mixed per-request top-k/top-p at
temperature 0 (greedy overrides the filters, so transcripts must stay
deterministic), and a staggered submit/step interleave.  Both engines replay
the identical stream and interleave; at temperature 0 every transcript and
finish reason must match token for token — the engines differ only in HOW
the math is laid out (head-sharded attention, expert-sharded MoE, data-
parallel slot pools), never in WHAT it computes.

Runs in a subprocess with 8 fake CPU devices (the CI recipe) on a 2x2 and a
1x8 (data, model) mesh.  Seeds are fixed; ``REPRO_FUZZ_EXAMPLES`` bounds the
number of streams so the CI matrix stays fast.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_TRAFFIC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, random
    import jax, numpy as np
    from repro import configs
    from repro.launch.mesh import make_serving_mesh
    from repro.models import transformer as T
    from repro.serve import (Engine, Request, Scheduler, ServeConfig,
                             ShardedEngine, make_engine)

    N_STREAMS = max(1, int(os.environ.get("REPRO_FUZZ_EXAMPLES", "8")) // 8)
    MAX_LEN, SLOTS, CHUNK = 32, 4, 3

    class MonoEngine(Engine):
        # force every admission through the batched-prefill fallback: the
        # chunked-vs-monolithic differential below asserts the two paths
        # serve bit-identical transcripts
        requires_monolithic_admission = True

    def make_stream(cfg, seed):
        rng = random.Random(seed)
        n = rng.randint(6, 10)
        reqs = []
        for _ in range(n):
            L = rng.randint(1, 8)
            prompt = [rng.randrange(cfg.vocab) for _ in range(L)]
            budget = rng.choice([0, 0, 1, 2, 3, 5, 8])
            eos = None
            r = rng.random()
            if r < 0.3:
                # EOS likely to fire mid-decode: a low token id (greedy
                # argmax over random weights lands anywhere, so sometimes
                # this truncates, sometimes not — both must agree)
                eos = rng.randrange(cfg.vocab)
            elif r < 0.5:
                # EOS that sits INSIDE the prompt: prompt tokens must never
                # terminate the request
                eos = prompt[rng.randrange(L)]
            # mixed sampling params at temperature 0: greedy overrides the
            # filters, so these must not perturb transcripts
            top_k = rng.choice([None, 0, 3, 8])
            top_p = rng.choice([None, 1.0, 0.7])
            reqs.append(dict(prompt=prompt, max_new_tokens=budget,
                             eos_id=eos, temperature=0.0, top_k=top_k,
                             top_p=top_p))
        # staggered admission plan: how many submissions before each step
        plan = [rng.randint(0, 3) for _ in range(4 * n)]
        return reqs, plan

    def drive(engine, specs, plan):
        sched = Scheduler(engine, slots=SLOTS, chunk=CHUNK)
        reqs = [Request(**s) for s in specs]
        i, p = 0, 0
        while i < len(reqs) or sched.has_work:
            take = plan[p % len(plan)]; p += 1
            for _ in range(min(take, len(reqs) - i)):
                sched.submit(reqs[i]); i += 1
            if not sched.has_work and i < len(reqs):
                sched.submit(reqs[i]); i += 1
            sched.step()
        # slot-pool invariants after every stream
        assert all(s is None for s in sched.slots) and not sched.queue
        return [(r.tokens, r.finish_reason) for r in reqs]

    def stream_case(arch, quant, mesh_spec, seed, prefill_chunk,
                    mono_check=False):
        cfg = dataclasses.replace(
            configs.get_config(arch, smoke=True, quant=quant),
            compute_dtype="float32")
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        scfg = ServeConfig(max_len=MAX_LEN, quant=quant,
                           prefill_chunk=prefill_chunk)
        specs, plan = make_stream(cfg, seed)
        want = drive(make_engine(params, cfg, scfg), specs, plan)
        eng = make_engine(params, cfg, scfg,
                          mesh=make_serving_mesh(mesh_spec))
        got = drive(eng, specs, plan)
        for i, (w, g) in enumerate(zip(want, got)):
            assert g == w, (arch, mesh_spec, seed, i, g, w)
        if mono_check:
            # chunked-vs-monolithic: the SAME stream admitted through the
            # batched-prefill fallback must serve identical transcripts
            mono = drive(MonoEngine(cfg, params, scfg), specs, plan)
            assert mono == want, ("monolithic-dense", seed)
        print("OK", arch, mesh_spec, "seed=", seed, "reqs=", len(specs),
              flush=True)

    for s in range(N_STREAMS):
        stream_case("qwen2-7b", "w4a4_lut", "2x2", 100 + s, 4,
                    mono_check=(s == 0))
        stream_case("qwen2-7b", "w4a4_lut", "1x8", 200 + s, None)
    # one MoE stream: expert-sharded banks under random traffic (MoE routing
    # forces the monolithic fallback on its own — both engines must agree)
    stream_case("qwen2-moe-a2.7b", "w4a4_lut", "2x2", 300, None)
    print("ALL-OK")
""")


@pytest.mark.slow
def test_randomized_traffic_differential_subprocess():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _TRAFFIC_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "ALL-OK" in out.stdout, out.stdout


_FORMULATION_SCRIPT = textwrap.dedent("""
    import dataclasses, random
    import jax, numpy as np
    from repro import configs
    from repro.kernels.lutmul import ops as lut_ops
    from repro.models import transformer as T
    from repro.serve import Request, Scheduler, ServeConfig, make_engine

    MAX_LEN, SLOTS, CHUNK = 32, 4, 3

    def make_stream(cfg, seed):
        rng = random.Random(seed)
        reqs = []
        for _ in range(rng.randint(5, 8)):
            L = rng.randint(1, 8)
            prompt = [rng.randrange(cfg.vocab) for _ in range(L)]
            budget = rng.choice([0, 1, 2, 3, 5, 8])
            eos = rng.randrange(cfg.vocab) if rng.random() < 0.3 else None
            reqs.append(dict(prompt=prompt, max_new_tokens=budget,
                             eos_id=eos, temperature=0.0))
        plan = [rng.randint(0, 3) for _ in range(4 * len(reqs))]
        return reqs, plan

    def drive(engine, specs, plan):
        sched = Scheduler(engine, slots=SLOTS, chunk=CHUNK)
        reqs = [Request(**s) for s in specs]
        i, p = 0, 0
        while i < len(reqs) or sched.has_work:
            take = plan[p % len(plan)]; p += 1
            for _ in range(min(take, len(reqs) - i)):
                sched.submit(reqs[i]); i += 1
            if not sched.has_work and i < len(reqs):
                sched.submit(reqs[i]); i += 1
            sched.step()
        assert all(s is None for s in sched.slots) and not sched.queue
        return [(r.tokens, r.finish_reason) for r in reqs]

    def engine_for(quant, backend, force_onehot=False):
        cfg = dataclasses.replace(
            configs.get_config("bitnet-3b", smoke=True, quant=quant),
            compute_dtype="float32")
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        lut_ops.set_backend(backend)
        if force_onehot:
            # pin the auto formulation to one-hot so the SAME w2 codes are
            # stored nibble-packed instead of as bitplanes
            lut_ops._FORMULATION_CACHE.clear()
            real = lut_ops.pick_formulation
            lut_ops.pick_formulation = lambda *a, **k: "onehot"
        try:
            eng = make_engine(params, cfg,
                              ServeConfig(max_len=MAX_LEN, quant=quant))
        finally:
            if force_onehot:
                lut_ops.pick_formulation = real
        return cfg, eng

    # w2: tmac-on-ref IS the decoded dense int oracle; the one-hot leaf
    # stores the identical codes nibble-packed; tmac-on-interpret runs the
    # actual grouped-table kernel.  All three transcripts must match.
    cfg, e_ref = engine_for("w2a4_tmac", "ref")
    specs, plan = make_stream(cfg, 11)
    want = drive(e_ref, specs, plan)
    _, e_oh = engine_for("w2a4", "ref", force_onehot=True)
    assert drive(e_oh, specs, plan) == want, "onehot formulation diverged"
    _, e_int = engine_for("w2a4_tmac", "interpret")
    n0 = lut_ops.WEIGHT_QUANT_COUNT
    assert drive(e_int, specs, plan) == want, "tmac kernel diverged"
    assert lut_ops.WEIGHT_QUANT_COUNT == n0, "decode re-quantized weights"
    print("OK w2a4", flush=True)

    # ternary/a8 (the BitNet serving mode): ref oracle vs interpret kernel
    cfg3, e3r = engine_for("ternary_a8_tmac", "ref")
    specs3, plan3 = make_stream(cfg3, 23)
    want3 = drive(e3r, specs3, plan3)
    _, e3i = engine_for("ternary_a8_tmac", "interpret")
    assert drive(e3i, specs3, plan3) == want3, "ternary kernel diverged"
    lut_ops.set_backend(None)
    print("ALL-OK")
""")


@pytest.mark.slow
def test_formulation_differential_subprocess():
    """Cross-formulation serving differential: at the SAME weight widths,
    temperature-0 transcripts from the tmac bitplane leaves (ref oracle and
    interpret kernel) and from the forced one-hot nibble leaves must be
    token-for-token identical — the stored formulation is a layout choice,
    never a numerics choice."""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("REPRO_KERNEL_BACKEND", None)
    out = subprocess.run([sys.executable, "-c", _FORMULATION_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=1800)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "ALL-OK" in out.stdout, out.stdout


_PAGED_TRAFFIC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, random
    import jax, numpy as np
    from repro import configs
    from repro.launch.mesh import make_serving_mesh
    from repro.models import transformer as T
    from repro.serve import (Engine, Request, Scheduler, ServeConfig,
                             ShardedEngine, make_engine)

    N_STREAMS = max(1, int(os.environ.get("REPRO_FUZZ_EXAMPLES", "8")) // 8)
    MAX_LEN, SLOTS, CHUNK = 32, 4, 3

    class MonoEngine(Engine):
        requires_monolithic_admission = True

    class MonoSharded(ShardedEngine):
        requires_monolithic_admission = True

    def make_stream(cfg, seed):
        # shared-prefix traffic: a small set of base prefixes (page-aligned
        # AND unaligned lengths) that many requests extend — prefix reuse
        # must fire, not just be smoke-tested.  The first four requests
        # share base 0 with budgets long enough to coexist (sharing needs
        # the sharer's pages RESIDENT), the rest is randomized.
        rng = random.Random(seed)
        bases = [[rng.randrange(cfg.vocab) for _ in range(L)]
                 for L in (8, 6, 12)]
        reqs = [dict(prompt=list(bases[0]) + [rng.randrange(cfg.vocab)
                                              for _ in range(i)],
                     max_new_tokens=6 + i, eos_id=None, temperature=0.0)
                for i in range(4)]
        for _ in range(rng.randint(4, 8)):
            if rng.random() < 0.7:
                p = list(rng.choice(bases))
                p += [rng.randrange(cfg.vocab)
                      for _ in range(rng.randint(0, 4))]
            else:
                p = [rng.randrange(cfg.vocab)
                     for _ in range(rng.randint(1, 10))]
            budget = rng.choice([0, 1, 2, 3, 5, 8, 12])
            eos = rng.randrange(cfg.vocab) if rng.random() < 0.3 else None
            reqs.append(dict(prompt=p, max_new_tokens=budget, eos_id=eos,
                             temperature=0.0))
        plan = [4] + [rng.randint(0, 3) for _ in range(4 * len(reqs))]
        return reqs, plan

    def drive(engine, specs, plan):
        sched = Scheduler(engine, slots=SLOTS, chunk=CHUNK)
        reqs = [Request(**s) for s in specs]
        i, p = 0, 0
        while i < len(reqs) or sched.has_work:
            take = plan[p % len(plan)]; p += 1
            for _ in range(min(take, len(reqs) - i)):
                sched.submit(reqs[i]); i += 1
            if not sched.has_work and i < len(reqs):
                sched.submit(reqs[i]); i += 1
            sched.step()
        assert all(s is None for s in sched.slots) and not sched.queue
        if getattr(engine, "paged", False):
            # zero-leak invariant: every retire path (EOS, budget, budget-0
            # admission, preemption, queued victims) must return its pages
            assert engine.pool.allocated_pages == 0, \\
                ("drained pool still holds pages", engine.pool.allocated_pages)
            assert not engine.pool.leaked_pages(), engine.pool.leaked_pages()
        return sched, [(r.tokens, r.finish_reason) for r in reqs]

    hits = preempts = 0
    for s in range(N_STREAMS):
        for mesh_spec, prefill_chunk, pages in (("2x2", 4, 0),
                                                ("1x8", None, 0),
                                                ("2x2", 4, 7)):
            # pages=7 (vs the 33-page worst case): chunked admission maps
            # pages exactly (no bucket inflation), so the pool must be this
            # tight before the coexisting shared-base requests exhaust it —
            # eviction is fuzzed alongside prefix reuse
            cfg = dataclasses.replace(
                configs.get_config("qwen2-7b", smoke=True, quant="w4a4_lut"),
                compute_dtype="float32")
            params = T.init_params(jax.random.PRNGKey(0), cfg)
            specs, plan = make_stream(cfg, 1000 + s)
            dense = ServeConfig(max_len=MAX_LEN, quant="w4a4_lut",
                                prefill_chunk=prefill_chunk)
            _, want = drive(make_engine(params, cfg, dense), specs, plan)
            paged = dataclasses.replace(dense, paged=True, page_size=4,
                                        num_pages=pages)
            peng = make_engine(params, cfg, paged)
            _, got = drive(peng, specs, plan)
            assert got == want, ("paged-1dev", mesh_spec, s)
            hits += peng.pool.prefix_hits
            preempts += peng.pool.preemptions
            if pages == 0:      # sharded pool sizes must divide the mesh
                seng = make_engine(params, cfg, paged,
                                   mesh=make_serving_mesh(mesh_spec))
                _, got_s = drive(seng, specs, plan)
                assert got_s == want, ("paged-sharded", mesh_spec, s)
                hits += seng.pool.prefix_hits
            if s == 0 and pages == 0 and mesh_spec == "2x2":
                # chunked-vs-monolithic: the batched-prefill fallback must
                # serve the same stream bit-identically — paged single
                # device AND paged sharded
                _, mono = drive(MonoEngine(cfg, params, paged), specs, plan)
                assert mono == want, ("monolithic-paged", s)
                meng = MonoSharded(cfg, params, paged,
                                   mesh=make_serving_mesh(mesh_spec))
                _, mono_s = drive(meng, specs, plan)
                assert mono_s == want, ("monolithic-paged-sharded", s)
            print("OK", mesh_spec, "chunk=", prefill_chunk, "pages=", pages,
                  flush=True)
    assert hits > 0, "prefix reuse never fired across the fuzz streams"
    assert preempts > 0, "the contended pool never forced a preemption"
    print("ALL-OK hits=", hits, "preempts=", preempts)
""")


@pytest.mark.slow
def test_paged_traffic_differential_subprocess():
    """Shared-prefix request streams through the dense Engine, the paged
    Engine, and the paged ShardedEngine (2x2 / 1x8): transcripts must match
    token for token at temperature 0 while prefix reuse AND pool-exhaustion
    preemption actually fire (asserted, not just smoke-tested)."""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _PAGED_TRAFFIC_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=1800)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "ALL-OK" in out.stdout, out.stdout
