"""Minimal hypothesis shim for environments without the real package.

Provides just the API surface this repo's tests use — ``given``/``settings``
and the ``integers``/``floats``/``lists``/``sampled_from``/``booleans``/
``just``/``tuples`` strategies (+ ``.map``/``.filter``) — executing each
property test over a fixed number of deterministically-seeded samples.
Registered from ``conftest.py`` into ``sys.modules`` only when the real
hypothesis is absent, so installing it transparently upgrades the tests.
"""
from __future__ import annotations

import random
import types


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._draw(rng)))

    def filter(self, pred):
        def draw(rng):
            for _ in range(1000):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise RuntimeError("filter predicate too restrictive")
        return _Strategy(draw)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float, **_: object) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10,
          **_: object) -> _Strategy:
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elements.draw(rng) for _ in range(n)]
    return _Strategy(draw)


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


def just(value) -> _Strategy:
    return _Strategy(lambda rng: value)


def tuples(*strategies: _Strategy) -> _Strategy:
    return _Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))


def settings(max_examples: int = 100, **_: object):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*arg_strategies: _Strategy, **kw_strategies: _Strategy):
    def deco(fn):
        def wrapper():
            # read the settings() cap at CALL time from the wrapper first:
            # @settings stacked ABOVE @given tags the wrapper, below it tags
            # fn — both orders must work like real hypothesis
            n = min(getattr(wrapper, "_stub_max_examples",
                            getattr(fn, "_stub_max_examples", 100)), 25)
            rng = random.Random(0)
            for _ in range(n):
                drawn_args = tuple(s.draw(rng) for s in arg_strategies)
                drawn_kw = {k: s.draw(rng) for k, s in kw_strategies.items()}
                fn(*drawn_args, **drawn_kw)
        # deliberately NOT functools.wraps: pytest must see a zero-arg
        # signature, or it treats the property params as fixtures
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco


def install(sys_modules: dict) -> None:
    mod = types.ModuleType("hypothesis")
    strat = types.ModuleType("hypothesis.strategies")
    strat.integers, strat.floats, strat.lists = integers, floats, lists
    strat.sampled_from, strat.booleans = sampled_from, booleans
    strat.just, strat.tuples = just, tuples
    mod.given, mod.settings, mod.strategies = given, settings, strat
    sys_modules["hypothesis"] = mod
    sys_modules["hypothesis.strategies"] = strat
