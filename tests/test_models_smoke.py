"""Per-arch smoke tests (deliverable f): reduced same-family config, one
forward + one train step on CPU, asserting shapes and finiteness; plus
prefill/decode agreement — the serving-correctness invariant."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import encdec, mobilenet, transformer as T
from repro.train.step import TrainConfig, init_state, make_train_step

LM_ARCHS = ["rwkv6-1.6b", "zamba2-2.7b", "gemma2-2b", "phi3-medium-14b",
            "qwen2-7b", "minicpm-2b", "qwen2-moe-a2.7b", "mixtral-8x22b",
            "qwen2-vl-72b"]


def _batch(cfg, B=2, S=16, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 4)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab),
             "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["embeddings"] = jax.random.normal(ks[2], (B, S, cfg.d_model))
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, :, None], (B, S, 3)).astype(jnp.int32)
    return batch


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = configs.get_config(arch, smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, _ = T.forward(params, cfg, batch["tokens"],
                          embeddings=batch.get("embeddings"),
                          mrope_positions=batch.get("mrope_positions"))
    assert logits.shape == (2, 16, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    step = jax.jit(make_train_step(cfg, TrainConfig(total_steps=10)))
    state = init_state(params)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_prefill_decode_agreement(arch):
    """decode(token S | prefill(tokens[:S])) == forward(tokens[:S+1])[:, S]."""
    cfg = configs.get_config(arch, smoke=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab)
    if cfg.family == "vlm":
        pytest.skip("vlm decode consumes text tokens after embedded prefix; "
                    "covered by engine test")
    full, _ = T.forward(params, cfg, toks)
    pl_logits, cache = T.prefill(params, cfg, toks[:, :S])
    np.testing.assert_allclose(np.asarray(pl_logits),
                               np.asarray(full[:, S - 1], np.float32),
                               rtol=5e-4, atol=5e-4)
    # grow attn caches by a slot so decode can append
    from repro.serve.engine import Engine, ServeConfig
    eng = Engine(cfg, params, ServeConfig(max_len=S + 4))
    grown = eng._grow_cache(cache, S)
    logits2, _ = T.decode_step(params, cfg, toks[:, S], grown, jnp.int32(S))
    np.testing.assert_allclose(np.asarray(logits2),
                               np.asarray(full[:, S], np.float32),
                               rtol=5e-4, atol=5e-4)


def test_smoke_whisper():
    cfg = configs.get_config("whisper-large-v3", smoke=True)
    p = encdec.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 10
    frames = jax.random.normal(jax.random.PRNGKey(1), (B, cfg.enc_seq,
                                                       cfg.d_model))
    batch = {"frames": frames,
             "tokens": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                          cfg.vocab),
             "labels": jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                                          cfg.vocab)}
    logits = encdec.forward(p, cfg, frames, batch["tokens"])
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    step = jax.jit(make_train_step(cfg, TrainConfig(total_steps=10)))
    state = init_state(p)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))


def test_smoke_whisper_prefill_decode():
    cfg = configs.get_config("whisper-large-v3", smoke=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    p = encdec.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 8
    frames = jax.random.normal(jax.random.PRNGKey(1),
                               (B, cfg.enc_seq, cfg.d_model))
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 0, cfg.vocab)
    full = encdec.forward(p, cfg, frames, toks)
    pl, cache = encdec.prefill(p, cfg, frames, toks[:, :S])
    np.testing.assert_allclose(np.asarray(pl),
                               np.asarray(full[:, S - 1], np.float32),
                               rtol=5e-4, atol=5e-4)
    from repro.serve.engine import Engine, ServeConfig
    eng = Engine(cfg, p, ServeConfig(max_len=S + 4))
    grown = eng._grow_cache(cache, S)
    logits2, _ = encdec.decode_step(p, cfg, toks[:, S], grown, jnp.int32(S))
    np.testing.assert_allclose(np.asarray(logits2),
                               np.asarray(full[:, S], np.float32),
                               rtol=5e-4, atol=5e-4)


def test_smoke_mobilenet_qat():
    cfg = configs.get_config("mobilenetv2", smoke=True)
    p = mobilenet.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    logits = mobilenet.forward(p, cfg, x)
    assert logits.shape == (2, 10)
    assert np.isfinite(np.asarray(logits)).all()
    # one QAT train step
    step = jax.jit(make_train_step(cfg, TrainConfig(total_steps=10,
                                                    qat_project=True)))
    state = init_state(p)
    batch = {"images": x,
             "labels": jnp.asarray([1, 2], jnp.int32)}
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))


@pytest.mark.parametrize("quant", ["w8a8", "w4a4_mxu", "w4a4_lut"])
def test_smoke_quantized_serving_path(quant):
    """The paper's technique as a first-class serving feature."""
    cfg = configs.get_config("qwen2-7b", smoke=True, quant=quant)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    logits, cache = T.prefill(params, cfg, toks)
    assert np.isfinite(np.asarray(logits)).all()


def test_unroll_groups_matches_scan():
    cfg = configs.get_config("gemma2-2b", smoke=True)
    cfg32 = dataclasses.replace(cfg, compute_dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    a, _ = T.forward(params, cfg32, toks)
    cfg_unroll = dataclasses.replace(cfg32, unroll_groups=True)
    b, _ = T.forward(params, cfg_unroll, toks)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)
