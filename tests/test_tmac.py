"""Unit tests for the T-MAC sub-4-bit serving family: the mode grammar,
bit-width validation, the plane quantizer's consistency guarantees, the
formulation/variant pickers, and the roofline mixed-bits planner.

Bit-exactness of the kernels themselves is fuzzed in test_lutmul_fuzz.py;
the end-to-end serving differential lives in test_traffic_fuzz.py.  This
file pins the API contracts around them.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lut import (decode_planes, pack_bitplanes, plane_decomposition,
                            planes_from_codes, unpack_bitplanes,
                            validate_weight_bits)
from repro.kernels.lutmul import ops as lut_ops
from repro.serve.quantize import dequantize_weight, quantize_leaf_mode


# ---------------------------------------------------------------------------
# mode grammar
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode,want", [
    ("w4a4_mxu", ("int", 4, 4)),
    ("", ("int", 4, 4)),
    ("w8a8", ("int", 8, 8)),
    ("w4a4_lut", ("onehot", 4, 4)),
    ("w2a4_tmac", ("tmac", 2, 4)),
    ("w1a8_tmac", ("tmac", 1, 8)),
    ("w3a4_tmac", ("tmac", 3, 4)),
    ("ternary_a8_tmac", ("tmac", "ternary", 8)),
    ("ternary_a4", ("auto", "ternary", 4)),
    ("w2a4", ("auto", 2, 4)),
])
def test_parse_mode(mode, want):
    assert lut_ops.parse_mode(mode) == want


@pytest.mark.parametrize("bad", ["w5a4_tmac", "w2a2_tmac", "w2a16",
                                 "tmac", "w2", "ternary", "w2a4_foo"])
def test_parse_mode_rejects_with_grammar(bad):
    # bad widths are caught by validate_weight_bits (names the family),
    # bad grammar by parse_mode (names the grammar) — both actionable
    with pytest.raises(ValueError, match="mode|bit width"):
        lut_ops.parse_mode(bad)


def test_validate_weight_bits_actionable():
    with pytest.raises(ValueError, match="ternary"):
        validate_weight_bits(1.58)          # must use the string spec
    with pytest.raises(ValueError, match="weight"):
        validate_weight_bits(5)


# ---------------------------------------------------------------------------
# shape validation errors are actionable
# ---------------------------------------------------------------------------

def test_check_lut_shapes_errors():
    a = jnp.zeros((4, 6), jnp.uint8)
    with pytest.raises(ValueError, match="even K"):
        lut_ops._check_lut_shapes(jnp.zeros((4, 7), jnp.uint8),
                                  jnp.zeros((3, 8), jnp.uint8))
    with pytest.raises(ValueError, match="K//2"):
        lut_ops._check_lut_shapes(a, jnp.zeros((2, 8), jnp.uint8))
    with pytest.raises(ValueError, match="bitplane"):
        # a 3D tmac leaf fed to the one-hot path: the hint names the fix
        lut_ops._check_lut_shapes(a, jnp.zeros((2, 3, 8), jnp.uint8))


def test_check_tmac_shapes_errors():
    a = jnp.zeros((4, 16), jnp.int8)
    planes2 = jnp.zeros((2, 2, 8), jnp.uint8)
    with pytest.raises(ValueError, match="plane"):
        lut_ops._check_tmac_shapes(a, planes2, 3)      # w3 needs 3 planes
    with pytest.raises(ValueError, match="K"):
        lut_ops._check_tmac_shapes(jnp.zeros((4, 24), jnp.int8), planes2, 2)
    with pytest.raises(ValueError, match=r"\[P, K//8, N\]"):
        lut_ops._check_tmac_shapes(a, jnp.zeros((2, 8), jnp.uint8), 2)


# ---------------------------------------------------------------------------
# quantizers: cross-format consistency
# ---------------------------------------------------------------------------

def test_w4_planes_decode_to_w4_codes():
    """The w4 plane quantizer and the nibble quantizer are THE SAME
    quantizer — the basis of cross-formulation bit-identity."""
    rng = np.random.default_rng(0)
    wf = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
    planes, s_p = lut_ops.quantize_weights_planes(wf, 4)
    q, s_n = lut_ops.quantize_weights(wf, 4, pack=False)
    np.testing.assert_array_equal(
        np.asarray(decode_planes(unpack_bitplanes(planes), 4)), np.asarray(q))
    np.testing.assert_array_equal(np.asarray(s_p), np.asarray(s_n))


@pytest.mark.parametrize("spec", [1, "ternary", 2, 3, 4])
def test_planes_roundtrip_and_ranges(spec):
    rng = np.random.default_rng(1)
    wf = jnp.asarray(rng.normal(size=(24, 8)), jnp.float32)
    planes, scale = lut_ops.quantize_weights_planes(wf, spec)
    n_planes, _, _ = plane_decomposition(spec)
    assert planes.shape == (n_planes, 24 // 8, 8)
    assert scale.shape == (1, 8)
    dec = np.asarray(decode_planes(unpack_bitplanes(planes), spec))
    if spec == "ternary":
        assert set(np.unique(dec)) <= {-1, 0, 1}
    elif spec == 1:
        assert set(np.unique(dec)) <= {-1, 1}
    else:
        lo, hi = -(2 ** (spec - 1)), 2 ** (spec - 1) - 1
        assert dec.min() >= lo and dec.max() <= hi
    # pack/unpack round-trips through the plane stack too
    codes = planes_from_codes(jnp.asarray(dec), spec)
    np.testing.assert_array_equal(np.asarray(pack_bitplanes(codes)),
                                  np.asarray(planes))


def test_quantize_weights_rejects_sub4():
    with pytest.raises(ValueError, match="quantize_weights_planes"):
        lut_ops.quantize_weights(jnp.zeros((8, 8)), 2)
    with pytest.raises(ValueError, match="a4 or a8"):
        lut_ops.quantize_activations(jnp.zeros((2, 8)), 2)


def test_stacked_leaf_quantizes_per_slice():
    """Leading stack dims (the scanned block axis) pass through and each
    slice quantizes independently — identical to slicing first."""
    rng = np.random.default_rng(2)
    wf = jnp.asarray(rng.normal(size=(3, 16, 8)), jnp.float32)
    planes, scale = lut_ops.quantize_weights_planes(wf, "ternary")
    assert planes.shape == (3, 2, 2, 8) and scale.shape == (3, 1, 8)
    p0, s0 = lut_ops.quantize_weights_planes(wf[1], "ternary")
    np.testing.assert_array_equal(np.asarray(planes[1]), np.asarray(p0))
    np.testing.assert_array_equal(np.asarray(scale[1]), np.asarray(s0))


@pytest.mark.parametrize("mode,keys", [
    ("w2a4_tmac", {"w_q", "w_scale", "w_tmac"}),
    ("ternary_a8_tmac", {"w_q", "w_scale", "w_tmac", "w_tern"}),
    ("w8a8", {"w_q", "w_scale"}),
])
def test_quantize_leaf_mode_formats(mode, keys):
    rng = np.random.default_rng(3)
    wf = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
    leaf = quantize_leaf_mode(wf, mode)
    assert set(leaf.keys()) == keys
    if "w_tmac" in leaf:
        assert leaf["w_tmac"].shape == (0,)     # zero-size static marker
        # dequantize round-trips through the plane decode
        deq = dequantize_weight(leaf, jnp.float32)
        _, wspec, _ = lut_ops.parse_mode(mode)
        dense = decode_planes(unpack_bitplanes(leaf["w_q"]), wspec)
        np.testing.assert_array_equal(
            np.asarray(deq),
            np.asarray(dense.astype(jnp.float32) * leaf["w_scale"]))


# ---------------------------------------------------------------------------
# pickers
# ---------------------------------------------------------------------------

def test_pick_formulation_defaults():
    lut_ops._FORMULATION_CACHE.clear()
    lut_ops.set_autotune(False)
    try:
        assert lut_ops.pick_formulation(2, 4, 256, 256, "ref") == "tmac"
        assert lut_ops.pick_formulation("ternary", 4, 256, 256,
                                        "ref") == "tmac"
        assert lut_ops.pick_formulation(4, 4, 256, 256, "ref") == "onehot"
        # a8 activations never fit the 4-bit one-hot product table
        assert lut_ops.pick_formulation(4, 8, 256, 256, "ref") == "tmac"
    finally:
        lut_ops.set_autotune(None)
        lut_ops._FORMULATION_CACHE.clear()


def test_pick_variant_defaults_and_ab():
    lut_ops._VARIANT_CACHE.clear()
    lut_ops.set_autotune(False)
    try:
        assert lut_ops.pick_variant("lutmul", 8, 64, 64,
                                    "interpret") == "unfused"
        assert lut_ops.pick_variant("lutmul", 8, 64, 64,
                                    "pallas") == "fused"
    finally:
        lut_ops.set_autotune(None)
    lut_ops._VARIANT_CACHE.clear()
    lut_ops.set_autotune(True)
    try:
        import time
        got = lut_ops.pick_variant(
            "lutmul", 9, 64, 64, "interpret",
            bench_fns={"fused": lambda: time.sleep(0.002),
                       "unfused": lambda: None})
        assert got == "unfused"
        # cached: a second call returns the winner without bench_fns
        assert lut_ops.pick_variant("lutmul", 9, 64, 64,
                                    "interpret") == "unfused"
    finally:
        lut_ops.set_autotune(None)
        lut_ops._VARIANT_CACHE.clear()


# ---------------------------------------------------------------------------
# mixed-bits planner
# ---------------------------------------------------------------------------

def _smoke_params():
    from repro import configs
    from repro.models import transformer as T
    cfg = dataclasses.replace(
        configs.get_config("bitnet-3b", smoke=True), compute_dtype="float32")
    return cfg, T.init_params(jax.random.PRNGKey(0), cfg)


def _eff_bits(mode: str) -> float:
    return 1.58 if mode.startswith("ternary") else float(mode[1])


def test_plan_mixed_bits_hits_target_and_floors():
    from repro.roofline.analysis import plan_mixed_bits
    cfg, params = _smoke_params()
    plan = plan_mixed_bits(params, target_bits=2.0, abits=4)
    assert plan, "planner found no eligible leaves"
    # every value is a valid tmac mode string; attention floored at 2 bits
    for path, mode in plan.items():
        assert lut_ops.parse_mode(mode)[0] == "tmac"
        if "['attn']" in path:
            assert _eff_bits(mode) >= 2.0
    # parameter-weighted average reaches the target
    sizes = {}

    def walk(tree, path=""):
        if isinstance(tree, dict):
            for k, v in tree.items():
                sub = f"{path}['{k}']"
                if isinstance(v, dict) and "w" in v and (sub + "['w']") \
                        in plan:
                    sizes[sub + "['w']"] = int(np.prod(v["w"].shape))
                else:
                    walk(v, sub)
        elif isinstance(tree, (tuple, list)):
            for i, v in enumerate(tree):
                walk(v, f"{path}[{i}]")

    walk(params)
    assert set(sizes) == set(plan)
    avg = sum(sizes[p] * _eff_bits(m) for p, m in plan.items()) \
        / sum(sizes.values())
    assert avg <= 2.0 + 1e-9
    # identity at target 4
    assert set(plan_mixed_bits(params, 4.0).values()) == {"w4a4_tmac"}


def test_plan_keys_match_serving_walk():
    """The planner's path strings are consumable as a serving bits_plan:
    every planned leaf comes out in the planned format."""
    from repro.roofline.analysis import plan_mixed_bits
    from repro.serve.quantize import quantize_params_for_serving
    cfg, params = _smoke_params()
    plan = plan_mixed_bits(params, target_bits=2.0, abits=4)
    qp = quantize_params_for_serving(params, mode="w4a4_mxu", bits_plan=plan)
    blk = qp["blocks"][0]
    for sub in (blk["attn"]["wq"], blk["mlp"]["wi"]):
        assert "w_tmac" in sub and sub["w_q"].shape[-3] == 2   # w2 planes
    # off-plan leaves follow the base mode (packed nibbles, no marker)
    assert "w_tmac" not in qp["lm_head"]
