"""Continuous-batching scheduler: equivalence with static batching, EOS
slot-freeing, per-sequence-position ring addressing, scanned-decode
bit-exactness, no-retrace static shapes, and top-k/top-p sampling."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import attention as A
from repro.models import transformer as T
from repro.serve import Engine, Request, Scheduler, ServeConfig, sample_logits


def _engine(arch="qwen2-7b", max_len=32, **scfg):
    cfg = dataclasses.replace(configs.get_config(arch, smoke=True),
                              compute_dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params, Engine(cfg, params, ServeConfig(max_len=max_len,
                                                        **scfg))


# ---------------------------------------------------------------------------
# scheduler == static batching (temperature 0)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,S", [("qwen2-7b", 6), ("gemma2-2b", 4),
                                    ("gemma2-2b", 12)])
def test_staggered_continuous_matches_static(arch, S):
    """Continuous batching with staggered admission emits the same tokens
    per request as one-shot static batching — including through gemma's
    SWA ring caches for prompts shorter AND longer than the window."""
    cfg, params, eng = _engine(arch)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, S), 0, cfg.vocab)
    want = eng.generate(prompts, max_new_tokens=5)[:, S:]
    sched = Scheduler(eng, slots=2, chunk=2)
    reqs = [Request(prompt=np.asarray(prompts[i]).tolist(), max_new_tokens=5)
            for i in range(4)]
    sched.submit(reqs[0])
    sched.submit(reqs[1])
    sched.step()                     # first two requests mid-flight...
    sched.submit(reqs[2])            # ...then more arrive
    sched.submit(reqs[3])
    while sched.has_work:
        sched.step()
    for i, r in enumerate(reqs):
        assert r.tokens == np.asarray(want[i]).tolist(), i
        assert r.done and r.finish_reason == "length"


def test_chunked_prefill_matches_static():
    """Chunked admission (prompts split across rounds at prefill_chunk
    granularity) must not change any emitted token, and under backlog the
    chunk lane carries no pad entries (padding waste exactly 1.0)."""
    cfg, params, eng = _engine(prefill_chunk=4)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab)
    want = eng.generate(prompts, max_new_tokens=5)[:, 6:]
    sched = Scheduler(eng, slots=2, chunk=4)
    reqs = [Request(prompt=np.asarray(prompts[i]).tolist(), max_new_tokens=5)
            for i in range(2)]
    sched.run(reqs)
    for i, r in enumerate(reqs):
        assert r.tokens == np.asarray(want[i]).tolist()
    assert sched.padding_waste == 1.0


def test_prompt_bucket_kwarg_is_deprecated_and_ignored():
    """The pre-chunking admission knob warns and changes nothing."""
    cfg, params, eng = _engine()
    want = np.asarray(eng.generate(jnp.asarray([[1, 2, 3, 4]]), 3)[:, 4:])
    req = Request(prompt=[1, 2, 3, 4], max_new_tokens=3)
    with pytest.warns(DeprecationWarning, match="prefill_chunk"):
        sched = Scheduler(eng, slots=1, chunk=2, prompt_bucket="pow2")
    sched.run([req])
    assert req.tokens == want[0].tolist()


# ---------------------------------------------------------------------------
# EOS early-exit frees the slot
# ---------------------------------------------------------------------------

def test_eos_early_exit_frees_slot():
    cfg, params, eng = _engine()
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab)
    want = np.asarray(eng.generate(prompts, max_new_tokens=6)[:, 6:])
    eos = int(want[0, 2])            # req0's greedy stream hits this early
    hit = int(np.argmax(want[0] == eos))       # first occurrence
    sched = Scheduler(eng, slots=1, chunk=2)
    r0 = Request(prompt=np.asarray(prompts[0]).tolist(), max_new_tokens=6,
                 eos_id=eos)
    r1 = Request(prompt=np.asarray(prompts[1]).tolist(), max_new_tokens=6)
    sched.run([r0, r1])
    # r0 stopped at (and including) the first EOS token, under budget
    assert r0.finish_reason == "eos"
    assert r0.tokens == want[0, :hit + 1].tolist() and r0.tokens[-1] == eos
    # the freed slot served r1, whose stream matches static batching
    assert r1.finish_reason == "length"
    assert r1.tokens == want[1].tolist()
    assert all(s is None for s in sched.slots) and not sched.queue


# ---------------------------------------------------------------------------
# per-sequence positions
# ---------------------------------------------------------------------------

def test_decode_attention_per_sequence_ring_positions():
    """SWA ring addressing with a [B] position vector must match per-row
    scalar-position calls (each sequence at its own depth)."""
    B, W, H, D = 3, 8, 2, 16
    key = jax.random.PRNGKey(0)
    p = A.init_attention(key, H * D, H, H, D, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 1, H * D), jnp.float32)
    ck = jax.random.normal(jax.random.PRNGKey(2), (B, W, H, D), jnp.float32)
    cv = jax.random.normal(jax.random.PRNGKey(3), (B, W, H, D), jnp.float32)
    pos = jnp.asarray([3, 7, 12], jnp.int32)
    y, nk, nv = A.decode_attention(p, x, ck, cv, pos, n_heads=H, n_kv=H,
                                   head_dim=D, window=W, rolling=True,
                                   compute_dtype=jnp.float32)
    for b in range(B):
        yb, nkb, nvb = A.decode_attention(
            p, x[b:b + 1], ck[b:b + 1], cv[b:b + 1], jnp.int32(int(pos[b])),
            n_heads=H, n_kv=H, head_dim=D, window=W, rolling=True,
            compute_dtype=jnp.float32)
        np.testing.assert_array_equal(np.asarray(y[b:b + 1]), np.asarray(yb))
        np.testing.assert_array_equal(np.asarray(nk[b:b + 1]), np.asarray(nkb))
        np.testing.assert_array_equal(np.asarray(nv[b:b + 1]), np.asarray(nvb))


def test_negative_position_is_free_slot_sentinel():
    """A negative per-sequence position masks every key of that row and
    writes only inside its own row — active neighbours are untouched."""
    B, Tlen, H, D = 2, 6, 2, 8
    p = A.init_attention(jax.random.PRNGKey(0), H * D, H, H, D,
                         dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 1, H * D), jnp.float32)
    ck = jax.random.normal(jax.random.PRNGKey(2), (B, Tlen, H, D), jnp.float32)
    cv = jax.random.normal(jax.random.PRNGKey(3), (B, Tlen, H, D), jnp.float32)
    pos = jnp.asarray([2, -1], jnp.int32)      # row 1 is a free slot
    y, nk, nv = A.decode_attention(p, x, ck, cv, pos, n_heads=H, n_kv=H,
                                   head_dim=D, compute_dtype=jnp.float32)
    y0, nk0, nv0 = A.decode_attention(p, x[:1], ck[:1], cv[:1], jnp.int32(2),
                                      n_heads=H, n_kv=H, head_dim=D,
                                      compute_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(y[:1]), np.asarray(y0))
    np.testing.assert_array_equal(np.asarray(nk[:1]), np.asarray(nk0))
    assert np.isfinite(np.asarray(y)).all()    # free row: garbage but finite


# ---------------------------------------------------------------------------
# scanned decode == python-loop decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_scanned_decode_matches_python_loop(temperature):
    cfg, params, eng = _engine(temperature=temperature)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0, cfg.vocab)
    a = eng.generate(prompts, max_new_tokens=6, use_scan=True)
    b = eng.generate(prompts, max_new_tokens=6, use_scan=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# static shapes: no retrace after warmup
# ---------------------------------------------------------------------------

def test_no_retrace_across_staggered_admissions():
    """After warmup (one chunk-carrying round + one pure-decode round) no
    new traces appear for any later prompt length or admission pattern —
    the unified step's shapes are fully static."""
    cfg, params, eng = _engine(max_len=48, prefill_chunk=4)
    sched = Scheduler(eng, slots=2, chunk=2)
    sched.submit(Request(prompt=[1, 2, 3, 4, 5, 6], max_new_tokens=6))
    while sched.has_work:
        sched.step()                 # warmup: chunked admission + decode
    C = eng.prefill_chunk
    assert set(eng._step_fns) == {(C, 2, True, False), (0, 2, True, False)}
    sizes = {k: fn._cache_size() for k, fn in eng._step_fns.items()}
    assert all(v == 1 for v in sizes.values())
    for p in ([7, 7, 7], [5, 4, 3, 2, 1], [1, 2, 3, 4, 5, 6, 7, 8]):
        sched.submit(Request(prompt=p, max_new_tokens=5))
    while sched.has_work:
        sched.step()
    assert {k: fn._cache_size() for k, fn in eng._step_fns.items()} == sizes


# ---------------------------------------------------------------------------
# sampling: top-k / top-p
# ---------------------------------------------------------------------------

def test_sample_logits_temperature_zero_is_argmax():
    logits = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
    got = sample_logits(logits, jax.random.PRNGKey(1), 0.0, 0, 1.0)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_sample_logits_topk1_and_tiny_topp_are_greedy():
    logits = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
    greedy = np.asarray(jnp.argmax(logits, -1))
    for key in range(3):
        k1 = sample_logits(logits, jax.random.PRNGKey(key), 1.0, 1, 1.0)
        np.testing.assert_array_equal(np.asarray(k1), greedy)
        p0 = sample_logits(logits, jax.random.PRNGKey(key), 1.0, 0, 1e-6)
        np.testing.assert_array_equal(np.asarray(p0), greedy)


def test_sample_logits_topk_support():
    """Sampled tokens always come from the k highest logits."""
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 32))
    top5 = np.asarray(jnp.argsort(-logits, axis=-1)[:, :5])
    for key in range(8):
        got = np.asarray(sample_logits(logits, jax.random.PRNGKey(key),
                                       1.5, 5, 1.0))
        for b in range(2):
            assert got[b] in top5[b]


def test_sample_logits_per_row_mix():
    """Per-slot sampling params: greedy rows stay exact argmax while
    sampled rows draw from their own filtered distribution."""
    logits = jax.random.normal(jax.random.PRNGKey(0), (3, 32))
    temp = jnp.asarray([0.0, 1.0, 0.0])
    got = np.asarray(sample_logits(logits, jax.random.PRNGKey(7), temp,
                                   jnp.asarray([0, 1, 0]), 1.0))
    greedy = np.asarray(jnp.argmax(logits, -1))
    np.testing.assert_array_equal(got, greedy)  # row 1 top_k=1 -> also argmax


def test_scheduler_per_request_sampling_flags():
    """A temperature>0 top-k request runs alongside greedy requests; its
    tokens stay inside the model's top-k support at every step."""
    cfg, params, eng = _engine(max_len=32)
    g_req = Request(prompt=[1, 2, 3, 4], max_new_tokens=4)
    s_req = Request(prompt=[5, 6, 7, 8], max_new_tokens=4, temperature=1.0,
                    top_k=3)
    sched = Scheduler(eng, slots=2, chunk=2)
    sched.run([g_req, s_req])
    want = np.asarray(eng.generate(jnp.asarray([[1, 2, 3, 4]]), 4)[:, 4:])
    assert g_req.tokens == want[0].tolist()      # greedy row unaffected
    assert len(s_req.tokens) == 4


def test_recurrent_state_mixed_length_admission_matches_static():
    """SSM/RWKV recurrent states are not pad-invariant: mixed-length
    requests must still decode exactly as their own static runs (the
    scheduler admits them unpadded, in equal-length groups)."""
    cfg = configs.get_config("rwkv6-1.6b", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, ServeConfig(max_len=24))
    assert eng.has_recurrent_state
    p5 = jax.random.randint(jax.random.PRNGKey(1), (1, 5), 0, cfg.vocab)
    p7 = jax.random.randint(jax.random.PRNGKey(2), (1, 7), 0, cfg.vocab)
    want5 = np.asarray(eng.generate(p5, max_new_tokens=4)[:, 5:])
    want7 = np.asarray(eng.generate(p7, max_new_tokens=4)[:, 7:])
    assert eng.requires_monolithic_admission  # chunking can't rebuild state
    sched = Scheduler(eng, slots=2, chunk=2)
    r5 = Request(prompt=np.asarray(p5[0]).tolist(), max_new_tokens=4)
    r7 = Request(prompt=np.asarray(p7[0]).tolist(), max_new_tokens=4)
    sched.run([r5, r7])
    assert r5.tokens == want5[0].tolist()
    assert r7.tokens == want7[0].tolist()


def test_long_prompt_admits_over_many_rounds():
    """A prompt much longer than prefill_chunk admits across several rounds
    and still matches its static run exactly."""
    cfg, params, eng = _engine(max_len=48, prefill_chunk=4)
    prompt = list(range(1, 34))                # len 33 -> 9 chunk rounds
    want = np.asarray(eng.generate(jnp.asarray([prompt]), 6)[:, 33:])
    req = Request(prompt=prompt, max_new_tokens=6)
    sched = Scheduler(eng, slots=2, chunk=3)
    sched.run([req])
    assert req.tokens == want[0].tolist()
    assert sched.stats["admission_rounds"] >= 9


def test_freed_slot_restores_greedy_fast_path():
    """A finished sampling request must not leave its slot's sampling
    mirrors behind — later all-greedy rounds take the argmax-only decode
    variant again."""
    cfg, params, eng = _engine(max_len=32)
    sched = Scheduler(eng, slots=2, chunk=2)
    sched.run([Request(prompt=[1, 2, 3, 4], max_new_tokens=3,
                       temperature=0.9, top_k=4)])
    assert all(t <= 0.0 and k == 0 and p >= 1.0 for t, k, p in
               zip(sched._temp_h, sched._topk_h, sched._topp_h))
    want = np.asarray(eng.generate(jnp.asarray([[5, 6, 7, 8]]), 4)[:, 4:])
    req = Request(prompt=[5, 6, 7, 8], max_new_tokens=4)
    sched.run([req])
    assert req.tokens == want[0].tolist()


# ---------------------------------------------------------------------------
# degenerate requests must not pin their slot
# ---------------------------------------------------------------------------

def test_prompt_ending_in_eos_frees_slot():
    """A prompt that already ends in the EOS token decodes normally (the
    trailing EOS is prompt context, not an emission) and its slot frees on
    retirement — it must not wedge the pool."""
    cfg, params, eng = _engine()
    eos = 7
    sched = Scheduler(eng, slots=1, chunk=2)
    r0 = Request(prompt=[1, 2, 3, eos], max_new_tokens=3, eos_id=eos)
    r1 = Request(prompt=[4, 5, 6, 8], max_new_tokens=3)
    done = sched.run([r0, r1], max_rounds=16)
    assert len(done) == 2 and r0.done and r1.done
    assert 1 <= len(r0.tokens) <= 3
    if r0.finish_reason == "eos":
        assert r0.tokens[-1] == eos
    else:
        assert r0.finish_reason == "length" and len(r0.tokens) == 3
    assert all(s is None for s in sched.slots) and not sched.queue


def test_budget_zero_request_finishes_at_admission():
    """budget=0 finishes at admission without emitting and without ever
    occupying the slot — the next queued request runs immediately (before
    this fix the slot stayed RUNNING forever: ``remaining`` went negative
    and the retirement check never fired)."""
    cfg, params, eng = _engine()
    want = np.asarray(eng.generate(jnp.asarray([[5, 6, 7, 8]]), 3)[:, 4:])
    sched = Scheduler(eng, slots=1, chunk=2)
    r0 = Request(prompt=[1, 2, 3, 4], max_new_tokens=0)
    r1 = Request(prompt=[5, 6, 7, 8], max_new_tokens=3)
    done = sched.run([r0, r1], max_rounds=16)
    assert len(done) == 2
    assert r0.done and r0.tokens == [] and r0.finish_reason == "length"
    # the freed slot served r1 with unchanged numerics
    assert r1.tokens == want[0].tolist()
    assert all(s is None for s in sched.slots) and not sched.queue


def test_budget_zero_and_one_mixed_with_normal_requests():
    """A pile of degenerate budgets drains in bounded rounds alongside a
    normal stream (regression guard on the admission fast-finish path)."""
    cfg, params, eng = _engine()
    sched = Scheduler(eng, slots=2, chunk=2)
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=b)
            for b in (0, 1, 0, 4, 1, 0)]
    done = sched.run(reqs, max_rounds=32)
    assert len(done) == len(reqs)
    for r, b in zip(reqs, (0, 1, 0, 4, 1, 0)):
        assert len(r.tokens) == b and r.done


def test_request_streaming_callback():
    cfg, params, eng = _engine()
    seen = []
    req = Request(prompt=[1, 2, 3, 4], max_new_tokens=4,
                  on_token=lambda r, t: seen.append(t))
    Scheduler(eng, slots=1, chunk=2).run([req])
    assert seen == req.tokens and len(seen) == 4
