"""Paged KV-cache pool: allocator units, bit-exactness vs the dense oracle,
prefix reuse, pool exhaustion -> deterministic preempt-and-requeue, and the
no-retrace executor invariants.

The paged engine must be *indistinguishable* from the dense engine at
temperature 0: page tables only change WHERE bytes live, never what the
attention math reads — the ordered page gather reconstructs the dense
[B, T, H, D] buffer value-for-value (see ``serve.paged``).
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T
from repro.serve import (Engine, PagedLayout, PagePool, Request, Scheduler,
                         ServeConfig)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# allocator units (pure host-side, no jax)
# ---------------------------------------------------------------------------

def _layout(max_len=32, ps=4, window=None):
    cfg = configs.get_config("qwen2-7b", smoke=True)
    if window is not None:
        cfg = dataclasses.replace(
            cfg, window=window,
            pattern=(T.BlockSpec(attn_type="local"),))
    return PagedLayout.build(cfg, max_len, ps)


def test_page_pool_alloc_release_roundtrip():
    pool = PagePool(2, _layout(), pages_per_shard=9)
    assert pool.admit(0, list(range(10))) == 0        # 3 pages (10 tokens)
    assert pool.allocated_pages == 3
    assert pool.table[0, 0] != 0 and pool.table[0, 3] == 0
    assert pool.ensure(0, 14)                          # grow to 4 pages
    assert pool.allocated_pages == 4
    assert pool.ensure(0, 14)                          # idempotent
    assert pool.allocated_pages == 4
    pool.release(0)
    assert pool.allocated_pages == 0
    assert (pool.table[0] == 0).all() and pool.n_full[0] == 0
    assert pool.peak_pages == 4


def test_page_pool_prefix_sharing_refcounts():
    pool = PagePool(3, _layout(), pages_per_shard=32)
    base = list(range(100, 108))                       # 2 full pages
    assert pool.admit(0, base + [1, 2]) == 0           # fresh: 3 pages
    assert pool.admit(1, base + [3]) == 8              # shares the 2 full
    assert pool.prefix_hits == 2
    assert (pool.table[0][:2] == pool.table[1][:2]).all()
    assert pool.table[0][2] != pool.table[1][2]        # divergence page: own
    # slot 0 releases; shared pages survive for slot 1
    pool.release(0)
    assert pool.admit(2, base + [4]) == 8              # still shareable
    pool.release(1)
    pool.release(2)
    assert pool.allocated_pages == 0
    # fully released prefixes are forgotten: next admit is fresh
    assert pool.admit(0, base + [5]) == 0


def test_page_pool_exhaustion_is_atomic():
    pool = PagePool(2, _layout(), pages_per_shard=4)   # 3 usable pages
    assert pool.admit(0, list(range(8))) == 0          # 2 pages
    assert pool.admit(1, list(range(50, 59))) is None  # needs 3 > 1 free
    assert pool.n_full[1] == 0 and (pool.table[1] == 0).all()
    assert not pool.ensure(0, 32)                      # needs 8 total
    assert pool.n_full[0] == 2                         # untouched
    pool.release(0)
    assert pool.allocated_pages == 0


def test_page_pool_sharded_ids_are_local():
    pool = PagePool(4, _layout(), pages_per_shard=8, n_shards=2)
    assert pool.admit(0, list(range(6))) == 0          # shard 0
    assert pool.admit(2, list(range(6))) == 0          # shard 1: NO sharing
    assert pool.prefix_hits == 0                       # cross-shard miss
    # both shards hand out the same local ids starting at 1
    assert pool.table[0, 0] == pool.table[2, 0] == 1
    # same-shard sharing still works
    assert pool.admit(3, list(range(6))) == 4
    assert pool.prefix_hits == 1


def test_paged_layout_validation():
    cfg = configs.get_config("qwen2-7b", smoke=True)
    with pytest.raises(ValueError, match="page_size"):
        PagedLayout.build(cfg, 30, 4)
    gem = configs.get_config("gemma2-2b", smoke=True)  # window 8
    with pytest.raises(ValueError, match="ring"):
        PagedLayout.build(gem, 32, 16)   # divides max_len, not the ring
    lay = PagedLayout.build(gem, 32, 4)
    assert lay.ring_entries == 2 and lay.full_entries == 8


# ---------------------------------------------------------------------------
# paged engine == dense oracle (temperature 0)
# ---------------------------------------------------------------------------

def _params(arch, **over):
    cfg = dataclasses.replace(configs.get_config(arch, smoke=True),
                              compute_dtype="float32", **over)
    return cfg, T.init_params(jax.random.PRNGKey(0), cfg)


def _drive_staggered(eng, prompts, new, slots=2, chunk=2):
    sched = Scheduler(eng, slots=slots, chunk=chunk)
    reqs = [Request(prompt=np.asarray(p).tolist(), max_new_tokens=new)
            for p in prompts]
    sched.submit(reqs[0])
    if len(reqs) > 1:
        sched.submit(reqs[1])
    sched.step()
    for r in reqs[2:]:
        sched.submit(r)
    while sched.has_work:
        sched.step()
    return sched, [r.tokens for r in reqs]


@pytest.mark.parametrize("arch,S", [("qwen2-7b", 6), ("gemma2-2b", 4),
                                    ("gemma2-2b", 12)])
def test_paged_scheduler_matches_dense_oracle(arch, S):
    """Staggered paged admission emits the same tokens as the dense
    python-loop generate — incl. gemma SWA rings as page-aligned windows
    for prompts shorter AND longer than the window."""
    cfg, params = _params(arch)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, S), 0, cfg.vocab)
    oracle = Engine(cfg, params, ServeConfig(max_len=32))
    want = np.asarray(
        oracle.generate(prompts, max_new_tokens=5, use_scan=False)[:, S:])
    eng = Engine(cfg, params,
                 ServeConfig(max_len=32, paged=True, page_size=4))
    _, got = _drive_staggered(eng, prompts, 5)
    for i, toks in enumerate(got):
        assert toks == want[i].tolist(), (arch, S, i)
    assert eng.pool.allocated_pages == 0           # everything released
    sizes = tuple(f._cache_size() for f in eng._step_fns.values())
    assert sizes and all(s == 1 for s in sizes), sizes  # no-retrace invariant


def test_paged_int8_kv_matches_dense_scheduler():
    """int8-KV pools page the codes AND the per-token-per-head scales; the
    oracle is the dense scheduler (int8 live KV has no generate analogue)."""
    cfg, params = _params("qwen2-7b", kv_quant="int8")
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 6), 0, cfg.vocab)
    dense = Engine(cfg, params, ServeConfig(max_len=32))
    _, want = _drive_staggered(dense, prompts, 5)
    eng = Engine(cfg, params,
                 ServeConfig(max_len=32, paged=True, page_size=4))
    _, got = _drive_staggered(eng, prompts, 5)
    assert got == want


def test_paged_recurrent_hybrid_matches_dense_oracle():
    """zamba2: paged shared-attention K/V + dense mamba recurrent state
    (exact-length admission) — mixed paged/dense leaves in one stitch."""
    cfg, params = _params("zamba2-2.7b")
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab)
    oracle = Engine(cfg, params, ServeConfig(max_len=32))
    want = np.asarray(
        oracle.generate(prompts, max_new_tokens=4, use_scan=False)[:, 6:])
    eng = Engine(cfg, params,
                 ServeConfig(max_len=32, paged=True, page_size=4))
    assert eng.has_recurrent_state
    _, got = _drive_staggered(eng, prompts, 4)
    for i, toks in enumerate(got):
        assert toks == want[i].tolist(), i


# ---------------------------------------------------------------------------
# prefix reuse
# ---------------------------------------------------------------------------

def test_prefix_reuse_shares_pages_and_stays_exact():
    """Requests sharing an 8-token prefix map to the same physical pages
    (nonzero hit rate, fewer peak pages) and still emit exactly the dense
    oracle's tokens."""
    cfg, params = _params("qwen2-7b")
    base = list(range(1, 9))                          # 2 full pages at ps=4
    prompts = [base + [20 + i] for i in range(4)]
    oracle = Engine(cfg, params, ServeConfig(max_len=32))
    want = np.asarray(oracle.generate(
        jnp.asarray(prompts, jnp.int32), max_new_tokens=4,
        use_scan=False)[:, 9:])
    eng = Engine(cfg, params,
                 ServeConfig(max_len=32, paged=True, page_size=4))
    sched = Scheduler(eng, slots=4, chunk=2)
    reqs = [Request(prompt=p, max_new_tokens=4) for p in prompts]
    sched.run(reqs)
    for i, r in enumerate(reqs):
        assert r.tokens == want[i].tolist(), i
    assert eng.pool.prefix_hits > 0
    assert eng.pool.prefix_hit_rate > 0.3
    # 4 sequences x 4 pages dense-equivalent; sharing must beat that
    assert eng.pool.peak_pages < 16
    assert eng.pool.allocated_pages == 0


def test_prefix_reuse_disabled_allocates_everything():
    cfg, params = _params("qwen2-7b")
    base = list(range(1, 9))
    eng = Engine(cfg, params,
                 ServeConfig(max_len=32, paged=True, page_size=4,
                             prefix_reuse=False))
    sched = Scheduler(eng, slots=2, chunk=2)
    sched.run([Request(prompt=base + [20 + i], max_new_tokens=2)
               for i in range(2)])
    assert eng.pool.prefix_hits == 0


# ---------------------------------------------------------------------------
# pool exhaustion: deterministic preempt-and-requeue
# ---------------------------------------------------------------------------

def test_pool_exhaustion_preempts_youngest_and_stays_exact():
    """When the allocator runs dry mid-decode the scheduler preempts the
    youngest slot, requeues it (keeping its emitted tokens), and the final
    transcripts are token-identical to an uncontended run."""
    cfg, params = _params("qwen2-7b")
    prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 6), 0, cfg.vocab)
    oracle = Engine(cfg, params, ServeConfig(max_len=32))
    want = np.asarray(
        oracle.generate(prompts, max_new_tokens=12, use_scan=False)[:, 6:])
    # 3 slots x ceil(18/4) = 15 pages uncontended; 10 usable forces eviction
    eng = Engine(cfg, params,
                 ServeConfig(max_len=32, paged=True, page_size=4,
                             num_pages=11))
    sched = Scheduler(eng, slots=3, chunk=2)
    reqs = [Request(prompt=np.asarray(p).tolist(), max_new_tokens=12)
            for p in prompts]
    sched.run(reqs)
    for i, r in enumerate(reqs):
        assert r.tokens == want[i].tolist(), (i, r.tokens, want[i].tolist())
    assert sched.stats["preemptions"] > 0          # pool really was contended
    assert eng.pool.allocated_pages == 0
    # the unified step never retraces across preempt/resume cycles
    assert all(f._cache_size() == 1 for f in eng._step_fns.values())


def test_single_oversized_request_raises():
    cfg, params = _params("qwen2-7b")
    eng = Engine(cfg, params,
                 ServeConfig(max_len=32, paged=True, page_size=4,
                             num_pages=3))
    sched = Scheduler(eng, slots=2, chunk=2)
    with pytest.raises(RuntimeError, match="num_pages"):
        sched.run([Request(prompt=list(range(1, 13)), max_new_tokens=4)])


# ---------------------------------------------------------------------------
# memory accounting
# ---------------------------------------------------------------------------

def test_paged_kv_bytes_below_dense_capacity():
    cfg, params = _params("qwen2-7b")
    dense = Engine(cfg, params, ServeConfig(max_len=32))
    eng = Engine(cfg, params,
                 ServeConfig(max_len=32, paged=True, page_size=4))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab)
    _drive_staggered(eng, prompts, 4)
    # short sequences resident: allocated pages well under max_len capacity
    assert 0 < eng.kv_cache_bytes(2) < dense.kv_cache_bytes(2)
    # page_bytes * total pages == pool capacity bytes
    assert eng.page_bytes(2) * (eng.pool.pages_per_shard
                                * eng.pool.n_shards) \
        == eng._kv_leaf_bytes(2)


# ---------------------------------------------------------------------------
# encdec: page-table-indexed self-attention decode
# ---------------------------------------------------------------------------

def test_encdec_paged_decode_matches_dense():
    from repro.models import encdec as E
    cfg = dataclasses.replace(
        configs.get_config("whisper-large-v3", smoke=True),
        compute_dtype="float32")
    params = E.init_params(jax.random.PRNGKey(0), cfg)
    B, S, max_len, ps = 2, 4, 16, 4
    frames = jax.random.normal(jax.random.PRNGKey(1),
                               (B, cfg.enc_seq, cfg.d_model))
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    logits, cache = E.prefill(params, cfg, frames, toks)
    dense = dict(cache)
    for k in ("k", "v"):
        buf = jnp.zeros(cache[k].shape[:2] + (max_len,) + cache[k].shape[3:],
                        cache[k].dtype)
        dense[k] = jax.lax.dynamic_update_slice_in_dim(buf, cache[k], 0,
                                                       axis=2)
    E_ent = max_len // ps
    paged = E.init_paged_cache(cfg, B, max_len, B * E_ent + 1, ps)
    table = np.arange(1, B * E_ent + 1, dtype=np.int32).reshape(B, E_ent)
    pool_k, pool_v = np.array(paged["k"]), np.array(paged["v"])
    dk, dv = np.asarray(dense["k"]), np.asarray(dense["v"])
    for b in range(B):
        for j in range(E_ent):
            pool_k[:, table[b, j]] = dk[:, b, j * ps:(j + 1) * ps]
            pool_v[:, table[b, j]] = dv[:, b, j * ps:(j + 1) * ps]
    paged = {**paged, "k": jnp.asarray(pool_k), "v": jnp.asarray(pool_v),
             "xk": dense["xk"], "xv": dense["xv"]}
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = jnp.full((B,), S, jnp.int32)
    l_d, _ = E.decode_step(params, cfg, tok, dense, pos)
    l_p, c_p = E.decode_step(params, cfg, tok, paged, pos,
                             tables=(jnp.asarray(table), None))
    np.testing.assert_array_equal(np.asarray(l_d), np.asarray(l_p))
    # the new token's K row landed in its page slot
    pg, off = int(table[0, S // ps]), S % ps
    assert np.abs(np.asarray(c_p["k"])[:, pg, off]).sum() > 0


# ---------------------------------------------------------------------------
# guard rails
# ---------------------------------------------------------------------------

def test_paged_engine_guard_rails():
    cfg, params = _params("qwen2-7b")
    with pytest.raises(ValueError, match="page_size"):
        Engine(cfg, params, ServeConfig(max_len=30, paged=True, page_size=4))
    gem, gparams = _params("gemma2-2b")
    with pytest.raises(ValueError, match="ring"):
        Engine(gem, gparams, ServeConfig(max_len=32, paged=True,
                                         page_size=16))
    # generate() on a paged engine silently takes the dense python loop
    eng = Engine(cfg, params, ServeConfig(max_len=32, paged=True,
                                          page_size=4))
    dense = Engine(cfg, params, ServeConfig(max_len=32))
    prompts = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(eng.generate(prompts, 4)),
        np.asarray(dense.generate(prompts, 4, use_scan=False)))


# ---------------------------------------------------------------------------
# sharded paged engine (8 fake CPU devices in a subprocess — the CI recipe)
# ---------------------------------------------------------------------------

_SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, numpy as np
    from repro import configs
    from repro.launch.mesh import make_serving_mesh
    from repro.models import transformer as T
    from repro.serve import Engine, Request, Scheduler, ServeConfig, \\
        ShardedEngine

    def case(arch, quant, mesh_spec, kv_quant="none",
             shared_prefix=False):
        cfg = dataclasses.replace(
            configs.get_config(arch, smoke=True, quant=quant),
            compute_dtype="float32", kv_quant=kv_quant)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        if shared_prefix:
            base = list(range(1, 9))
            plist = [base + [20 + i] for i in range(4)]
            prompts = jax.numpy.asarray(plist, jax.numpy.int32)
        else:
            prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 6), 0,
                                         cfg.vocab)
        dense_scfg = ServeConfig(max_len=32, quant=quant)
        ref = Engine(cfg, params, dense_scfg)
        if kv_quant == "none":
            want = np.asarray(ref.generate(
                prompts, 5, use_scan=False)[:, prompts.shape[1]:])
        else:
            rs = Scheduler(ref, slots=4, chunk=2)
            rr = [Request(prompt=np.asarray(prompts[i]).tolist(),
                          max_new_tokens=5) for i in range(4)]
            rs.run(rr)
            want = np.asarray([r.tokens for r in rr])
        scfg = ServeConfig(max_len=32, quant=quant, paged=True, page_size=4)
        eng = ShardedEngine(cfg, params, scfg,
                            mesh=make_serving_mesh(mesh_spec))
        sched = Scheduler(eng, slots=4, chunk=2)
        reqs = [Request(prompt=np.asarray(prompts[i]).tolist(),
                        max_new_tokens=5) for i in range(4)]
        sched.submit(reqs[0]); sched.submit(reqs[1]); sched.step()
        sched.submit(reqs[2]); sched.submit(reqs[3])
        while sched.has_work:
            sched.step()
        for i, r in enumerate(reqs):
            assert r.tokens == want[i].tolist(), \\
                (arch, mesh_spec, i, r.tokens, want[i].tolist())
        sizes = tuple(f._cache_size() for f in eng._step_fns.values())
        assert sizes and all(s == 1 for s in sizes), (arch, mesh_spec, sizes)
        if shared_prefix:
            assert eng.pool.prefix_hits > 0, "prefix reuse never fired"
        assert eng.pool.allocated_pages == 0
        # per-shard residency: head sharding shrinks the page footprint too
        print("OK", arch, quant, mesh_spec, "kv=" + kv_quant,
              "per_shard_bytes=", eng.kv_cache_bytes(4),
              "head_sharded=", eng.head_sharded, flush=True)

    case("qwen2-7b", "w4a4_lut", "2x2", shared_prefix=True)
    case("qwen2-7b", "w4a4_lut", "1x8")
    case("gemma2-2b", "w8a8", "2x2")                 # paged SWA rings
    case("qwen2-7b", "w4a4_lut", "2x2", kv_quant="int8")
    print("ALL-OK")
""")


@pytest.mark.slow
def test_sharded_paged_bit_identical_subprocess():
    """Dense Engine vs paged ShardedEngine on 2x2 / 1x8: bit-identical
    transcripts, page pools split over the data axis (shard-local ids),
    prefix reuse live under sharding, executors compile once."""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "ALL-OK" in out.stdout, out.stdout
