"""Crash recovery: serving-state checkpoints round-trip mid-stream.

A scheduler saved mid-decode and loaded into a FRESH engine + scheduler (and,
in the subprocess variant, a fresh process) must continue every in-flight
request token-identically to the uninterrupted run — dense and paged engines,
gemma2 SWA ring caches, int8-quantized KV, and the page-pool allocator +
prefix registry all included.  Plus the deadline / shedding / validation
satellites: logical-time expiry, deterministic shed sets, slack-aware
preemption ordering, submit rejection, and drain leak telemetry.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T
from repro.serve import Engine, Request, Scheduler, ServeConfig
from repro.serve.faults import Fault, FaultPlan

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _make(arch="qwen2-7b", max_len=32, kv_quant=None, **scfg):
    cfg = dataclasses.replace(configs.get_config(arch, smoke=True),
                              compute_dtype="float32")
    if kv_quant is not None:
        cfg = dataclasses.replace(cfg, kv_quant=kv_quant)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params, ServeConfig(max_len=max_len, **scfg)


def _reqs(cfg, n=4, S=5, budget=8):
    prompts = jax.random.randint(jax.random.PRNGKey(1), (n, S), 0, cfg.vocab)
    return [Request(prompt=np.asarray(prompts[i]).tolist(),
                    max_new_tokens=budget) for i in range(n)]


def _drain(sched, max_rounds=64):
    rounds = 0
    while sched.has_work:
        sched.step()
        rounds += 1
        assert rounds <= max_rounds
    return [(r.finish_reason, list(r.tokens)) for r in
            (list(sched.finished) + [r for r in sched.slots if r])]


# ---------------------------------------------------------------------------
# disk save/load round-trips (fresh engine + scheduler)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,scfg_kw,kv_quant", [
    ("qwen2-7b", {}, None),
    ("qwen2-7b", {"paged": True, "page_size": 4}, None),
    ("gemma2-2b", {}, None),                       # SWA ring caches
    ("qwen2-7b", {}, "int8"),                      # quantized KV + scales
])
def test_save_load_continues_token_identically(tmp_path, arch, scfg_kw,
                                               kv_quant):
    cfg, params, scfg = _make(arch, kv_quant=kv_quant, **scfg_kw)
    reqs = _reqs(cfg)

    # uninterrupted reference
    eng = Engine(cfg, params, scfg)
    ref = Scheduler(eng, slots=2, chunk=2)
    for r in _reqs(cfg):
        ref.submit(r)
    want = sorted(_drain(ref))

    # interrupted: a few rounds, save mid-stream, "crash"
    eng_a = Engine(cfg, params, scfg)
    a = Scheduler(eng_a, slots=2, chunk=2)
    for r in reqs:
        a.submit(r)
    a.step()
    a.step()
    assert a.has_work                   # genuinely mid-stream
    a.save(str(tmp_path))

    # fresh engine + scheduler (new params object, new executors)
    eng_b = Engine(cfg, T.init_params(jax.random.PRNGKey(0), cfg), scfg)
    b = Scheduler(eng_b, slots=2, chunk=2)
    b.load(str(tmp_path))
    got = sorted(_drain(b))
    assert got == want


def test_save_load_roundtrips_pool_allocator(tmp_path):
    """The paged allocator (tables, rings, free lists, refcounts, prefix
    registry, stats) survives the disk round-trip exactly."""
    cfg, params, scfg = _make(paged=True, page_size=4)
    eng = Engine(cfg, params, scfg)
    sched = Scheduler(eng, slots=2, chunk=2)
    for r in _reqs(cfg):
        sched.submit(r)
    sched.step()
    sched.step()
    state_a = eng.pool.state_dict()
    sched.save(str(tmp_path))
    eng2 = Engine(cfg, params, scfg)
    b = Scheduler(eng2, slots=2, chunk=2)
    b.load(str(tmp_path))
    assert eng2.pool.state_dict() == state_a
    assert eng2.pool.validate() == []


def test_load_rejects_geometry_mismatch(tmp_path):
    cfg, params, scfg = _make()
    eng = Engine(cfg, params, scfg)
    sched = Scheduler(eng, slots=2, chunk=2)
    sched.submit(_reqs(cfg, n=1)[0])
    sched.step()
    sched.save(str(tmp_path))
    other = Scheduler(Engine(cfg, params, scfg), slots=4, chunk=2)
    with pytest.raises(ValueError, match="geometry"):
        other.load(str(tmp_path))


@pytest.mark.slow
def test_save_load_fresh_process_subprocess(tmp_path):
    """The full crash-recovery story: save in process A, restore in a brand
    new process B, continue token-identically (paged engine)."""
    common = textwrap.dedent("""
        import dataclasses, jax, numpy as np
        from repro import configs
        from repro.models import transformer as T
        from repro.serve import Engine, Request, Scheduler, ServeConfig
        cfg = dataclasses.replace(configs.get_config("qwen2-7b", smoke=True),
                                  compute_dtype="float32")
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        scfg = ServeConfig(max_len=32, paged=True, page_size=4)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 5), 0,
                                     cfg.vocab)
        reqs = [Request(prompt=np.asarray(prompts[i]).tolist(),
                        max_new_tokens=8) for i in range(4)]
        def drain(s):
            while s.has_work:
                s.step()
            return sorted((r.finish_reason, tuple(r.tokens))
                          for r in s.finished)
    """)
    save_script = common + textwrap.dedent(f"""
        ref = Scheduler(Engine(cfg, params, scfg), slots=2, chunk=2)
        for r in [Request(prompt=list(r.prompt), max_new_tokens=8)
                  for r in reqs]:
            ref.submit(r)
        print("WANT", drain(ref))
        s = Scheduler(Engine(cfg, params, scfg), slots=2, chunk=2)
        for r in reqs:
            s.submit(r)
        s.step(); s.step()
        assert s.has_work
        s.save({str(tmp_path)!r})
        print("SAVED_OK")
    """)
    load_script = common + textwrap.dedent(f"""
        s = Scheduler(Engine(cfg, params, scfg), slots=2, chunk=2)
        s.load({str(tmp_path)!r})
        done = drain(s)
        print("GOT", done)
        print("LOADED_OK")
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    a = subprocess.run([sys.executable, "-c", save_script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert a.returncode == 0 and "SAVED_OK" in a.stdout, a.stderr[-4000:]
    b = subprocess.run([sys.executable, "-c", load_script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert b.returncode == 0 and "LOADED_OK" in b.stdout, b.stderr[-4000:]
    want = next(l for l in a.stdout.splitlines() if l.startswith("WANT"))
    got = next(l for l in b.stdout.splitlines() if l.startswith("GOT"))
    assert want.split(" ", 1)[1] == got.split(" ", 1)[1]


def test_host_snapshot_restore_is_exact():
    """The in-memory rolling snapshot restores device state, request state,
    and the allocator bit-exactly (the fault-recovery primitive)."""
    cfg, params, scfg = _make(paged=True, page_size=4)
    eng = Engine(cfg, params, scfg)
    sched = Scheduler(eng, slots=2, chunk=2)
    reqs = _reqs(cfg)
    for r in reqs:
        sched.submit(r)
    sched.step()
    snap = sched.snapshot()
    mid = [(r.status, list(r.tokens)) for r in reqs]
    pool_mid = eng.pool.state_dict()
    want = sorted(_drain(sched))
    # everything mutated since the snapshot rewinds
    sched.restore(snap)
    assert [(r.status, list(r.tokens)) for r in reqs] == mid
    assert eng.pool.state_dict() == pool_mid
    assert sorted(_drain(sched)) == want


def _mid_prefill(sched):
    return any(r is not None and sched._progress[s] < sched._target[s]
               for s, r in enumerate(sched.slots))


def test_snapshot_restore_mid_prefill_chunk():
    """A snapshot taken while a long prompt is still mid-way through chunked
    prefill (progress < target) carries the partial chunk cursor, and the
    restored run finishes token-identically."""
    cfg, params, scfg = _make(paged=True, page_size=4, prefill_chunk=4)
    eng = Engine(cfg, params, scfg)
    sched = Scheduler(eng, slots=2, chunk=2)
    prompts = jax.random.randint(jax.random.PRNGKey(7), (2, 20), 0, cfg.vocab)
    reqs = [Request(prompt=np.asarray(p).tolist(), max_new_tokens=6)
            for p in prompts]
    for r in reqs:
        sched.submit(r)
    sched.step()                        # 4 of 20 prompt tokens prefetched
    assert _mid_prefill(sched)          # snapshot lands inside the chunk walk
    snap = sched.snapshot()
    pool_mid = eng.pool.state_dict()
    want = sorted(_drain(sched))
    sched.restore(snap)
    assert _mid_prefill(sched)
    assert eng.pool.state_dict() == pool_mid
    assert sorted(_drain(sched)) == want


def test_save_load_mid_prefill_chunk(tmp_path):
    """Disk save/load while a prompt is mid-chunked-prefill restores the
    progress/target cursors into a FRESH engine and continues exactly."""
    cfg, params, scfg = _make(paged=True, page_size=4, prefill_chunk=4)
    reqs_ref = _reqs(cfg, n=2, S=20, budget=6)
    ref = Scheduler(Engine(cfg, params, scfg), slots=2, chunk=2)
    for r in reqs_ref:
        ref.submit(r)
    want = sorted(_drain(ref))

    a = Scheduler(Engine(cfg, params, scfg), slots=2, chunk=2)
    for r in _reqs(cfg, n=2, S=20, budget=6):
        a.submit(r)
    a.step()
    assert _mid_prefill(a)
    a.save(str(tmp_path))
    b = Scheduler(Engine(cfg, T.init_params(jax.random.PRNGKey(0), cfg),
                         scfg), slots=2, chunk=2)
    b.load(str(tmp_path))
    assert _mid_prefill(b)
    assert sorted(_drain(b)) == want


def test_fault_replay_resumes_mid_prefill_chunk():
    """A dispatch fault that lands while a long prompt is mid-chunked-prefill
    replays from the rolling snapshot — resuming INSIDE the chunk walk — and
    still matches the fault-free transcript bit-for-bit."""
    cfg, params, scfg = _make(paged=True, page_size=4, prefill_chunk=4)
    reqs = _reqs(cfg, n=2, S=20, budget=6)
    ref = Scheduler(Engine(cfg, params, scfg), slots=2, chunk=2)
    ref.run(reqs, max_rounds=64)
    want = [(r.finish_reason, list(r.tokens)) for r in reqs]

    eng = Engine(cfg, params, scfg)
    # admit dispatch #2 is the third prefill chunk of the 20-token prompt
    plan = FaultPlan([Fault(site="admit", index=2, kind="dispatch",
                            duration=0.001)])
    eng.set_fault_plan(plan)
    sched = Scheduler(eng, slots=2, chunk=2, snapshot_interval=1,
                      max_retries=3)
    got = _reqs(cfg, n=2, S=20, budget=6)
    try:
        sched.run(got, max_rounds=64)
    finally:
        eng.set_fault_plan(None)
    assert not plan.pending
    assert sched.stats["recoveries"] >= 1
    assert [(r.finish_reason, list(r.tokens)) for r in got] == want


# ---------------------------------------------------------------------------
# deadlines / shedding / preemption satellites (logical time throughout)
# ---------------------------------------------------------------------------

def test_deadline_expiry_queued_and_running():
    cfg, params, scfg = _make()
    eng = Engine(cfg, params, scfg)
    sched = Scheduler(eng, slots=1, chunk=2)
    r_run = Request(prompt=[1, 2, 3], max_new_tokens=12, deadline=5.0)
    r_q = Request(prompt=[4, 5, 6], max_new_tokens=4, deadline=1.0)
    sched.submit(r_run, now=0.0)
    sched.submit(r_q, now=0.0)
    sched.step(now=0.0)                  # r_run admitted, r_q queued
    assert r_run.status.value == "running"
    sched.step(now=2.0)                  # r_q's deadline passed while queued
    assert r_q.status.value == "timed_out" and r_q.tokens == []
    sched.step(now=6.0)                  # r_run expires mid-decode
    assert r_run.status.value == "timed_out"
    assert 0 < len(r_run.tokens) < 12    # partial transcript retained
    assert r_run.finish_time == 6.0
    assert not sched.has_work
    assert sched.stats["timed_out"] == 2


def test_clockless_run_never_expires():
    cfg, params, scfg = _make()
    sched = Scheduler(Engine(cfg, params, scfg), slots=2, chunk=2)
    req = Request(prompt=[1, 2, 3], max_new_tokens=4, deadline=0.5)
    sched.run([req])                     # no now= anywhere
    assert req.finish_reason == "length" and len(req.tokens) == 4


def test_shedding_is_deterministic_and_priority_ordered():
    """Saturated slots + overlong queue: the shed set is exactly the lowest
    (priority, slack, -submit order) tail, and two identical runs shed the
    identical set."""
    def run_once():
        cfg, params, scfg = _make()
        sched = Scheduler(Engine(cfg, params, scfg), slots=1, chunk=2, shed_watermark=1.0,
                          overload_queue=2)
        keep = Request(prompt=[1, 2, 3], max_new_tokens=8)
        sched.submit(keep, now=0.0)
        sched.step(now=0.0)              # slot saturated
        waiting = [Request(prompt=[10 + i, 2, 3], max_new_tokens=2,
                           priority=p, deadline=d)
                   for i, (p, d) in enumerate(
                       [(1, None), (0, 9.0), (0, 3.0), (1, 2.0)])]
        for r in waiting:
            sched.submit(r, now=1.0)
        sched.step(now=1.0)              # 4 queued > overload_queue=2
        return [r.status.value for r in waiting]
    got = run_once()
    # shed 2: priority-0 requests go first, least slack first
    assert got == ["queued", "shed", "shed", "queued"]
    assert run_once() == got             # deterministic replay


def test_no_shedding_below_watermark():
    cfg, params, scfg = _make()
    sched = Scheduler(Engine(cfg, params, scfg), slots=2, chunk=2, shed_watermark=1.0,
                      overload_queue=1)
    reqs = _reqs(cfg, n=6, budget=3)
    for r in reqs:
        sched.submit(r, now=0.0)
    while sched.has_work:
        sched.step(now=0.0)
    assert all(r.finish_reason == "length" for r in reqs[:2])
    assert sched.stats["shed"] < 6       # below-watermark rounds admit


def test_preemption_prefers_most_slack_victim():
    """Pool exhaustion evicts the slot that can best afford the requeue —
    the one with the MOST deadline slack — not simply the youngest."""
    cfg, params, scfg = _make(paged=True, page_size=4, num_pages=13)
    eng = Engine(cfg, params, scfg)
    sched = Scheduler(eng, slots=2, chunk=2)
    # 4 prompt + 24 new = 28 tokens = 7 pages per slot; two slots want 14
    # pages of the 12 usable (13 minus the null page) — the pool MUST
    # preempt someone mid-decode
    tight = Request(prompt=[1, 2, 3, 4], max_new_tokens=24, deadline=100.0)
    loose = Request(prompt=[5, 6, 7, 8], max_new_tokens=24, deadline=1e6)
    sched.submit(tight, now=0.0)
    sched.submit(loose, now=0.0)
    preempted = []
    orig = sched._preempt_victim

    def spy(now_v):
        slot, req = orig(now_v)
        preempted.append(req)
        return slot, req
    sched._preempt_victim = spy
    while sched.has_work:
        sched.step(now=0.0)
    assert preempted and all(r is loose for r in preempted)
    assert tight.finish_reason == "length" and len(tight.tokens) == 24
    assert loose.finish_reason == "length" and len(loose.tokens) == 24


# ---------------------------------------------------------------------------
# submit validation + leak telemetry satellites
# ---------------------------------------------------------------------------

def test_submit_rejects_malformed_requests():
    cfg, params, scfg = _make(max_len=16)
    sched = Scheduler(Engine(cfg, params, scfg), slots=2, chunk=2)
    with pytest.raises(ValueError, match="max_new_tokens"):
        Request(prompt=[1], max_new_tokens=-1)
    r = Request(prompt=[1], max_new_tokens=1)
    r.max_new_tokens = -2                # mutated after construction
    with pytest.raises(ValueError, match="max_new_tokens"):
        sched.submit(r)
    with pytest.raises(ValueError, match="prompt length"):
        sched.submit(Request(prompt=list(range(17)), max_new_tokens=0))
    with pytest.raises(ValueError, match="exceeds max_len"):
        sched.submit(Request(prompt=list(range(10)), max_new_tokens=10))
    with pytest.raises(ValueError, match="deadline"):
        Request(prompt=[1], deadline=float("nan"))
    with pytest.raises(ValueError, match="priority"):
        Request(prompt=[1], priority=float("inf"))
    r2 = Request(prompt=[1], max_new_tokens=1)
    r2.deadline = float("inf")
    with pytest.raises(ValueError, match="deadline"):
        sched.submit(r2)
    assert not sched.queue               # nothing malformed got queued


def test_drain_leak_telemetry():
    cfg, params, scfg = _make(paged=True, page_size=4)
    eng = Engine(cfg, params, scfg)
    sched = Scheduler(eng, slots=2, chunk=2)
    sched.run(_reqs(cfg))
    assert eng.pool.allocated_pages == 0
    assert eng.pool.leaked_pages() == []
    sched.check_drained()                # and the assertion agrees
    # a synthetic leak IS caught: bump a refcount with no slot mapping
    eng.pool._shards[0].ref[2] += 1
    assert eng.pool.leaked_pages() == [(0, 2)]
    with pytest.raises(AssertionError, match="leak"):
        sched.check_drained()
    eng.pool._shards[0].ref[2] -= 1


# ---------------------------------------------------------------------------
# streaming-callback isolation + shed-tiebreak restore determinism
# ---------------------------------------------------------------------------

def test_raising_stream_callback_fails_only_its_request():
    """A streaming ``on_token`` that raises mid-decode must fail ONLY its
    own request (terminal status ``failed``, counted in stats) — every
    other slot's tokens in the same continuous-batching round still commit
    bit-identically to a run without the bad consumer."""
    cfg, params, scfg = _make()
    ref = Scheduler(Engine(cfg, params, scfg), slots=2, chunk=2)
    clean = _reqs(cfg)
    for r in clean:
        ref.submit(r)
    _drain(ref)
    want = {tuple(r.prompt): list(r.tokens) for r in clean}

    calls = {"n": 0}

    def bad_consumer(req, tok):
        calls["n"] += 1
        if calls["n"] >= 3:
            raise RuntimeError("consumer went away")

    sched = Scheduler(Engine(cfg, params, scfg), slots=2, chunk=2)
    reqs = _reqs(cfg)
    reqs[0].on_token = bad_consumer
    for r in reqs:
        sched.submit(r)
    _drain(sched)
    assert reqs[0].status.value == "failed"
    assert reqs[0].finish_reason == "failed"
    assert sched.stats["failed"] == 1
    # the poisoned request keeps the tokens delivered before the raise
    # (at-least-once up to the callback boundary), a prefix of the oracle's
    got0 = list(reqs[0].tokens)
    assert got0 == want[tuple(reqs[0].prompt)][:len(got0)]
    for r in reqs[1:]:
        assert r.finish_reason == "length"
        assert list(r.tokens) == want[tuple(r.prompt)]


def test_raising_callback_at_admission_keeps_round():
    """First-token delivery happens inside the admission round; a raising
    callback there must not poison the other admissions."""
    cfg, params, scfg = _make(paged=True, page_size=4)

    def boom(req, tok):
        raise RuntimeError("no")

    eng = Engine(cfg, params, scfg)
    sched = Scheduler(eng, slots=2, chunk=2)
    reqs = _reqs(cfg, n=2)
    reqs[0].on_token = boom
    for r in reqs:
        sched.submit(r)
    _drain(sched)
    assert reqs[0].status.value == "failed"
    assert len(reqs[0].tokens) == 1      # the token itself is on record
    assert reqs[1].finish_reason == "length"
    assert eng.pool.allocated_pages == 0 and not eng.pool.leaked_pages()


def test_shed_tiebreak_survives_save_load(tmp_path):
    """The shed ordering's final tie-break is the submission sequence
    (latest submitted goes first); a crash-restored scheduler must shed the
    SAME set as the uninterrupted one — i.e. ``_seq`` and the submit
    counter round-trip through save/load."""
    def build():
        cfg, params, scfg = _make()
        sched = Scheduler(Engine(cfg, params, scfg), slots=1, chunk=2,
                          shed_watermark=1.0, overload_queue=2)
        keep = Request(prompt=[1, 2, 3], max_new_tokens=8)
        sched.submit(keep, now=0.0)
        sched.step(now=0.0)              # slot saturated
        # identical priority, no deadlines: ONLY -_seq breaks the tie
        waiting = [Request(prompt=[10 + i, 2, 3], max_new_tokens=2)
                   for i in range(4)]
        for r in waiting:
            sched.submit(r, now=1.0)
        return cfg, params, scfg, sched, waiting

    _, _, _, ref, ref_wait = build()
    ref.step(now=1.0)
    want = [r.status.value for r in ref_wait]
    assert want == ["queued", "queued", "shed", "shed"]
    want_shed = {tuple(r.prompt) for r in ref_wait
                 if r.status.value == "shed"}

    cfg, params, scfg, a, _ = build()
    a.save(str(tmp_path))
    b = Scheduler(Engine(cfg, T.init_params(jax.random.PRNGKey(0), cfg),
                         scfg), slots=1, chunk=2, shed_watermark=1.0,
                  overload_queue=2)
    b.load(str(tmp_path))
    b.step(now=1.0)
    got_shed = {tuple(r.prompt) for r in b.finished
                if r.finish_reason == "shed"}
    assert got_shed == want_shed
    # and a fresh submission continues the restored counter, keeping the
    # latest-first tie-break monotone across the crash
    late = Request(prompt=[99, 2, 3], max_new_tokens=2)
    b.submit(late, now=1.0)
    assert late._seq == b._submit_count and late._seq > max(
        getattr(r, "_seq", 0) for r in b.queue if r is not late)
