"""int8-quantized KV-cache decode (beyond-paper §Perf iteration A4)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import attention as A, transformer as T


def test_quantize_kv_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 16))
    q, s = A.quantize_kv(x)
    back = q.astype(jnp.float32) * s[..., None]
    rel = float(jnp.linalg.norm(back - x) / jnp.linalg.norm(x))
    assert rel < 0.01
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32


def test_int8_decode_matches_float_decode():
    cfg = dataclasses.replace(configs.get_config("qwen2-7b", smoke=True),
                              compute_dtype="float32")
    cfg8 = dataclasses.replace(cfg, kv_quant="int8")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    cf = T.init_cache(cfg, B, S)
    cq = T.init_cache(cfg8, B, S)
    assert cq[0]["k"].dtype == jnp.int8 and "k_scale" in cq[0]
    for t in range(S):
        lf, cf = T.decode_step(params, cfg, toks[:, t], cf, jnp.int32(t))
        lq, cq = T.decode_step(params, cfg8, toks[:, t], cq, jnp.int32(t))
    a, b = np.asarray(lf), np.asarray(lq)
    cos = float((a * b).sum() / (np.linalg.norm(a) * np.linalg.norm(b)))
    assert cos > 0.999, cos
    assert (np.argmax(a, -1) == np.argmax(b, -1)).all()


def test_int8_cache_halves_bytes():
    cfg = configs.get_config("qwen2-7b", smoke=True)
    cfg8 = dataclasses.replace(cfg, kv_quant="int8")
    cf = T.init_cache(cfg, 2, 64)
    cq = T.init_cache(cfg8, 2, 64)
    bytes_f = sum(x.size * x.dtype.itemsize
                  for x in jax.tree_util.tree_leaves(cf))
    bytes_q = sum(x.size * x.dtype.itemsize
                  for x in jax.tree_util.tree_leaves(cq))
    assert bytes_q < 0.66 * bytes_f   # int8 codes + fp32 scales < 2/3 of bf16
