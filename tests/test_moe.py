import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models.moe import MoEConfig, init_moe, moe_ffn


def _setup(E=8, k=2, d=16, ff=32, shared=0):
    cfg = MoEConfig(n_experts=E, top_k=k, d_ff=ff, shared_ff=shared)
    p = init_moe(jax.random.PRNGKey(0), d, cfg)
    return cfg, p


def test_moe_output_shape_and_finite():
    cfg, p = _setup(shared=24)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y, aux = moe_ffn(p, x, cfg, compute_dtype=jnp.float32)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) >= 0.0


def test_moe_capacity_drop_bound():
    """With capacity_factor >= E/topk the buffer can hold every token ->
    output must equal the dense-dispatch reference."""
    E, k, d, T = 4, 2, 8, 16
    cfg = MoEConfig(n_experts=E, top_k=k, d_ff=16, capacity_factor=float(E),
                    norm_topk=True)
    p = init_moe(jax.random.PRNGKey(0), d, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, T, d))
    y, _ = moe_ffn(p, x, cfg, compute_dtype=jnp.float32)

    # dense reference: run every expert on every token, weight by gates
    xf = x.reshape(T, d)
    logits = xf @ p["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    gv, ei = jax.lax.top_k(probs, k)
    gv = gv / gv.sum(-1, keepdims=True)
    h = jnp.einsum("td,edf->tef", xf, p["wi"])
    g = jnp.einsum("td,edf->tef", xf, p["wg"])
    act = jax.nn.silu(g) * h
    out_all = jnp.einsum("tef,efd->ted", act, p["wo"])
    want = jnp.zeros((T, d))
    for slot in range(k):
        want += gv[:, slot:slot + 1] * jnp.take_along_axis(
            out_all, ei[:, slot][:, None, None], axis=1)[:, 0]
    np.testing.assert_allclose(np.asarray(y.reshape(T, d)), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@given(seed=st.integers(0, 100), cf=st.floats(0.5, 2.0))
@settings(max_examples=20, deadline=None)
def test_moe_conservation_property(seed, cf):
    """Output norm bounded by gate-weighted expert outputs; no NaN for any
    routing pattern / capacity factor."""
    cfg = MoEConfig(n_experts=6, top_k=2, d_ff=12, capacity_factor=cf)
    p = init_moe(jax.random.PRNGKey(0), 8, cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 6, 8))
    y, aux = moe_ffn(p, x, cfg, compute_dtype=jnp.float32)
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(float(aux))


def test_moe_deterministic_capacity_static():
    cfg, p = _setup()
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 1, 16))

    def f(x):
        y, _ = moe_ffn(p, x, cfg, compute_dtype=jnp.float32,
                       deterministic_capacity=4)
        return y
    y = jax.jit(f)(x)
    assert y.shape == x.shape
