import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import pipeline
from repro.optim import adamw, grad_compress, schedules


def test_adamw_first_step_matches_reference():
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.1, 0.2, -0.3])}
    st = adamw.init(p)
    cfg = adamw.AdamWConfig(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                            grad_clip=1e9)
    new_p, st2, gn = adamw.update(p, g, st, jnp.float32(0.01), cfg)
    # bias-corrected first step: delta = lr * g/|g| elementwise -> lr*sign(g)
    np.testing.assert_allclose(
        np.asarray(new_p["w"]),
        np.asarray(p["w"]) - 0.01 * np.sign(np.asarray(g["w"])), rtol=1e-4)
    assert int(st2["step"]) == 1


def test_grad_clip():
    g = {"w": jnp.full((100,), 10.0)}
    clipped, gn = adamw.clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(100.0)
    assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_wsd_schedule_phases():
    f = schedules.make("wsd", peak_lr=1.0, warmup=10, stable=80, decay=10)
    assert float(f(jnp.asarray(0))) == 0.0
    assert float(f(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(f(jnp.asarray(50))) == pytest.approx(1.0)
    assert float(f(jnp.asarray(95))) < 1.0
    assert float(f(jnp.asarray(200))) == pytest.approx(0.1)


def test_compress_decompress_error_feedback():
    g = jnp.asarray(np.random.default_rng(0).normal(size=(256,)) * 1e-3,
                    jnp.float32)
    r = jnp.zeros_like(g)
    scale = jnp.max(jnp.abs(g)) / 127.0
    q, r_new = grad_compress.compress_decompress(g, r, scale)
    # residual = quantization error; reconstruction + residual == original
    np.testing.assert_allclose(
        np.asarray(q.astype(jnp.float32) * scale + r_new), np.asarray(g),
        rtol=1e-5, atol=1e-8)
    assert q.dtype == jnp.int8


def test_compressed_psum_single_axis():
    """Under shard_map on 1 device the mean must be exact after EF."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.dist.sharding import make_mesh
    mesh = make_mesh((1,), ("dp",))
    g = {"w": jnp.asarray([0.5, -0.25, 0.125])}
    r = grad_compress.init_residual(g)

    def f(g, r):
        return grad_compress.compressed_psum(g, r, "dp")

    out, r2 = shard_map(f, mesh=mesh, in_specs=(P(), P()),
                        out_specs=(P(), P()))(g, r)
    total = np.asarray(out["w"]) + np.asarray(r2["w"])
    np.testing.assert_allclose(total, np.asarray(g["w"]), atol=1e-7)


def test_data_determinism_and_sharding():
    cfg = pipeline.DataConfig(seed=7, global_batch=8, n_shards=2, shard=0)
    b1 = pipeline.lm_batch(cfg, 3)
    b2 = pipeline.lm_batch(cfg, 3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    other = pipeline.lm_batch(
        pipeline.DataConfig(seed=7, global_batch=8, n_shards=2, shard=1), 3)
    assert not np.array_equal(b1["tokens"], other["tokens"])
    assert b1["tokens"].shape == (4, 128)
    # labels are the shifted stream
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_data_learnable_structure():
    """The periodic stream is predictable: two consecutive batches from the
    same shard+step agree, and the sequence has period structure."""
    cfg = pipeline.DataConfig(seed=0, global_batch=2, noise_frac=0.0)
    b = pipeline.lm_batch(cfg, 0)
    t = b["tokens"][0]
    # find the period by checking repeats
    assert any(np.array_equal(t[:32], t[p:p + 32]) for p in range(2, 17))
