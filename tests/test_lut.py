"""Bit-exact validation of the paper's LUT mechanism (Fig. 5, Eq. 3)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import lut


def test_fig5_init_words_bit_exact():
    """The four 64-bit INIT constants printed in the paper for weights
    (+1, -3) must be reproduced exactly."""
    words = lut.lut6_2_init_words(1, -3)
    assert tuple(words) == tuple(lut.PAPER_FIG5_INIT_WORDS)


def test_eq3_lut_cost():
    # n=4: (2*4 * 2^4) / 2^6 = 2 LUTs per multiply — the headline number
    assert lut.luts_per_multiply(4) == 2.0
    assert lut.luts_per_multiply(8) == 64.0
    assert lut.luts_per_multiply(2) == 0.25


@given(w0=st.integers(-8, 7), w1=st.integers(-8, 7),
       ws=st.integers(0, 1), a=st.integers(0, 15))
@settings(max_examples=200, deadline=None)
def test_lut6_functional_multiply(w0, w1, ws, a):
    """Evaluating the generated LUT6_2 bank == integer multiplication."""
    w = (w0, w1)[ws]
    assert lut.multiply_via_lut6(w0, w1, ws, a) == w * a


def test_product_table_exhaustive():
    T = lut.product_table()           # signed w, unsigned a
    for w in range(-8, 8):
        for a in range(16):
            assert T[(w + 16) % 16, a] == w * a
    Ts = lut.product_table(a_signed=True)
    for w in range(-8, 8):
        for a in range(-8, 8):
            assert Ts[(w + 16) % 16, (a + 16) % 16] == w * a


@given(st.lists(st.integers(-8, 7), min_size=2, max_size=64)
       .filter(lambda v: len(v) % 2 == 0))
@settings(max_examples=50, deadline=None)
def test_pack_unpack_roundtrip(vals):
    import jax.numpy as jnp
    x = jnp.asarray(vals, jnp.int8)
    packed = lut.pack_int4(x)
    assert packed.shape[-1] == len(vals) // 2
    out = lut.unpack_int4(packed, signed=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_lut_general_multiplier_range():
    lo, hi = lut.luts_per_multiply_general(4)
    assert lo == 13 and hi == 28     # paper Sec. 3.5
