"""QuantizedLinear weight-code caching: quantize + pack once at load, never
per forward call (counted via ops.WEIGHT_QUANT_COUNT, which every weight
quantization event in the codebase funnels through)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.lutmul import ops
from repro.models.layers import QuantizedLinear, init_linear, linear


def test_quantized_linear_packs_once():
    p = init_linear(jax.random.PRNGKey(0), 32, 16)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32), jnp.float32)

    before = ops.WEIGHT_QUANT_COUNT
    qlin = QuantizedLinear(p, mode="w4a4_lut")
    assert ops.WEIGHT_QUANT_COUNT == before + 1      # once, at construction
    assert qlin.params["w_q"].dtype == jnp.uint8     # packed int4 codes

    ys = [qlin(x, compute_dtype=jnp.float32) for _ in range(3)]
    assert ops.WEIGHT_QUANT_COUNT == before + 1      # forwards: zero repacks

    # the uncached functional path re-quantizes on every call
    uncached = ops.WEIGHT_QUANT_COUNT
    for _ in range(3):
        y_un = linear(p, x, quant="w4a4_lut", compute_dtype=jnp.float32)
    assert ops.WEIGHT_QUANT_COUNT == uncached + 3

    # same quantizer grid -> the cached path reproduces the uncached output
    np.testing.assert_array_equal(np.asarray(ys[0]), np.asarray(y_un))
    np.testing.assert_array_equal(np.asarray(ys[0]), np.asarray(ys[-1]))


def test_quantized_linear_accepts_prequantized_leaf():
    from repro.serve.quantize import quantize_leaf
    p = init_linear(jax.random.PRNGKey(0), 16, 8, bias=True)
    leaf = quantize_leaf(p["w"], 8)
    leaf["b"] = p["b"]
    before = ops.WEIGHT_QUANT_COUNT
    qlin = QuantizedLinear(leaf, mode="w8a8")
    assert ops.WEIGHT_QUANT_COUNT == before          # no re-quantization
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16), jnp.float32)
    y = qlin(x, compute_dtype=jnp.float32)
    assert y.shape == (2, 8) and np.isfinite(np.asarray(y)).all()
