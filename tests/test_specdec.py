"""Bitplane-truncated self-speculative decoding.

The drafter is the SAME packed tmac weight codes sliced to their top
``draft_planes`` bitplanes (scale folded by ``2^(B-p)``), so it costs zero
extra weight memory; ``draft_k`` drafter steps are verified by ONE batched
``draft_k+1``-token target forward and the longest matching prefix commits.
At temperature 0 the argmax chain makes acceptance exact, so every
transcript here must be BIT-IDENTICAL to the non-speculative scheduler —
dense and paged, across mid-stream snapshots, injected-fault replays, crash
save/load, and the sharded (2x2 / 1x8) engines.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T
from repro.serve import Engine, Request, Scheduler, ServeConfig
from repro.serve.faults import Fault, FaultPlan
from repro.serve.paged import PagedLayout, PagePool

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _make(max_len=32, **scfg):
    cfg = dataclasses.replace(configs.get_config("qwen2-7b", smoke=True),
                              compute_dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params, ServeConfig(max_len=max_len, quant="w4a4_tmac",
                                    **scfg)


def _reqs(cfg, n=4, S=5, budget=9, eos_id=None):
    p = jax.random.randint(jax.random.PRNGKey(1), (n, S), 0, cfg.vocab)
    return [Request(prompt=np.asarray(p[i]).tolist(), max_new_tokens=budget,
                    eos_id=eos_id) for i in range(n)]


def _drain(sched, max_rounds=200):
    rounds = 0
    while sched.has_work:
        sched.step()
        rounds += 1
        assert rounds <= max_rounds
    sched.check_drained()
    return sorted((tuple(r.prompt), r.finish_reason, tuple(r.tokens))
                  for r in sched.finished)


# ---------------------------------------------------------------------------
# config / eligibility validation
# ---------------------------------------------------------------------------

def test_spec_config_validation():
    with pytest.raises(ValueError, match="draft_k"):
        ServeConfig(spec_decode=True, draft_k=0)
    with pytest.raises(ValueError, match="draft_planes"):
        ServeConfig(spec_decode=True, draft_planes=1)
    with pytest.raises(ValueError, match="max_len"):
        ServeConfig(spec_decode=True, draft_k=8, max_len=8)


def test_spec_requires_draftable_leaves():
    cfg, params, _ = _make()
    # w8a8 quantizes to int8 codes, not bitplanes: nothing to truncate
    with pytest.raises(ValueError, match="draftable"):
        Engine(cfg, params, ServeConfig(max_len=32, quant="w8a8",
                                        spec_decode=True))
    # spec=True on a non-spec engine is a usage error, not a silent fallback
    eng = Engine(cfg, params, ServeConfig(max_len=32, quant="w4a4_tmac"))
    with pytest.raises(ValueError, match="spec_decode"):
        eng.step(eng.init_cache(1), None, *[None] * 7, 0, 1, spec=True)


def test_spec_rejects_sliding_window():
    cfg = dataclasses.replace(configs.get_config("gemma2-2b", smoke=True),
                              compute_dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="sliding-window"):
        Engine(cfg, params, ServeConfig(max_len=32, quant="w4a4_tmac",
                                        spec_decode=True))


# ---------------------------------------------------------------------------
# temperature-0 bit-identity vs the non-speculative scheduler
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("paged", [False, True])
def test_spec_transcripts_bit_identical(paged):
    cfg, params, _ = _make()
    pkw = {"paged": True, "page_size": 4} if paged else {}

    def run(**kw):
        eng = Engine(cfg, params, ServeConfig(max_len=32, quant="w4a4_tmac",
                                              **pkw, **kw))
        sched = Scheduler(eng, slots=2, chunk=2)
        for r in _reqs(cfg):
            sched.submit(r)
        return _drain(sched), dict(sched.stats)

    want, _ = run()
    got, st = run(spec_decode=True, draft_k=3)
    assert got == want
    assert st["spec_rounds"] > 0
    assert st["spec_drafted"] >= st["spec_accepted"] >= 0


def test_spec_eos_truncation_bit_identical():
    """An EOS landing inside the accepted speculative block must cut the
    transcript at exactly the oracle's position (pos advances for the EOS
    token itself, tokens after it are discarded)."""
    cfg, params, scfg = _make()
    ref = Scheduler(Engine(cfg, params, scfg), slots=2, chunk=2)
    probe = _reqs(cfg)
    for r in probe:
        ref.submit(r)
    _drain(ref)
    eos = int(probe[0].tokens[3])        # a token the oracle really emits

    def run(**kw):
        eng = Engine(cfg, params, ServeConfig(max_len=32, quant="w4a4_tmac",
                                              **kw))
        sched = Scheduler(eng, slots=2, chunk=2)
        for r in _reqs(cfg, eos_id=eos):
            sched.submit(r)
        return _drain(sched)

    want = run()
    assert any(reason == "eos" for _, reason, _ in want)
    assert run(spec_decode=True, draft_k=3) == want


def test_spec_near_max_len_falls_back_and_matches():
    """Rows close to max_len can't fit a draft_k+1 block unclamped: those
    rounds must fall back to plain decode and still match the oracle."""
    cfg, params, _ = _make(max_len=16)

    def run(**kw):
        eng = Engine(cfg, params, ServeConfig(max_len=16, quant="w4a4_tmac",
                                              **kw))
        sched = Scheduler(eng, slots=2, chunk=2)
        for r in _reqs(cfg, n=2, S=5, budget=11):     # runs right to the rim
            sched.submit(r)
        return _drain(sched)

    assert run(spec_decode=True, draft_k=3) == run()


# ---------------------------------------------------------------------------
# fault replay / snapshot / crash recovery with speculation live
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["dispatch", "nan_logits"])
def test_spec_fault_replay_bit_identical(kind):
    cfg, params, _ = _make()
    kw = dict(max_len=32, quant="w4a4_tmac", spec_decode=True, draft_k=3,
              paged=True, page_size=4)
    ref = Scheduler(Engine(cfg, params, ServeConfig(**kw)), slots=2, chunk=2)
    for r in _reqs(cfg):
        ref.submit(r)
    want = _drain(ref)

    eng = Engine(cfg, params, ServeConfig(**kw))
    plan = FaultPlan([Fault(site="decode", index=3, kind=kind)])
    eng.set_fault_plan(plan)
    sched = Scheduler(eng, slots=2, chunk=2, snapshot_interval=1,
                      max_retries=3)
    for r in _reqs(cfg):
        sched.submit(r)
    try:
        got = _drain(sched)
    finally:
        eng.set_fault_plan(None)
    assert not plan.pending
    assert sched.stats["recoveries"] >= 1
    assert got == want


def test_spec_save_load_continues_token_identically(tmp_path):
    cfg, params, _ = _make()
    kw = dict(max_len=32, quant="w4a4_tmac", spec_decode=True, draft_k=3)
    ref = Scheduler(Engine(cfg, params, ServeConfig(**kw)), slots=2, chunk=2)
    for r in _reqs(cfg):
        ref.submit(r)
    want = _drain(ref)

    a = Scheduler(Engine(cfg, params, ServeConfig(**kw)), slots=2, chunk=2)
    for r in _reqs(cfg):
        a.submit(r)
    a.step()
    a.step()                              # save mid-stream, between rounds
    a.save(str(tmp_path))
    b = Scheduler(Engine(cfg, T.init_params(jax.random.PRNGKey(0), cfg),
                         ServeConfig(**kw)), slots=2, chunk=2)
    b.load(str(tmp_path))
    assert _drain(b) == want


# ---------------------------------------------------------------------------
# paged rollback of rejected speculation
# ---------------------------------------------------------------------------

def test_pool_trim_unmaps_speculative_tail():
    lay = PagedLayout(page_size=4, max_len=32, full_entries=8,
                      ring_entries=0, ring_len=0)
    pool = PagePool(4, lay)
    assert pool.admit(0, list(range(8))) == 0          # 2 full pages
    assert pool.ensure(0, 16)                          # + 2 speculative
    before = pool.allocated_pages
    assert pool.trim(0, 9) == 1                        # 9 tokens -> 3 pages
    assert pool.allocated_pages == before - 1
    assert pool.trim(0, 9) == 0                        # idempotent
    assert not pool.validate() and not pool.leaked_pages()
    # trim never reaches below the kept residency: the shared-prefix pages
    # of a second sharer survive the first sharer's trim
    assert pool.admit(1, list(range(8))) == 8          # full prefix hit
    pool.trim(1, 9)
    assert pool.table[0, 0] == pool.table[1, 0]
    pool.release(0)
    pool.release(1)
    assert pool.allocated_pages == 0 and not pool.leaked_pages()


def test_spec_paged_pool_drains_clean():
    """Speculative page growth + trim rollback across a full serve: zero
    allocated pages and zero unreachable refs at drain (check_drained
    asserts inside _drain)."""
    cfg, params, _ = _make()
    eng = Engine(cfg, params, ServeConfig(
        max_len=32, quant="w4a4_tmac", spec_decode=True, draft_k=3,
        paged=True, page_size=4, num_pages=24))
    sched = Scheduler(eng, slots=2, chunk=2)
    for r in _reqs(cfg, n=4, budget=9):
        sched.submit(r)
    _drain(sched)
    assert eng.pool.allocated_pages == 0


# ---------------------------------------------------------------------------
# sharded engines (8 fake CPU devices in a subprocess — the CI recipe)
# ---------------------------------------------------------------------------

_SHARDED_SPEC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, numpy as np
    from repro import configs
    from repro.launch.mesh import make_serving_mesh
    from repro.models import transformer as T
    from repro.serve import Engine, Request, Scheduler, ServeConfig, \\
        ShardedEngine

    cfg = dataclasses.replace(configs.get_config("qwen2-7b", smoke=True),
                              compute_dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 6), 0,
                                 cfg.vocab)

    def reqs():
        return [Request(prompt=np.asarray(prompts[i]).tolist(),
                        max_new_tokens=7) for i in range(4)]

    def drain(sched):
        rounds = 0
        while sched.has_work:
            sched.step()
            rounds += 1
            assert rounds <= 200
        sched.check_drained()

    # single-device dense NON-speculative oracle (same tmac codes)
    ref = Scheduler(Engine(cfg, params,
                           ServeConfig(max_len=32, quant="w4a4_tmac")),
                    slots=4, chunk=2)
    want = reqs()
    for r in want:
        ref.submit(r)
    drain(ref)
    want = [list(r.tokens) for r in want]

    def case(mesh_spec, paged):
        scfg = ServeConfig(max_len=32, quant="w4a4_tmac", spec_decode=True,
                           draft_k=3,
                           **({"paged": True, "page_size": 4} if paged
                              else {}))
        eng = ShardedEngine(cfg, params, scfg,
                            mesh=make_serving_mesh(mesh_spec))
        sched = Scheduler(eng, slots=4, chunk=2)
        got = reqs()
        for r in got:
            sched.submit(r)
        drain(sched)
        for i, r in enumerate(got):
            assert list(r.tokens) == want[i], \\
                (mesh_spec, paged, i, r.tokens, want[i])
        assert sched.stats["spec_rounds"] > 0
        sizes = tuple(f._cache_size() for f in eng._step_fns.values())
        assert sizes and all(s == 1 for s in sizes), (mesh_spec, sizes)
        print("OK", mesh_spec, "paged=" + str(paged), flush=True)

    case("2x2", False)
    case("2x2", True)
    case("1x8", True)
    print("ALL-OK")
""")


@pytest.mark.slow
def test_spec_sharded_bit_identical_subprocess():
    """Speculative ShardedEngine (2x2 / 1x8, dense + paged) vs the
    single-device dense non-speculative oracle: transcripts bit-identical
    (the tmac drafter rides the same row-parallel int32 psum as the
    target, so truncated-plane matmuls shard exactly too)."""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SHARDED_SPEC_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=1800)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "ALL-OK" in out.stdout, out.stdout
