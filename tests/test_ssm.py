"""Chunked SSD / RWKV6 recurrences vs naive step-by-step references."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ssm as S


def test_ssd_chunked_vs_naive():
    rng = np.random.default_rng(0)
    B, T, H, P, N = 2, 64, 3, 8, 5
    xs = jnp.asarray(rng.normal(size=(B, T, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, size=(B, T, H)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.1, 2.0, size=(H,)), jnp.float32)
    Bc = jnp.asarray(rng.normal(size=(B, T, N)), jnp.float32)
    Cc = jnp.asarray(rng.normal(size=(B, T, N)), jnp.float32)

    h = np.zeros((B, H, N, P))
    ys = []
    for t in range(T):
        dec = np.exp(np.asarray(a)[None] * np.asarray(dt[:, t]))
        h = h * dec[:, :, None, None] + np.einsum(
            "bn,bh,bhp->bhnp", np.asarray(Bc[:, t]), np.asarray(dt[:, t]),
            np.asarray(xs[:, t]))
        ys.append(np.einsum("bn,bhnp->bhp", np.asarray(Cc[:, t]), h))
    want = np.stack(ys, 1)
    got, h_final = S._ssd_chunked(xs, dt, a, Bc, Cc, chunk=16)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h_final), h, rtol=2e-5, atol=2e-5)


def test_mamba2_full_vs_decode():
    cfgk = dict(d_inner=32, d_state=8, n_heads=4)
    p = S.init_mamba2(jax.random.PRNGKey(0), 16, 32, 8, 4)
    B, T = 2, 20
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, 16))
    full, st_final = S.mamba2(p, x, compute_dtype=jnp.float32,
                              return_state=True, **cfgk)
    st = S.Mamba2State(h=jnp.zeros((B, 4, 8, 8)),
                       conv=jnp.zeros((B, 3, 32 + 16)))
    outs = []
    for t in range(T):
        y, st = S.mamba2_decode(p, x[:, t:t + 1], st,
                                compute_dtype=jnp.float32, **cfgk)
        outs.append(y)
    got = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st.h), np.asarray(st_final.h),
                               rtol=1e-4, atol=1e-4)


def test_rwkv6_full_vs_decode():
    d, nh = 32, 4
    p = S.init_rwkv6(jax.random.PRNGKey(0), d, nh, decay_lora=8)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 48, d))
    full, (S_final, xlast) = S.rwkv6_timemix(
        p, x, n_heads=nh, chunk=16, compute_dtype=jnp.float32,
        return_state=True)
    st = S.RWKVState(S=jnp.zeros((1, nh, 8, 8)),
                     x_prev_t=jnp.zeros((1, 1, d)),
                     x_prev_c=jnp.zeros((1, 1, d)))
    outs = []
    for t in range(48):
        y, st = S.rwkv6_timemix_decode(p, x[:, t:t + 1], st, n_heads=nh,
                                       compute_dtype=jnp.float32)
        outs.append(y)
    got = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st.S), np.asarray(S_final),
                               rtol=1e-4, atol=1e-4)


def test_rwkv6_odd_length_chunk_fallback():
    d, nh = 16, 2
    p = S.init_rwkv6(jax.random.PRNGKey(0), d, nh, decay_lora=4)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 17, d))
    y = S.rwkv6_timemix(p, x, n_heads=nh, chunk=32, compute_dtype=jnp.float32)
    assert y.shape == (1, 17, d)
    assert np.isfinite(np.asarray(y)).all()
