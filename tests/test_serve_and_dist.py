"""Serving engine end-to-end + partitioning specs + small-mesh integration
(8 fake devices in a subprocess so the main process stays single-device)."""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.dist import partitioning
from repro.dist.sharding import production_rules
from repro.models import transformer as T
from repro.serve.engine import Engine, ServeConfig

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_engine_generate_matches_forward_greedy():
    cfg = configs.get_config("qwen2-7b", smoke=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, ServeConfig(max_len=32))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab)
    out = eng.generate(prompts, max_new_tokens=4)
    assert out.shape == (2, 10)
    # greedy decode must match teacher-forced argmax on its own outputs
    logits, _ = T.forward(params, cfg, out[:, :-1])
    want = jnp.argmax(logits[:, 5:], axis=-1)
    np.testing.assert_array_equal(np.asarray(out[:, 6:]), np.asarray(want))


def test_engine_rwkv_generate():
    cfg = configs.get_config("rwkv6-1.6b", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, ServeConfig(max_len=24))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0, cfg.vocab)
    out = eng.generate(prompts, max_new_tokens=3)
    assert out.shape == (2, 8)


def test_param_specs_match_rules():
    from jax.sharding import PartitionSpec as P
    cfg = configs.get_config("qwen2-7b", smoke=True)
    params = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    rules = production_rules()
    rules["fsdp"] = "data"
    specs = partitioning.param_specs(params, rules)
    # stacked attn wq: [G, d, H*dh] -> (None, fsdp, model)
    assert specs["blocks"][0]["attn"]["wq"]["w"] == P(None, "data", "model")
    assert specs["blocks"][0]["attn"]["wo"]["w"] == P(None, "model", "data")
    assert specs["blocks"][0]["mlp"]["wi"]["w"] == P(None, "data", "model")
    assert specs["embed"]["emb"] == P("model", "data")
    assert specs["final_norm"]["scale"] == P()


def test_moe_param_specs_ep_vs_tp():
    from jax.sharding import PartitionSpec as P
    cfg = configs.get_config("qwen2-moe-a2.7b", smoke=True)
    params = jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    ep = production_rules()
    ep.update(expert="model", expert_mlp=None, fsdp="data")
    specs = partitioning.param_specs(params, ep)
    assert specs["blocks"][0]["moe"]["wi"] == P(None, "model", "data", None)
    tp = production_rules()
    tp.update(expert=None, expert_mlp="model", fsdp="data")
    specs = partitioning.param_specs(params, tp)
    assert specs["blocks"][0]["moe"]["wi"] == P(None, None, "data", "model")
    assert specs["blocks"][0]["moe"]["wo"] == P(None, None, "model", "data")


@pytest.mark.slow
def test_small_mesh_dryrun_subprocess():
    """Compile a smoke-config train step + decode step on a (2,4) fake mesh:
    proves the sharding rules produce a partitionable program end-to-end."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, json, sys
        import jax, jax.numpy as jnp
        from repro import configs
        from repro.dist.sharding import use_rules
        from repro.launch.mesh import rules_for
        from repro.launch.specs import build_cell
        from repro.roofline import analysis

        from repro.dist.sharding import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        orig = configs.get_config
        configs.get_config = lambda a, quant="none", **kw: orig(
            a, smoke=True, quant=quant)
        configs.SHAPES["_t"] = configs.ShapeSpec("_t", 64, 8, "train")
        configs.SHAPES["_d"] = configs.ShapeSpec("_d", 64, 8, "decode")
        results = {}
        for arch, shape in [("qwen2-7b", "_t"), ("mixtral-8x22b", "_t"),
                            ("gemma2-2b", "_d"), ("zamba2-2.7b", "_d")]:
            cfg = configs.get_config(arch)
            rules = rules_for(cfg, configs.SHAPES[shape].kind, shape)
            with mesh, use_rules(rules, mesh):
                cell = build_cell(arch, shape, mesh, rules)
                jf = jax.jit(cell["fn"], in_shardings=cell["in_shardings"],
                             out_shardings=cell["out_shardings"])
                compiled = jf.lower(*cell["args_sds"]).compile()
                cost = compiled.cost_analysis()
                terms = analysis.roofline_terms(cost, compiled.as_text())
                results[f"{arch}:{shape}"] = {
                    "flops": terms["hlo_flops_per_device"],
                    "ncoll": terms["n_collectives"],
                    "mem": compiled.memory_analysis().temp_size_in_bytes,
                }
        print("RESULTS" + json.dumps(results))
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [ln for ln in out.stdout.splitlines()
            if ln.startswith("RESULTS")][0]
    results = json.loads(line[len("RESULTS"):])
    assert len(results) == 4
    for k, v in results.items():
        assert v["flops"] > 0 and v["ncoll"] > 0, (k, v)


@pytest.mark.slow
def test_compressed_psum_multidevice_subprocess():
    """Error-feedback int8 psum across 8 fake devices: mean within int8
    quantization error of the exact mean, residual carries the rest."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.optim import grad_compress
        from repro.dist.sharding import make_mesh
        mesh = make_mesh((8,), ("dp",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
        r = jnp.zeros((8, 64))
        def f(g, r):
            out, r2 = grad_compress.compressed_psum(
                {"w": g[0]}, {"w": r[0]}, "dp")
            return out["w"][None], r2["w"][None]
        out, r2 = shard_map(f, mesh=mesh, in_specs=(P("dp"), P("dp")),
                            out_specs=(P("dp"), P("dp")))(g, r)
        exact = jnp.mean(g, axis=0)
        got = np.asarray(out[0])
        err = np.abs(got - np.asarray(exact)).max()
        scale = float(jnp.max(jnp.abs(g)) / 127.0)
        assert err <= scale + 1e-6, (err, scale)
        print("OK maxerr", err, "scale", scale)
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
