"""Pallas threshold-epilogue kernel vs oracle vs core/thresholds math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantization import A4
from repro.core.thresholds import BNParams, apply_thresholds, make_thresholds
from repro.kernels.thresholds import ops, ref


@pytest.mark.parametrize("M,N", [(8, 8), (100, 24), (256, 128), (33, 7)])
def test_threshold_kernel_vs_oracle(M, N):
    rng = np.random.default_rng(M + N)
    acc = jnp.asarray(rng.integers(-500, 500, (M, N)), jnp.int32)
    thr = jnp.sort(jnp.asarray(rng.normal(0, 100, (N, 15)), jnp.float32), axis=1)
    sign = jnp.asarray(rng.choice([-1.0, 1.0], N), jnp.float32)
    want = ref.threshold_ref(acc, thr, sign)
    got = ops.threshold(acc, thr, sign, backend="interpret")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_threshold_kernel_matches_core_streamlining():
    """Kernel output == core/thresholds.apply_thresholds (+ qmin offset) on a
    real streamlined stage."""
    key = jax.random.PRNGKey(0)
    N = 16
    bn = BNParams(gamma=jax.random.uniform(key, (N,), minval=0.2, maxval=2.0),
                  beta=jnp.zeros((N,)), mean=jnp.zeros((N,)),
                  var=jnp.ones((N,)))
    t, sign = make_thresholds(jnp.full((N,), 0.02), bn, A4,
                              jnp.full((N,), 0.1))
    acc = jnp.asarray(np.random.default_rng(1).integers(-400, 400, (32, N)),
                      jnp.int32)
    core = apply_thresholds(acc, t, sign, A4)
    kern = ops.threshold(acc, t, sign, backend="interpret") + A4.qmin
    np.testing.assert_array_equal(np.asarray(core), np.asarray(kern))


def test_fused_lutmul_threshold_stage():
    from repro.core.lut import pack_int4
    rng = np.random.default_rng(2)
    M, K, N = 16, 32, 8
    a = rng.integers(0, 16, (M, K))
    w = rng.integers(-8, 8, (K, N)).astype(np.int8)
    a_codes = jnp.asarray(a.astype(np.uint8))
    w_packed = pack_int4(jnp.asarray(w).T).T
    thr = jnp.sort(jnp.asarray(rng.normal(0, 200, (N, 15)), jnp.float32), 1)
    sign = jnp.ones((N,), jnp.float32)
    got = ops.lutmul_threshold_stage(a_codes, w_packed, thr, sign,
                                     backend="interpret")
    acc = a.astype(np.int32) @ w.astype(np.int32)
    want = np.sum(acc[:, :, None] >= np.asarray(thr)[None], axis=-1)
    np.testing.assert_array_equal(np.asarray(got), want)
