"""Pre-quantized serving (serve/quantize.py): the deployment path of the
paper's technique — weights stored as integer codes, LUT/MXU integer matmul,
and the LUT path bit-identical to the integer-dot path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T
from repro.serve.quantize import dequantize_weight, quantize_params_for_serving


@pytest.mark.parametrize("mode", ["w8a8", "w4a4_mxu"])
def test_roundtrip_error_bounded(mode):
    cfg = configs.get_config("qwen2-7b", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    q = quantize_params_for_serving(params, mode=mode)
    leaf = q["blocks"][0]["attn"]["wq"]
    assert "w_q" in leaf and "w_scale" in leaf
    back = dequantize_weight(leaf, jnp.float32)
    orig = params["blocks"][0]["attn"]["wq"]["w"]
    rel = float(jnp.linalg.norm(back - orig) / jnp.linalg.norm(orig))
    assert rel < (0.02 if mode == "w8a8" else 0.15)
    # packed int4 halves the K dim
    if mode.startswith("w4"):
        assert leaf["w_q"].dtype == jnp.uint8
        assert leaf["w_q"].shape[-2] == orig.shape[-2] // 2
    # norms untouched
    assert "scale" in q["blocks"][0]["ln1"]


def test_lut_serving_identical_to_mxu_serving():
    """Same integer codes -> the table-gather path and the int-dot path must
    produce bitwise-identical logits (the kernel-equivalence property,
    end-to-end)."""
    params = T.init_params(jax.random.PRNGKey(0),
                           configs.get_config("qwen2-7b", smoke=True))
    q = quantize_params_for_serving(params, mode="w4a4_mxu")
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 512)
    cfg_mxu = configs.get_config("qwen2-7b", smoke=True, quant="w4a4_mxu")
    cfg_lut = configs.get_config("qwen2-7b", smoke=True, quant="w4a4_lut")
    l_mxu, _ = T.prefill(q, cfg_mxu, toks)
    l_lut, _ = T.prefill(q, cfg_lut, toks)
    np.testing.assert_array_equal(np.asarray(l_mxu), np.asarray(l_lut))


def test_quantized_moe_serving():
    cfg = configs.get_config("mixtral-8x22b", smoke=True, quant="w4a4_mxu")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    q = quantize_params_for_serving(params, mode="w4a4_mxu")
    assert "w_q" in q["blocks"][0]["moe"]["wi"]
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    logits, _ = T.prefill(q, cfg, toks)
    assert np.isfinite(np.asarray(logits)).all()


def test_split_head_params_forward():
    cfg = configs.get_config("qwen2-7b", smoke=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32",
                              split_head_params=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, cfg.vocab)
    full, _ = T.forward(params, cfg, toks)
    pl, _ = T.prefill(params, cfg, toks[:, :9])
    np.testing.assert_allclose(np.asarray(pl),
                               np.asarray(full[:, 8], np.float32),
                               rtol=5e-4, atol=5e-4)
