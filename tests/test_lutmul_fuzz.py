"""Property-based bit-exactness fuzz for the lutmul kernel family.

Every drawn (M, K, N, weight bits, block shape, contract dtype) combination
must make the Pallas kernels (interpret mode — the CPU lowering of the TPU
kernel) agree EXACTLY with the pure-jnp oracles in ``kernels/lutmul/ref.py``:
integer accumulators bit for bit, fused-dequant outputs bit for bit against
the oracle's epilogue order.  Runs under real hypothesis when installed, or
the deterministic shim in ``tests/_hypothesis_stub.py`` (fixed seed) —
``REPRO_FUZZ_EXAMPLES`` bounds the example count so CI stays fast.
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.lut import (contraction_table, decode_planes, pack_int4,
                            plane_decomposition, unpack_bitplanes)
from repro.kernels.lutmul import kernel, ref
from repro.kernels.lutmul import ops as lut_ops

N_EXAMPLES = int(os.environ.get("REPRO_FUZZ_EXAMPLES", "8"))

# (bm, bn, bk) — (8, 128, 128)-aligned like ops._CANDIDATES, small enough
# that interpret mode stays fast
BLOCKS = st.sampled_from([(8, 128, 128), (16, 128, 128), (8, 256, 128),
                          (8, 128, 256)])
DIMS = st.tuples(st.integers(1, 24),                 # M
                 st.integers(1, 96).map(lambda k: 2 * k),   # K (even)
                 st.integers(1, 140))                # N
CONTRACT_DTYPE = st.sampled_from(["float32", "int8"])


def _codes(rng: np.random.Generator, m: int, k: int) -> np.ndarray:
    """Random 4-bit activation codes (two's-complement nibbles in uint8)."""
    return (rng.integers(-8, 8, (m, k)) & 0xF).astype(np.uint8)


def _packed_weights(rng: np.random.Generator, k: int, n: int) -> np.ndarray:
    w = rng.integers(-8, 8, (k, n)).astype(np.int8)
    return np.asarray(pack_int4(jnp.asarray(w).T).T)


def _int8_vals(rng: np.random.Generator, shape, bits: int) -> np.ndarray:
    qmax = 2 ** (bits - 1) - 1
    return rng.integers(-qmax, qmax + 1, shape).astype(np.int8)


@given(DIMS, st.integers(0, 2 ** 31 - 1))
@settings(max_examples=N_EXAMPLES, deadline=None)
def test_fuzz_lutmul_interpret_matches_ref(dims, seed):
    """ops.lutmul (onehot Pallas kernel, interpret) == ref, any shape —
    padding, block clipping, and the one-hot contraction all exact."""
    m, k, n = dims
    rng = np.random.default_rng(seed)
    a = _codes(rng, m, k)
    wp = _packed_weights(rng, k, n)
    got = lut_ops.lutmul(jnp.asarray(a), jnp.asarray(wp),
                         backend="interpret")
    want = ref.lutmul_ref(jnp.asarray(a), jnp.asarray(wp))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(DIMS, st.sampled_from([4, 8]), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=N_EXAMPLES, deadline=None)
def test_fuzz_int_matmul_interpret_matches_ref(dims, bits, seed):
    """ops.int_matmul (interpret) == ref over 4- and 8-bit value ranges."""
    m, k, n = dims
    rng = np.random.default_rng(seed)
    a = _int8_vals(rng, (m, k), bits)
    w = _int8_vals(rng, (k, n), bits)
    got = lut_ops.int_matmul(jnp.asarray(a), jnp.asarray(w),
                             backend="interpret")
    want = ref.int_matmul_ref(jnp.asarray(a), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(BLOCKS, CONTRACT_DTYPE, st.integers(0, 2 ** 31 - 1))
@settings(max_examples=N_EXAMPLES, deadline=None)
def test_fuzz_onehot_contract_dtype_exact(blocks, contract_dtype, seed):
    """The one-hot/bitplane contraction itself is exact in BOTH contract
    dtypes: float32 (interpret-mode path) and int8 (the TPU MXU path) —
    the int8 variant is what real hardware runs, so the fuzz must pin it."""
    bm, bn, bk = blocks
    rng = np.random.default_rng(seed)
    a = _codes(rng, bm, bk).astype(np.int32)
    wp = _packed_weights(rng, bk, bn)
    table = jnp.asarray(contraction_table(a_signed=True), jnp.int32)
    acc = kernel._onehot_contract(jnp.asarray(a), jnp.asarray(wp), table,
                                  contract_dtype=jnp.dtype(contract_dtype))
    want = ref.lutmul_ref(jnp.asarray(a.astype(np.uint8)), jnp.asarray(wp))
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(want))


@given(BLOCKS, st.integers(1, 2), st.integers(1, 2), st.integers(1, 2),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=N_EXAMPLES, deadline=None)
def test_fuzz_lutmul_block_shapes_exact(blocks, gm, gn, gk, seed):
    """Explicit (bm, bn, bk) sweep through the raw Pallas entry point on
    multi-block grids: the K-accumulation order and block indexing never
    change the integer result."""
    bm, bn, bk = blocks
    M, N, K = gm * bm, gn * bn, gk * bk
    rng = np.random.default_rng(seed)
    a = _codes(rng, M, K)
    wp = _packed_weights(rng, K, N)
    table = jnp.asarray(contraction_table(a_signed=True), jnp.int32)
    got = kernel.lutmul_pallas(jnp.asarray(a), jnp.asarray(wp), table,
                               bm=bm, bn=bn, bk=bk, interpret=True)
    want = ref.lutmul_ref(jnp.asarray(a), jnp.asarray(wp))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(BLOCKS, st.sampled_from(["lut", "int"]),
       st.sampled_from(["float32", "bfloat16"]),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=N_EXAMPLES, deadline=None)
def test_fuzz_fused_dequant_matches_scaled_ref(blocks, which, out_dtype,
                                               seed):
    """Fused-epilogue kernels == the scaled oracle bit for bit: the in-kernel
    rescale must apply the exact epilogue order ``ref.scaled_lutmul_ref``
    documents, in both output dtypes."""
    bm, bn, bk = blocks
    M, N, K = bm, bn, 2 * bk                  # 2 K-blocks: epilogue at k=nk-1
    rng = np.random.default_rng(seed)
    a = _codes(rng, M, K)
    wp = _packed_weights(rng, K, N)
    a_scale = jnp.asarray(rng.uniform(1e-3, 1.0, (M, 1)), jnp.float32)
    w_scale = jnp.asarray(rng.uniform(1e-3, 1.0, (1, N)), jnp.float32)
    od = jnp.dtype(out_dtype)
    if which == "lut":
        table = jnp.asarray(contraction_table(a_signed=True), jnp.int32)
        got = kernel.lutmul_fused_pallas(
            jnp.asarray(a), jnp.asarray(wp), table, a_scale, w_scale,
            bm=bm, bn=bn, bk=bk, out_dtype=od, interpret=True)
        want = ref.scaled_lutmul_ref(jnp.asarray(a), jnp.asarray(wp),
                                     a_scale, w_scale, out_dtype=od)
    else:
        w = np.asarray(ref.decode_codes(jnp.asarray(_codes(rng, K, N)))
                       ).astype(np.int8)
        a8 = _int8_vals(rng, (M, K), 8)
        got = kernel.int_matmul_fused_pallas(
            jnp.asarray(a8), jnp.asarray(w), a_scale, w_scale,
            bm=bm, bn=bn, bk=bk, out_dtype=od, interpret=True)
        acc = ref.int_matmul_ref(jnp.asarray(a8), jnp.asarray(w))
        want = (acc.astype(jnp.float32) * a_scale * w_scale).astype(od)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# tmac: K must pack into bitplane bytes (K % 8 == 0); every weight width of
# the sub-4-bit serving family, both activation widths (a4 -> g=2 grouped
# tables, a8 -> g=1 direct contraction)
WBITS = st.sampled_from([1, 2, 3, 4, "ternary"])
TMAC_DIMS = st.tuples(st.integers(1, 24),                    # M
                      st.integers(1, 24).map(lambda k: 8 * k),   # K (mult 8)
                      st.integers(1, 140))                   # N


@given(TMAC_DIMS, WBITS, st.sampled_from([4, 8]),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=N_EXAMPLES, deadline=None)
def test_fuzz_tmac_matches_ref_and_dense_oracle(dims, wbits, abits, seed):
    """ops.lutmul_tmac (interpret kernel) == the faithful group-table oracle
    ``ref.lutmul_tmac_ref`` == the decoded dense int matmul, for every
    weight width in the family and both activation widths — padding, plane
    accumulation order, and the per-row const correction all exact."""
    m, k, n = dims
    rng = np.random.default_rng(seed)
    a = jnp.asarray(_int8_vals(rng, (m, k), abits))
    wf = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    planes, _ = lut_ops.quantize_weights_planes(wf, wbits)
    g = lut_ops.tmac_group_size(abits)
    got = lut_ops.lutmul_tmac(a, planes, wbits, abits=abits,
                              backend="interpret")
    want = ref.lutmul_tmac_ref(a, planes, wbits, g=g)
    dense = decode_planes(unpack_bitplanes(planes), wbits)
    oracle = a.astype(jnp.int32) @ dense.astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(oracle))


@given(BLOCKS, WBITS, st.sampled_from(["float32", "bfloat16"]),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=N_EXAMPLES, deadline=None)
def test_fuzz_tmac_fused_matches_scaled_oracle(blocks, wbits, out_dtype,
                                               seed):
    """The fused-dequant tmac kernel == the scaled dense oracle bit for bit
    on multi-K-block grids (epilogue fires at k = nk-1), in both output
    dtypes."""
    bm, bn, bk = blocks
    M, N, K = bm, bn, 2 * bk
    rng = np.random.default_rng(seed)
    a = jnp.asarray(_int8_vals(rng, (M, K), 4))
    wf = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    planes, _ = lut_ops.quantize_weights_planes(wf, wbits)
    a_scale = jnp.asarray(rng.uniform(1e-3, 1.0, (M, 1)), jnp.float32)
    w_scale = jnp.asarray(rng.uniform(1e-3, 1.0, (1, N)), jnp.float32)
    _, coeffs, const = plane_decomposition(wbits)
    od = jnp.dtype(out_dtype)
    got = kernel.lutmul_tmac_fused_pallas(
        a, planes, a_scale, w_scale, coeffs=coeffs, const=const, g=2,
        bm=bm, bn=bn, bk=bk, out_dtype=od, interpret=True)
    dense = decode_planes(unpack_bitplanes(planes), wbits)
    acc = a.astype(jnp.int32) @ dense.astype(jnp.int32)
    want = (acc.astype(jnp.float32) * a_scale * w_scale).astype(od)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(TMAC_DIMS, WBITS, st.integers(0, 2 ** 31 - 1))
@settings(max_examples=N_EXAMPLES, deadline=None)
def test_fuzz_plane_prefix_is_low_width_code(dims, wbits, seed):
    """The self-speculative drafter's algebra: the top-``keep`` plane prefix
    of a wB tmac weight IS a valid w(keep) tmac operand whose decode is
    exactly ``floor(code / 2^(B-keep))`` of the full code — every truncated
    code lands in the keep-bit range, the residual is bounded by the dropped
    planes' mass, and the tmac kernel contracts the sliced planes exactly
    like their decoded dense codes.  Ternary and w1 have no positional
    prefix and must refuse."""
    m, k, n = dims
    rng = np.random.default_rng(seed)
    wf = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    planes, _ = lut_ops.quantize_weights_planes(wf, wbits)
    if wbits in (1, "ternary"):
        with pytest.raises(ValueError):
            lut_ops.truncate_planes(planes, wbits, 2)
        return
    with pytest.raises(ValueError):                    # keep == B: no draft
        lut_ops.truncate_planes(planes, wbits, wbits)
    full = np.asarray(decode_planes(unpack_bitplanes(planes), wbits))
    for keep in range(2, wbits):
        sliced, kept, mult = lut_ops.truncate_planes(planes, wbits, keep)
        assert (kept, mult) == (keep, 2 ** (wbits - keep))
        low = np.asarray(decode_planes(unpack_bitplanes(sliced), keep))
        qmax = 2 ** (keep - 1)
        assert low.min() >= -qmax and low.max() <= qmax - 1
        err = full - mult * low
        assert err.min() >= 0 and err.max() <= mult - 1
        a = jnp.asarray(_int8_vals(rng, (m, k), 4))
        got = lut_ops.lutmul_tmac(a, sliced, keep, abits=4,
                                  backend="interpret")
        oracle = np.asarray(a, np.int32) @ low.astype(np.int32)
        np.testing.assert_array_equal(np.asarray(got), oracle)


@given(st.tuples(st.integers(1, 8), st.integers(1, 32).map(lambda k: 2 * k),
                 st.integers(1, 48)),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=N_EXAMPLES, deadline=None)
def test_fuzz_gather_impl_matches_ref(dims, seed):
    """The retained serial table-gather baseline stays bit-exact too (small
    dims: it is the slow A/B kernel)."""
    m, k, n = dims
    rng = np.random.default_rng(seed)
    a = _codes(rng, m, k)
    wp = _packed_weights(rng, k, n)
    got = lut_ops.lutmul_gather(jnp.asarray(a), jnp.asarray(wp),
                                backend="interpret")
    want = ref.lutmul_ref(jnp.asarray(a), jnp.asarray(wp))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
