"""Multi-threshold streamlining == float BN+quantize, exactly, on integer
accumulators (the property FINN streamlining relies on)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.quantization import A4
from repro.core.thresholds import (BNParams, apply_thresholds,
                                   float_reference, make_thresholds)


def _check(gamma, beta, mean, var, acc_scale, out_scale, accs):
    C = len(gamma)
    bn = BNParams(gamma=jnp.asarray(gamma, jnp.float32),
                  beta=jnp.asarray(beta, jnp.float32),
                  mean=jnp.asarray(mean, jnp.float32),
                  var=jnp.asarray(var, jnp.float32))
    acc_scale = jnp.asarray(acc_scale, jnp.float32)
    out_scale = jnp.asarray(out_scale, jnp.float32)
    acc = jnp.asarray(accs, jnp.int32).reshape(-1, C)
    t, sign = make_thresholds(acc_scale, bn, A4, out_scale)
    got = apply_thresholds(acc, t, sign, A4)
    want = float_reference(acc, acc_scale, bn, A4, out_scale)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(
    gamma=st.lists(st.floats(0.05, 4.0), min_size=3, max_size=3),
    beta=st.lists(st.floats(-2, 2), min_size=3, max_size=3),
    mean=st.lists(st.floats(-2, 2), min_size=3, max_size=3),
    var=st.lists(st.floats(0.05, 4.0), min_size=3, max_size=3),
    accs=st.lists(st.integers(-512, 512), min_size=12, max_size=12),
)
@settings(max_examples=100, deadline=None)
def test_threshold_equivalence_positive_gamma(gamma, beta, mean, var, accs):
    _check(gamma, beta, mean, var,
           acc_scale=[0.01, 0.02, 0.05], out_scale=[0.1, 0.2, 0.05], accs=accs)


@given(
    gamma=st.lists(st.floats(-4.0, -0.05), min_size=2, max_size=2),
    accs=st.lists(st.integers(-512, 512), min_size=8, max_size=8),
)
@settings(max_examples=50, deadline=None)
def test_threshold_equivalence_negative_gamma(gamma, accs):
    """Negative BN slope flips the comparisons; the sign channel handles it."""
    _check(gamma, beta=[0.3, -0.4], mean=[0.1, 0.2], var=[1.0, 0.5],
           acc_scale=[0.02, 0.03], out_scale=[0.1, 0.07], accs=accs)


def test_thresholds_no_bn():
    acc = jnp.arange(-100, 100, dtype=jnp.int32).reshape(-1, 1)
    t, sign = make_thresholds(jnp.asarray([0.05]), None, A4,
                              jnp.asarray([0.25]))
    got = apply_thresholds(acc, t, sign, A4)
    want = float_reference(acc, jnp.asarray([0.05]), None, A4,
                           jnp.asarray([0.25]))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
