"""Fault tolerance: checkpoint round trips, atomicity, failure-injected
restart producing the identical loss trajectory."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.ckpt import checkpoint
from repro.data import pipeline
from repro.models import transformer as T
from repro.train import loop
from repro.train.step import TrainConfig


def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.asarray([1, 2, 3], jnp.int32)}}


def test_checkpoint_roundtrip_bitwise(tmp_path):
    t = _tree()
    checkpoint.save(str(tmp_path), 7, t, extra={"note": "x"})
    restored, extra = checkpoint.restore(str(tmp_path), t)
    assert extra["note"] == "x"
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_latest(tmp_path):
    t = _tree()
    th = checkpoint.save(str(tmp_path), 1, t, async_save=True)
    th.join()
    checkpoint.save(str(tmp_path), 5, t)
    assert checkpoint.latest_step(str(tmp_path)) == 5


def test_checkpoint_ignores_uncommitted(tmp_path):
    t = _tree()
    checkpoint.save(str(tmp_path), 3, t)
    # simulate a crash mid-save: directory without _COMMITTED
    os.makedirs(tmp_path / "step_00000009")
    assert checkpoint.latest_step(str(tmp_path)) == 3


def test_checkpoint_structure_mismatch_detected(tmp_path):
    checkpoint.save(str(tmp_path), 1, _tree())
    bad = {"a": jnp.zeros((3, 4)), "b": {"WRONG": jnp.zeros(3)}}
    with pytest.raises(ValueError):
        checkpoint.restore(str(tmp_path), bad)


@pytest.mark.slow
def test_failure_injection_resumes_identically(tmp_path):
    """Loss trajectory with an injected failure + restart == uninterrupted
    run (determinism through (seed, step, shard) data + committed ckpts)."""
    cfg = configs.get_config("minicpm-2b", smoke=True)
    dcfg = pipeline.DataConfig(seed=3, vocab=cfg.vocab, seq_len=16,
                               global_batch=4)
    def init_fn():
        return T.init_params(jax.random.PRNGKey(0), cfg)
    tcfg = TrainConfig(total_steps=12, peak_lr=1e-3, warmup=2)

    r1 = loop.run(cfg, init_fn, dcfg, tcfg,
                  loop.RunConfig(steps=10, ckpt_every=3,
                                 ckpt_dir=str(tmp_path / "a"),
                                 async_ckpt=False))
    r2 = loop.run(cfg, init_fn, dcfg, tcfg,
                  loop.RunConfig(steps=10, ckpt_every=3,
                                 ckpt_dir=str(tmp_path / "b"),
                                 async_ckpt=False, fail_at_step=7))
    assert r2["restarts"] == 1
    l1 = {m["step"]: m["loss"] for m in r1["history"]}
    l2 = {m["step"]: m["loss"] for m in r2["history"]}
    # steps re-run after restart overwrite; final losses per step must agree
    for s in range(10):
        np.testing.assert_allclose(l1[s], l2[s], rtol=1e-6,
                                   err_msg=f"step {s}")


def test_elastic_restore_reshards(tmp_path):
    """Save under one 'topology' (shard count), restore under another —
    params identical, data pipeline re-shards deterministically."""
    t = _tree()
    checkpoint.save(str(tmp_path), 2, t)
    restored, _ = checkpoint.restore(str(tmp_path), t)
    np.testing.assert_array_equal(np.asarray(t["a"]), np.asarray(restored["a"]))
    # data: global batch assembled from 2 shards == from 4 shards
    d2 = [pipeline.lm_batch(pipeline.DataConfig(seed=1, global_batch=8,
                                                n_shards=2, shard=i), 5)
          for i in range(2)]
    d4 = [pipeline.lm_batch(pipeline.DataConfig(seed=1, global_batch=8,
                                                n_shards=4, shard=i), 5)
          for i in range(4)]
    g2 = np.concatenate([b["tokens"] for b in d2])
    g4 = np.concatenate([b["tokens"] for b in d4])
    assert g2.shape == g4.shape == (8, 128)


def test_qat_eval_weight_code_cache():
    """Eval of the deployed (integer-code) model quantizes + packs weights
    ONCE per evaluation — never per eval batch (the QuantizedLinear
    weight-code cache, asserted via ops.WEIGHT_QUANT_COUNT)."""
    from repro.kernels.lutmul import ops
    cfg = configs.get_config("minicpm-2b", smoke=True)
    dcfg = pipeline.DataConfig(seed=3, vocab=cfg.vocab, seq_len=16,
                               global_batch=4)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    evaluate = loop.make_eval_fn(cfg, "w4a4_mxu")
    b1 = [pipeline.lm_batch(dcfg, 10 ** 6)]
    b3 = [pipeline.lm_batch(dcfg, 10 ** 6 + i) for i in range(3)]
    c0 = ops.WEIGHT_QUANT_COUNT
    l1 = evaluate(params, b1)
    d1 = ops.WEIGHT_QUANT_COUNT - c0
    c0 = ops.WEIGHT_QUANT_COUNT
    l3 = evaluate(params, b3)
    d3 = ops.WEIGHT_QUANT_COUNT - c0
    assert d1 == d3 > 0          # quantization events independent of #batches
    assert np.isfinite([l1, l3]).all()


def test_loop_runs_periodic_qat_eval(tmp_path):
    cfg = configs.get_config("minicpm-2b", smoke=True)
    dcfg = pipeline.DataConfig(seed=3, vocab=cfg.vocab, seq_len=16,
                               global_batch=4)
    r = loop.run(cfg, lambda: T.init_params(jax.random.PRNGKey(0), cfg), dcfg,
                 TrainConfig(total_steps=4, warmup=1),
                 loop.RunConfig(steps=4, ckpt_every=10,
                                ckpt_dir=str(tmp_path), eval_every=2,
                                eval_batches=1))
    evs = [m.get("eval_loss") for m in r["history"]]
    assert evs[1] is not None and evs[3] is not None
    assert evs[0] is None and evs[2] is None


def test_straggler_monitor():
    from repro.dist.straggler import StragglerConfig, StragglerMonitor
    mon = StragglerMonitor(StragglerConfig(threshold=1.5, patience=2))
    for _ in range(5):
        for h in ("h0", "h1", "h2", "h3"):
            mon.record(h, 1.0 if h != "h2" else 2.5)
        rep = mon.evaluate()
    assert rep["exclude"] == ["h2"]
    assert "h2" in rep["slow"]
