"""Roofline parser + the paper's analytic FPGA model (Eq. 1/2, Fig. 1,
Table 2 reproduction checks)."""
import pytest

from repro.core import fpga_model as F
from repro.roofline import analysis

HLO = """
HloModule test
  %ar = f32[256,1024]{1,0} all-reduce(f32[256,1024]{1,0} %x), replica_groups={{0,1,2,3}}, to_apply=%sum
  %ag = bf16[64,2048]{1,0} all-gather(bf16[64,128]{1,0} %y), replica_groups=[16,16]<=[256], dimensions={1}
  %rs = f32[16,64]{1,0} reduce-scatter(f32[16,1024]{1,0} %z), replica_groups=[1,16]<=[16], dimensions={1}
  %cp = bf16[8,128]{1,0} collective-permute(bf16[8,128]{1,0} %w), source_target_pairs={{0,1},{1,0}}
  %a2a = f32[32,32]{1,0} all-to-all(f32[32,32]{1,0} %v), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
"""


def test_collective_parser():
    colls = analysis.parse_collectives(HLO)
    by = {c.op: c for c in colls}
    assert len(colls) == 5
    ar = by["all-reduce"]
    assert ar.result_bytes == 256 * 1024 * 4 and ar.group_size == 4
    assert ar.link_bytes == pytest.approx(2 * 3 / 4 * 256 * 1024 * 4)
    ag = by["all-gather"]
    assert ag.group_size == 16
    assert ag.link_bytes == pytest.approx(15 / 16 * 64 * 2048 * 2)
    rs = by["reduce-scatter"]
    assert rs.link_bytes == pytest.approx(15 * 16 * 64 * 4)
    cp = by["collective-permute"]
    assert cp.link_bytes == 8 * 128 * 2
    a2a = by["all-to-all"]
    assert a2a.link_bytes == pytest.approx(7 / 8 * 32 * 32 * 4)


def test_roofline_terms_and_dominance():
    cost = {"flops": 197e12 * 0.5, "bytes accessed": 819e9 * 2.0}
    terms = analysis.roofline_terms(cost, HLO)
    assert terms["compute_s"] == pytest.approx(0.5)
    assert terms["memory_s"] == pytest.approx(2.0)
    assert analysis.dominant(terms) == "memory"


def test_model_flops():
    assert analysis.model_flops("train", 1e9, 8, 1024) == 6e9 * 8 * 1024
    assert analysis.model_flops("decode", 1e9, 128, 4096) == 2e9 * 128


# ---------------------------------------------------------------------------
# the paper's FPGA claims
# ---------------------------------------------------------------------------

def test_eq1_dsp_peak():
    # Eq (1) at the paper's 333 MHz, 4-bit packing p=4, all 9024 DSPs
    peak = F.dsp_peak_ops(F.U280, bits=4)
    assert peak == pytest.approx(4 * 9024 * 2 * 333e6)


def test_lutmul_peak_exceeds_dsp_peak():
    """The headline claim: LUT-based multiplication raises the roofline."""
    for overhead in (1.0, 2.0, 3.24):     # 3.24 = Fig.6 measured overhead
        lut_peak = F.lutmul_peak_ops(F.U280, bits=4, lut_overhead=overhead)
        dsp_peak = F.dsp_peak_ops(F.U280, bits=4)
        assert lut_peak > dsp_peak, overhead


def test_fig1_ridge_points():
    r = F.roofline(F.U280, bits=4, frac=1 / 64)
    assert r["lutmul_peak_ops"] > r["dsp_peak_ops"]
    # both rooflines meet bandwidth at their ridge intensity
    for kind in ("dsp", "lutmul"):
        ridge = r[f"{kind}_ridge_intensity"]
        at = r[f"{kind}_attainable"](ridge)
        assert at == pytest.approx(r[f"{kind}_peak_ops"], rel=1e-6)
        assert r[f"{kind}_attainable"](ridge / 10) == pytest.approx(
            r[f"{kind}_peak_ops"] / 10, rel=1e-6)


def test_folding_respects_budget_and_balances():
    from repro.models.mobilenet import MobileNetConfig, fpga_layer_table
    layers = fpga_layer_table(MobileNetConfig())
    res = F.balance_folding(layers, lut_budget=500_000, freq_hz=333e6,
                            lut_overhead=2.0, full_parallel_prefix=15)
    assert res["total_luts"] <= 500_000
    assert res["fps"] > 0
    # bottleneck stage defines fps
    assert res["fps"] == pytest.approx(333e6 / res["bottleneck_cycles"])


def test_mobilenet_macs_match_paper_ops():
    """Paper Table 2: 978.6 GOPS at 1627 FPS -> ~0.6 GOPs/frame.  Our layer
    table must reproduce MobileNetV2's MAC count (~300M MACs)."""
    from repro.models.mobilenet import MobileNetConfig, fpga_layer_table
    layers = fpga_layer_table(MobileNetConfig())
    macs = sum(lyr.macs for lyr in layers)
    assert 280e6 < macs < 330e6, macs / 1e6
