"""benchmarks.run --diff / --fail-on-regress: structured deltas + the gate.

``diff_records`` must report baseline benchmarks missing from the run (a
silently dropped benchmark used to diff clean) and ``gate_regressions``
turns deltas into CI pass/fail.
"""
import json

import pytest

from benchmarks.run import diff_records, gate_regressions


def _baseline(tmp_path, rows):
    p = tmp_path / "base.json"
    p.write_text(json.dumps({"rows": rows}))
    return str(p)


def _rec(name, ms, gops=None):
    return {"name": name, "median_ms": ms, "gops": gops, "derived": ""}


def test_diff_reports_missing_and_new(tmp_path, capsys):
    base = _baseline(tmp_path, [_rec("a", 1.0), _rec("dropped", 2.0)])
    diffs = diff_records([_rec("a", 1.1), _rec("fresh", 3.0)], base)
    by_name = {d["name"]: d for d in diffs}
    assert by_name["a"]["status"] == "ok"
    assert by_name["a"]["delta_ms_pct"] == pytest.approx(10.0)
    assert by_name["fresh"]["status"] == "new"
    assert by_name["dropped"]["status"] == "missing"
    out = capsys.readouterr().out
    assert "dropped,MISSING" in out and "fresh,NEW" in out


def test_gate_passes_within_threshold(tmp_path):
    base = _baseline(tmp_path, [_rec("a", 1.0), _rec("b", 2.0)])
    diffs = diff_records([_rec("a", 1.2), _rec("b", 1.5)], base)
    assert gate_regressions(diffs, 25.0) == []


def test_gate_fails_on_slowdown_beyond_threshold(tmp_path):
    base = _baseline(tmp_path, [_rec("a", 1.0)])
    diffs = diff_records([_rec("a", 1.5)], base)
    bad = gate_regressions(diffs, 25.0)
    assert len(bad) == 1 and "a" in bad[0] and "slower" in bad[0]


def test_gate_fails_on_missing_benchmark(tmp_path):
    base = _baseline(tmp_path, [_rec("a", 1.0), _rec("dropped", 2.0)])
    diffs = diff_records([_rec("a", 1.0)], base)
    bad = gate_regressions(diffs, 25.0)
    assert len(bad) == 1 and "dropped" in bad[0] and "missing" in bad[0]


def test_gate_ignores_new_and_speedups(tmp_path):
    base = _baseline(tmp_path, [_rec("a", 2.0)])
    diffs = diff_records([_rec("a", 0.5), _rec("fresh", 9.0)], base)
    assert gate_regressions(diffs, 0.0) == []


def test_normalize_cancels_uniform_host_speed(tmp_path):
    """A uniformly 2x-slower host trips the raw gate but passes when
    normalized by a calibration row (the plain-XLA matmul probe)."""
    base = _baseline(tmp_path, [_rec("cal", 1.0), _rec("a", 4.0)])
    run = [_rec("cal", 2.0), _rec("a", 8.0)]
    raw = diff_records(run, base)
    assert gate_regressions(raw, 25.0)                      # +100% raw
    norm = diff_records(run, base, normalize="cal")
    assert gate_regressions(norm, 25.0) == []               # 0% relative
    by = {d["name"]: d for d in norm}
    assert by["a"]["delta_ms_pct"] == pytest.approx(0.0)


def test_normalize_rescales_gops_consistently(tmp_path, capsys):
    """The gops delta column must agree with the normalized ms delta
    (gops ~ 1/time, so the baseline gops is rescaled by 1/speed)."""
    base = _baseline(tmp_path, [_rec("cal", 1.0, gops=10.0),
                                _rec("a", 2.0, gops=5.0)])
    run = [_rec("cal", 2.0, gops=5.0), _rec("a", 4.0, gops=2.5)]
    diff_records(run, base, normalize="cal")
    out = capsys.readouterr().out
    row = [ln for ln in out.splitlines() if ln.startswith("a,")][0]
    assert row.endswith(",+0.0") and ",+0.0," in row   # ms AND gops deltas


def test_normalize_still_catches_relative_regressions(tmp_path):
    base = _baseline(tmp_path, [_rec("cal", 1.0), _rec("a", 4.0)])
    # host 2x slower AND 'a' regressed another 2x on top
    run = [_rec("cal", 2.0), _rec("a", 16.0)]
    norm = diff_records(run, base, normalize="cal")
    bad = gate_regressions(norm, 25.0)
    assert len(bad) == 1 and "a" in bad[0]


def test_normalize_requires_calibration_row(tmp_path):
    base = _baseline(tmp_path, [_rec("a", 1.0)])
    with pytest.raises(SystemExit):
        diff_records([_rec("a", 1.0)], base, normalize="cal")


def test_normalize_median_is_robust_to_one_regressed_row(tmp_path):
    """Median-of-ratios: a 2x-slower host cancels; the one row that
    regressed 4x relative to its peers still trips the gate, and the
    regression can't hide by dragging the calibration with it."""
    names = ["a", "b", "c", "d", "bad"]
    base = _baseline(tmp_path, [_rec(n, 1.0) for n in names])
    run = [_rec(n, 2.0) for n in names[:-1]] + [_rec("bad", 8.0)]
    diffs = diff_records(run, base, normalize="median")
    bad = gate_regressions(diffs, 25.0)
    assert len(bad) == 1 and "bad" in bad[0]
    by = {d["name"]: d for d in diffs}
    assert by["a"]["delta_ms_pct"] == pytest.approx(0.0)


def test_cli_gate_exit_codes(tmp_path, capsys, monkeypatch):
    """main() wires --fail-on-regress to the exit status (run the cheap
    lut_init module against a synthetic baseline)."""
    from benchmarks import run as run_mod

    # a fabricated baseline containing a row that this run won't produce
    rows = [{"name": "ghost_bench", "median_ms": 1.0, "gops": None,
             "derived": ""}]
    base = _baseline(tmp_path, rows)
    with pytest.raises(SystemExit) as e:
        run_mod.main(["--only", "lut_init", "--diff", base,
                      "--fail-on-regress", "25"])
    assert e.value.code == 1
    assert "ghost_bench" in capsys.readouterr().err


def test_cli_fail_on_regress_requires_diff():
    from benchmarks import run as run_mod
    with pytest.raises(SystemExit) as e:
        run_mod.main(["--only", "lut_init", "--fail-on-regress", "25"])
    assert e.value.code == 2          # argparse usage error
