"""Fault injection + recovery differentials.

The contract under test: for EVERY FaultPlan category (NaN logits, page-table
corruption, dispatch failure, host stall), a scheduler with snapshots enabled
recovers such that every non-shed request's transcript is token-identical to
the fault-free run — on the single-device Engine, the paged engine, and the
2x2 ShardedEngine.  Plus the guard units: corruption is DETECTED (not served),
poisoned tokens never reach streaming callbacks, retry bounds drop requests
deterministically, and recovery without snapshots fails loudly.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T
from repro.serve import Engine, Request, Scheduler, ServeConfig
from repro.serve.faults import (CacheCorruption, Fault, FaultPlan,
                                InjectedFault, KINDS)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _engine(arch="qwen2-7b", max_len=32, **scfg):
    cfg = dataclasses.replace(configs.get_config(arch, smoke=True),
                              compute_dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params, Engine(cfg, params,
                               ServeConfig(max_len=max_len, **scfg))


def _reqs(cfg, n=4, S=5, budget=6):
    prompts = jax.random.randint(jax.random.PRNGKey(1), (n, S), 0, cfg.vocab)
    return [Request(prompt=np.asarray(prompts[i]).tolist(),
                    max_new_tokens=budget) for i in range(n)]


def _transcripts(reqs):
    return [(r.finish_reason, list(r.tokens)) for r in reqs]


def _run(eng, cfg, plan=None, **sched_kw):
    sched = Scheduler(eng, slots=2, chunk=2,
                      **sched_kw)
    eng.set_fault_plan(plan)
    reqs = _reqs(cfg)
    try:
        sched.run(reqs, max_rounds=64)
    finally:
        eng.set_fault_plan(None)
    return sched, _transcripts(reqs)


# ---------------------------------------------------------------------------
# the differential: every category, dense and paged, vs the fault-free run
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("site", ["admit", "decode"])
def test_single_fault_differential_dense(kind, site):
    cfg, params, eng = _engine()
    _, want = _run(eng, cfg)
    plan = FaultPlan([Fault(site=site, index=1, kind=kind, duration=0.001)])
    sched, got = _run(eng, cfg, plan, snapshot_interval=1, max_retries=3)
    assert got == want
    assert not plan.pending
    if kind in ("dispatch",) or (kind == "nan_logits"
                                 and not plan.faults[0].skipped):
        assert sched.stats["recoveries"] >= 1
    if kind == "page_table":                 # dense engine: no pool to corrupt
        assert plan.faults[0].skipped


@pytest.mark.parametrize("kind", KINDS)
def test_single_fault_differential_paged(kind):
    cfg, params, eng = _engine(paged=True, page_size=4)
    _, want = _run(eng, cfg)
    plan = FaultPlan([Fault(site="decode", index=1, kind=kind,
                            duration=0.001)])
    sched, got = _run(eng, cfg, plan, snapshot_interval=1, max_retries=3)
    assert got == want
    assert not plan.pending and not plan.faults[0].skipped
    if kind in ("nan_logits", "page_table", "dispatch"):
        assert sched.stats["recoveries"] >= 1


def test_seeded_chaos_plan_differential():
    """A multi-fault random plan (seed from REPRO_FAULT_SEED — the chaos CI
    job sweeps it) still converges to the fault-free transcripts."""
    seed = int(os.environ.get("REPRO_FAULT_SEED", "0"))
    cfg, params, eng = _engine(paged=True, page_size=4)
    _, want = _run(eng, cfg)
    plan = FaultPlan.random(seed, n=4, max_index=8, slots=2, duration=0.001)
    sched, got = _run(eng, cfg, plan, snapshot_interval=1, max_retries=8)
    assert got == want
    # faults drawn past the run's dispatch count legitimately never fire;
    # everything that came due must have been consumed
    assert all(f.index >= plan.counters[f.site] for f in plan.pending)


# ---------------------------------------------------------------------------
# detection guards
# ---------------------------------------------------------------------------

def test_nan_poison_is_detected_not_served():
    """Without recovery (snapshots off), the finite-logits guard must FAIL
    the run rather than serve argmax-of-NaN tokens."""
    cfg, params, eng = _engine()
    plan = FaultPlan([Fault(site="decode", index=1, kind="nan_logits")])
    sched = Scheduler(eng, slots=2, chunk=2)
    eng.set_fault_plan(plan)
    try:
        with pytest.raises(RuntimeError, match="snapshot"):
            sched.run(_reqs(cfg), max_rounds=64)
    finally:
        eng.set_fault_plan(None)


def test_page_table_corruption_caught_by_pool_audit():
    cfg, params, eng = _engine(paged=True, page_size=4)
    sched = Scheduler(eng, slots=2, chunk=2)
    plan = FaultPlan([Fault(site="decode", index=1, kind="page_table")])
    eng.set_fault_plan(plan)
    try:
        with pytest.raises(RuntimeError, match="snapshot"):
            sched.run(_reqs(cfg), max_rounds=64)
    finally:
        eng.set_fault_plan(None)


def test_streaming_callbacks_never_see_poisoned_tokens():
    """Detection precedes emission: the token streams of a faulted run are
    exactly the fault-free streams even though a NaN round executed."""
    cfg, params, eng = _engine()
    clean = []
    reqs = _reqs(cfg)
    for r in reqs:
        r.on_token = lambda rq, t: clean.append((id(rq), t))
    Scheduler(eng, slots=2, chunk=2).run(
        reqs, max_rounds=64)
    streamed = []
    reqs2 = _reqs(cfg)
    pairs = {id(r2): id(r1) for r1, r2 in zip(reqs, reqs2)}
    for r in reqs2:
        r.on_token = lambda rq, t: streamed.append((pairs[id(rq)], t))
    eng.set_fault_plan(FaultPlan([Fault(site="decode", index=1,
                                        kind="nan_logits")]))
    try:
        Scheduler(eng, slots=2, chunk=2,
                  snapshot_interval=1).run(reqs2, max_rounds=64)
    finally:
        eng.set_fault_plan(None)
    # at-least-once delivery: replays may repeat a prefix, but every stream
    # is a sequence of prefixes of the clean stream — no foreign token ever
    by_req_clean, by_req = {}, {}
    for k, t in clean:
        by_req_clean.setdefault(k, []).append(t)
    for k, t in streamed:
        by_req.setdefault(k, []).append(t)
    for k, toks in by_req.items():
        want = by_req_clean[k]
        # the final len(want) tokens must be the clean stream, and every
        # streamed token must appear at a valid replay offset
        assert toks[-len(want):] == want


def test_retry_bound_drops_request_as_failed():
    """Corruption recurring past max_retries fails the in-flight requests
    deterministically instead of retrying forever."""
    cfg, params, eng = _engine()
    # three NaN faults at well-separated decode indices: each fires in its
    # own round, so the global retries-since-progress counter resets between
    # them while the per-request retry count accumulates to the bound
    plan = FaultPlan([Fault(site="decode", index=i, kind="nan_logits")
                      for i in (1, 3, 5)])
    sched = Scheduler(eng, slots=2, chunk=2,
                      snapshot_interval=1, max_retries=2)
    reqs = _reqs(cfg, n=2, budget=10)
    eng.set_fault_plan(plan)
    try:
        sched.run(reqs, max_rounds=64)
    finally:
        eng.set_fault_plan(None)
    assert sched.stats["recoveries"] == 3
    assert sched.stats["failed"] == 2
    assert all(r.finish_reason == "failed" and r.retries > 2 for r in reqs)


def test_dispatch_fault_rolls_back_admission_atomically():
    """An injected admit failure releases the candidates' pages and requeues
    them in order — the retry admits an identical round."""
    cfg, params, eng = _engine(paged=True, page_size=4)
    _, want = _run(eng, cfg)
    plan = FaultPlan([Fault(site="admit", index=0, kind="dispatch")])
    sched, got = _run(eng, cfg, plan, snapshot_interval=1)
    assert got == want
    assert sched.stats["dispatch_retries"] == 1


def test_fault_plan_seeded_reproducibility():
    a = FaultPlan.random(7, n=5)
    b = FaultPlan.random(7, n=5)
    assert [(f.site, f.index, f.kind, f.slot) for f in a.faults] == \
           [(f.site, f.index, f.kind, f.slot) for f in b.faults]
    c = FaultPlan.random(8, n=5)
    assert [(f.site, f.index, f.kind) for f in a.faults] != \
           [(f.site, f.index, f.kind) for f in c.faults]


# ---------------------------------------------------------------------------
# sharded 2x2 differential (subprocess: needs 4+ fake CPU devices)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_fault_differential_subprocess():
    script = textwrap.dedent("""
        import dataclasses, jax, numpy as np
        from jax.sharding import Mesh
        from repro import configs
        from repro.models import transformer as T
        from repro.serve import Request, Scheduler, ServeConfig, ShardedEngine
        from repro.serve.faults import Fault, FaultPlan

        cfg = dataclasses.replace(configs.get_config("qwen2-7b", smoke=True),
                                  compute_dtype="float32")
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        mesh = Mesh(np.array(jax.devices()[:4]).reshape(2, 2),
                    ("data", "model"))
        scfg = ServeConfig(max_len=32, quant="w4a4_lut", paged=True,
                           page_size=4)

        def run(plan):
            eng = ShardedEngine(cfg, params, scfg, mesh=mesh)
            sched = Scheduler(eng, slots=4, chunk=2,
                              snapshot_interval=1, max_retries=6)
            eng.set_fault_plan(plan)
            prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 5), 0,
                                         cfg.vocab)
            reqs = [Request(prompt=np.asarray(prompts[i]).tolist(),
                            max_new_tokens=6) for i in range(4)]
            sched.run(reqs, max_rounds=64)
            return sched, [(r.finish_reason, list(r.tokens)) for r in reqs]

        _, want = run(None)
        for kind in ("nan_logits", "page_table", "dispatch", "stall"):
            plan = FaultPlan([Fault(site="decode", index=1, kind=kind,
                                    duration=0.001)])
            sched, got = run(plan)
            assert got == want, (kind, got, want)
            assert not plan.pending
            if kind != "stall":
                assert sched.stats["recoveries"] >= 1, kind
        print("SHARDED_FAULTS_OK")
    """)
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "SHARDED_FAULTS_OK" in out.stdout
