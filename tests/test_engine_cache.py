"""Engine cache stitching (serve/engine._grow_cache) + quantize-at-load.

The ring-buffer predicate regression: a short prompt (S < window) produces a
full-size (non-ring) prefill cache that MUST be grown to min(max_len, window)
— the old code skipped every local layer whenever a window was configured,
leaving an S-slot buffer whose modular addressing dropped in-window tokens.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T
from repro.serve.engine import Engine, ServeConfig


def _gemma_engine(max_len=24, dtype="float32"):
    cfg = configs.get_config("gemma2-2b", smoke=True)
    cfg = dataclasses.replace(cfg, compute_dtype=dtype)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params, Engine(cfg, params, ServeConfig(max_len=max_len))


def test_grow_cache_local_layers_grow_to_window():
    cfg, params, eng = _gemma_engine(max_len=24)
    S = 4                                       # shorter than window (8)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0, cfg.vocab)
    _, cache = eng._prefill(params, prompts)
    grown = eng._grow_cache(cache, S)
    for spec, c in zip(cfg.pattern, grown):
        T_dim = c["k"].shape[2]
        if spec.attn_type == "local":
            assert T_dim == min(24, cfg.window) == 8
        else:
            assert T_dim == 24


def test_grow_cache_ring_buffers_untouched():
    cfg, params, eng = _gemma_engine(max_len=24)
    S = 12                                      # longer than window: ring
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0, cfg.vocab)
    _, cache = eng._prefill(params, prompts)
    for spec, c in zip(cfg.pattern, cache):
        if spec.attn_type == "local":
            assert c["k"].shape[2] == cfg.window     # prefill emitted a ring
    grown = eng._grow_cache(cache, S)
    for spec, (c0, c1) in zip(cfg.pattern, zip(cache, grown)):
        if spec.attn_type == "local":
            np.testing.assert_array_equal(np.asarray(c0["k"]),
                                          np.asarray(c1["k"]))


@pytest.mark.parametrize("S", [4, 12])
def test_engine_swa_greedy_matches_forward(S):
    """Greedy decode through the ring caches must match teacher-forced
    argmax on its own outputs — for prompts shorter AND longer than the
    window (the short case is the regression the predicate fix covers)."""
    cfg, params, eng = _gemma_engine(max_len=32)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0, cfg.vocab)
    out = eng.generate(prompts, max_new_tokens=6)
    assert out.shape == (2, S + 6)
    logits, _ = T.forward(params, cfg, out[:, :-1])
    want = jnp.argmax(logits[:, S - 1:], axis=-1)
    np.testing.assert_array_equal(np.asarray(out[:, S:]), np.asarray(want))


def test_engine_quantize_at_load():
    cfg = configs.get_config("qwen2-7b", smoke=True, quant="w4a4_mxu")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, ServeConfig(max_len=16, quant="w4a4_mxu"))
    # weights were converted to integer codes once, at construction
    assert "w_q" in eng.params["blocks"][0]["attn"]["wq"]
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0, cfg.vocab)
    out = eng.generate(prompts, max_new_tokens=3)
    assert out.shape == (2, 8)
