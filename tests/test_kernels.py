"""Pallas kernels vs pure-jnp oracles: exact integer equality across shape
sweeps (interpret mode executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.lut import pack_int4
from repro.kernels.lutmul import ops, ref

SHAPES = [(8, 32, 16), (16, 128, 128), (100, 256, 130), (128, 384, 256),
          (1, 64, 48), (257, 128, 64)]


def _rand_case(rng, M, K, N):
    a = rng.integers(-8, 8, size=(M, K)).astype(np.int8)
    w = rng.integers(-8, 8, size=(K, N)).astype(np.int8)
    a_codes = jnp.asarray(a.astype(np.uint8) & 0xF)
    w_packed = pack_int4(jnp.asarray(w).T).T
    want = a.astype(np.int32) @ w.astype(np.int32)
    return a, w, a_codes, w_packed, want


@pytest.mark.parametrize("M,K,N", SHAPES)
def test_lutmul_kernel_vs_oracle(M, K, N):
    rng = np.random.default_rng(M * 1000 + N)
    a, w, a_codes, w_packed, want = _rand_case(rng, M, K, N)
    got_ref = ref.lutmul_ref(a_codes, w_packed, a_signed=True)
    np.testing.assert_array_equal(np.asarray(got_ref), want)
    got_kernel = ops.lutmul(a_codes, w_packed, backend="interpret")
    np.testing.assert_array_equal(np.asarray(got_kernel), want)


@pytest.mark.parametrize("M,K,N", SHAPES)
def test_int_matmul_kernel_vs_oracle(M, K, N):
    rng = np.random.default_rng(M + N)
    a = rng.integers(-128, 128, size=(M, K)).astype(np.int8)
    w = rng.integers(-128, 128, size=(K, N)).astype(np.int8)
    want = a.astype(np.int32) @ w.astype(np.int32)
    got = ops.int_matmul(jnp.asarray(a), jnp.asarray(w), backend="interpret")
    np.testing.assert_array_equal(np.asarray(got), want)


@given(M=st.integers(1, 40), K=st.integers(2, 96).map(lambda k: k * 2),
       N=st.integers(1, 40))
@settings(max_examples=20, deadline=None)
def test_lutmul_property_random_shapes(M, K, N):
    rng = np.random.default_rng(M * 7 + K * 13 + N)
    a, w, a_codes, w_packed, want = _rand_case(rng, M, K, N)
    got = ops.lutmul(a_codes, w_packed, backend="ref")
    np.testing.assert_array_equal(np.asarray(got), want)


@pytest.mark.parametrize("mode", ["w4a4_lut", "w4a4_mxu", "w8a8"])
def test_quantized_matmul_accuracy(mode):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (32, 128), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 64), jnp.float32)
    y = ops.quantized_matmul(x, w, mode=mode, backend="ref",
                             compute_dtype=jnp.float32)
    rel = float(jnp.linalg.norm(y - x @ w) / jnp.linalg.norm(x @ w))
    # 4-bit dynamic quant of gaussian data: ~4.7% per-operand grid error
    # compounding over both operands -> ~17% output error pre-QAT (QAT's job
    # is to adapt the distributions; see benchmarks/qat_accuracy.py)
    assert rel < (0.02 if mode == "w8a8" else 0.20), rel
    assert np.isfinite(np.asarray(y)).all()


def test_quantized_matmul_lut_equals_mxu_int_math():
    """The LUT path and the integer-dot path share quantizers -> identical."""
    x = jax.random.normal(jax.random.PRNGKey(2), (16, 64), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(3), (64, 32), jnp.float32)
    y1 = ops.quantized_matmul(x, w, mode="w4a4_lut", backend="ref",
                              compute_dtype=jnp.float32)
    y2 = ops.quantized_matmul(x, w, mode="w4a4_mxu", backend="ref",
                              compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6)


@pytest.mark.slow
def test_lutmul_interpret_dtype_sweep():
    rng = np.random.default_rng(0)
    for a_signed in (True, False):
        M, K, N = 64, 128, 96
        a_vals = rng.integers(-8, 8, (M, K)) if a_signed \
            else rng.integers(0, 16, (M, K))
        w = rng.integers(-8, 8, size=(K, N)).astype(np.int8)
        a_codes = jnp.asarray(a_vals.astype(np.uint8) & 0xF)
        w_packed = pack_int4(jnp.asarray(w).T).T
        want = a_vals.astype(np.int32) @ w.astype(np.int32)
        got = ops.lutmul(a_codes, w_packed, a_signed=a_signed,
                         backend="interpret")
        np.testing.assert_array_equal(np.asarray(got), want)


# ---------------------------------------------------------------------------
# padding edge cases + impl agreement (onehot contraction vs gather vs ref)
# ---------------------------------------------------------------------------

PAD_SHAPES = [(5, 18, 7),       # everything under one block
              (3, 130, 5),      # K just over a block
              (129, 126, 129),  # M/N just over, K just under
              (7, 2, 1),        # M < 8, minimal K/N
              (1, 64, 48)]      # single row


@pytest.mark.parametrize("M,K,N", PAD_SHAPES)
def test_lutmul_padding_all_impls_agree(M, K, N):
    rng = np.random.default_rng(M * 31 + K * 7 + N)
    a, w, a_codes, w_packed, want = _rand_case(rng, M, K, N)
    got_ref = np.asarray(ref.lutmul_ref(a_codes, w_packed, a_signed=True))
    got_onehot = np.asarray(ops.lutmul(a_codes, w_packed,
                                       backend="interpret", impl="onehot"))
    got_gather = np.asarray(ops.lutmul(a_codes, w_packed,
                                       backend="interpret", impl="gather"))
    np.testing.assert_array_equal(got_ref, want)
    np.testing.assert_array_equal(got_onehot, want)
    np.testing.assert_array_equal(got_gather, want)


def test_lutmul_odd_k_rejected():
    a_codes = jnp.zeros((4, 7), jnp.uint8)          # odd K
    w_packed = jnp.zeros((3, 8), jnp.uint8)
    with pytest.raises(ValueError, match="even K"):
        ops.lutmul(a_codes, w_packed)
    # packed rows must be exactly K // 2
    with pytest.raises(ValueError, match="K//2"):
        ops.lutmul(jnp.zeros((4, 8), jnp.uint8), jnp.zeros((3, 8), jnp.uint8))


def test_quantized_matmul_padding_shapes():
    for (M, K, N) in [(5, 30, 7), (1, 128, 3), (100, 130, 70)]:
        x = jax.random.normal(jax.random.PRNGKey(M), (M, K), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(N), (K, N), jnp.float32)
        for mode in ("w4a4_lut", "w4a4_mxu", "w8a8"):
            y_ref = ops.quantized_matmul(x, w, mode=mode, backend="ref",
                                         compute_dtype=jnp.float32)
            y_int = ops.quantized_matmul(x, w, mode=mode, backend="interpret",
                                         compute_dtype=jnp.float32)
            # same integer accumulator, same epilogue -> bitwise identical
            np.testing.assert_array_equal(np.asarray(y_ref),
                                          np.asarray(y_int))


def test_prequant_fused_epilogue_matches_ref():
    from repro.serve.quantize import quantize_leaf
    x = jax.random.normal(jax.random.PRNGKey(0), (6, 34), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (34, 20), jnp.float32)
    leaf = quantize_leaf(w, 4)
    for mode in ("w4a4_lut", "w4a4_mxu"):
        y_ref = ops.prequant_matmul(x, leaf["w_q"], leaf["w_scale"],
                                    mode=mode, compute_dtype=jnp.float32,
                                    backend="ref")
        y_int = ops.prequant_matmul(x, leaf["w_q"], leaf["w_scale"],
                                    mode=mode, compute_dtype=jnp.float32,
                                    backend="interpret")
        np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y_int))


def test_block_autotuner_caches_winner():
    rng = np.random.default_rng(0)
    a, w, a_codes, w_packed, want = _rand_case(rng, 16, 128, 128)
    ops.set_autotune(True)
    try:
        ops._BLOCK_CACHE.clear()
        got = ops.lutmul(a_codes, w_packed, backend="interpret")
        key = ("lutmul_onehot", 16, 128, 128, "interpret")
        assert key in ops._BLOCK_CACHE
        bm, bn, bk = ops._BLOCK_CACHE[key]
        assert bm % 8 == 0 and bn % 128 == 0 and bk % 128 == 0
        np.testing.assert_array_equal(np.asarray(got), want)
        # second call is a pure cache hit (no sweep) and stays exact
        got2 = ops.lutmul(a_codes, w_packed, backend="interpret")
        np.testing.assert_array_equal(np.asarray(got2), want)
    finally:
        ops.set_autotune(None)
        ops._BLOCK_CACHE.clear()


def test_fused_kernel_matches_scaled_oracle():
    rng = np.random.default_rng(3)
    M, K, N = 10, 64, 33
    a, w, a_codes, w_packed, _ = _rand_case(rng, M, K, N)
    a_scale = jnp.asarray(rng.uniform(0.01, 1.0, (M, 1)), jnp.float32)
    w_scale = jnp.asarray(rng.uniform(0.01, 1.0, (1, N)), jnp.float32)
    want = ref.scaled_lutmul_ref(a_codes, w_packed, a_scale, w_scale)
    from repro.kernels.lutmul import kernel, ops as _ops
    a_p = _ops._pad_to(a_codes, 8, 128)
    w_p = _ops._pad_to(w_packed, 64, 128)
    as_p = _ops._pad_to(a_scale, 8, 1)
    ws_p = _ops._pad_to(w_scale, 1, 128)
    got = kernel.lutmul_fused_pallas(
        a_p, w_p, _ops._get_table(True), as_p, ws_p, bm=16, bn=128, bk=128,
        out_dtype=jnp.float32, interpret=True)[:M, :N]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_prequant_malformed_packed_rejected_on_all_backends():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8), jnp.float32)
    bad_wq = jnp.zeros((3, 8), jnp.uint8)           # rows != K//2
    w_scale = jnp.ones((1, 8), jnp.float32)
    for backend in ("ref", "interpret"):
        with pytest.raises(ValueError, match="K//2"):
            ops.prequant_matmul(x, bad_wq, w_scale, mode="w4a4_lut",
                                backend=backend)
