"""Pallas kernels vs pure-jnp oracles: exact integer equality across shape
sweeps (interpret mode executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.lut import pack_int4
from repro.kernels.lutmul import ops, ref

SHAPES = [(8, 32, 16), (16, 128, 128), (100, 256, 130), (128, 384, 256),
          (1, 64, 48), (257, 128, 64)]


def _rand_case(rng, M, K, N):
    a = rng.integers(-8, 8, size=(M, K)).astype(np.int8)
    w = rng.integers(-8, 8, size=(K, N)).astype(np.int8)
    a_codes = jnp.asarray(a.astype(np.uint8) & 0xF)
    w_packed = pack_int4(jnp.asarray(w).T).T
    want = a.astype(np.int32) @ w.astype(np.int32)
    return a, w, a_codes, w_packed, want


@pytest.mark.parametrize("M,K,N", SHAPES)
def test_lutmul_kernel_vs_oracle(M, K, N):
    rng = np.random.default_rng(M * 1000 + N)
    a, w, a_codes, w_packed, want = _rand_case(rng, M, K, N)
    got_ref = ref.lutmul_ref(a_codes, w_packed, a_signed=True)
    np.testing.assert_array_equal(np.asarray(got_ref), want)
    got_kernel = ops.lutmul(a_codes, w_packed, backend="interpret")
    np.testing.assert_array_equal(np.asarray(got_kernel), want)


@pytest.mark.parametrize("M,K,N", SHAPES)
def test_int_matmul_kernel_vs_oracle(M, K, N):
    rng = np.random.default_rng(M + N)
    a = rng.integers(-128, 128, size=(M, K)).astype(np.int8)
    w = rng.integers(-128, 128, size=(K, N)).astype(np.int8)
    want = a.astype(np.int32) @ w.astype(np.int32)
    got = ops.int_matmul(jnp.asarray(a), jnp.asarray(w), backend="interpret")
    np.testing.assert_array_equal(np.asarray(got), want)


@given(M=st.integers(1, 40), K=st.integers(2, 96).map(lambda k: k * 2),
       N=st.integers(1, 40))
@settings(max_examples=20, deadline=None)
def test_lutmul_property_random_shapes(M, K, N):
    rng = np.random.default_rng(M * 7 + K * 13 + N)
    a, w, a_codes, w_packed, want = _rand_case(rng, M, K, N)
    got = ops.lutmul(a_codes, w_packed, backend="ref")
    np.testing.assert_array_equal(np.asarray(got), want)


@pytest.mark.parametrize("mode", ["w4a4_lut", "w4a4_mxu", "w8a8"])
def test_quantized_matmul_accuracy(mode):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (32, 128), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 64), jnp.float32)
    y = ops.quantized_matmul(x, w, mode=mode, backend="ref",
                             compute_dtype=jnp.float32)
    rel = float(jnp.linalg.norm(y - x @ w) / jnp.linalg.norm(x @ w))
    # 4-bit dynamic quant of gaussian data: ~4.7% per-operand grid error
    # compounding over both operands -> ~17% output error pre-QAT (QAT's job
    # is to adapt the distributions; see benchmarks/qat_accuracy.py)
    assert rel < (0.02 if mode == "w8a8" else 0.20), rel
    assert np.isfinite(np.asarray(y)).all()


def test_quantized_matmul_lut_equals_mxu_int_math():
    """The LUT path and the integer-dot path share quantizers -> identical."""
    x = jax.random.normal(jax.random.PRNGKey(2), (16, 64), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(3), (64, 32), jnp.float32)
    y1 = ops.quantized_matmul(x, w, mode="w4a4_lut", backend="ref",
                              compute_dtype=jnp.float32)
    y2 = ops.quantized_matmul(x, w, mode="w4a4_mxu", backend="ref",
                              compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6)


@pytest.mark.slow
def test_lutmul_interpret_dtype_sweep():
    rng = np.random.default_rng(0)
    for a_signed in (True, False):
        M, K, N = 64, 128, 96
        a_vals = rng.integers(-8, 8, (M, K)) if a_signed \
            else rng.integers(0, 16, (M, K))
        w = rng.integers(-8, 8, size=(K, N)).astype(np.int8)
        a_codes = jnp.asarray(a_vals.astype(np.uint8) & 0xF)
        w_packed = pack_int4(jnp.asarray(w).T).T
        want = a_vals.astype(np.int32) @ w.astype(np.int32)
        got = ops.lutmul(a_codes, w_packed, a_signed=a_signed,
                         backend="interpret")
        np.testing.assert_array_equal(np.asarray(got), want)
