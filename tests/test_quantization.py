import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.quantization import (W4, QuantConfig, compute_scale,
                                     dequantize, fake_quant, quant_error,
                                     quantize)


@given(st.lists(st.floats(-10, 10, allow_nan=False), min_size=4, max_size=64))
@settings(max_examples=50, deadline=None)
def test_quantize_within_range(vals):
    x = jnp.asarray(vals, jnp.float32).reshape(1, -1)
    s = compute_scale(x, W4)
    q = quantize(x, s, 0, W4)
    assert int(q.min()) >= W4.qmin and int(q.max()) <= W4.qmax


def test_dequantize_inverse_on_grid():
    """Values already on the quant grid survive a round trip exactly."""
    cfg = W4
    s = jnp.float32(0.25)
    grid = jnp.arange(cfg.qmin, cfg.qmax + 1, dtype=jnp.float32) * s
    q = quantize(grid, s, 0, cfg)
    back = dequantize(q, s, 0)
    np.testing.assert_allclose(np.asarray(back), np.asarray(grid), atol=1e-7)


def test_fake_quant_straight_through_gradient():
    x = jnp.linspace(-1.0, 1.0, 32)
    g = jax.grad(lambda v: jnp.sum(fake_quant(v, W4)))(x)
    np.testing.assert_allclose(np.asarray(g), np.ones(32), atol=1e-6)


def test_quant_error_decreases_with_bits():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
    errs = [float(quant_error(x, QuantConfig(bits=b))) for b in (2, 4, 8)]
    assert errs[0] > errs[1] > errs[2]


def test_per_channel_scale_shape():
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
    s = compute_scale(x, W4)          # channel_axis=-1
    assert s.shape == (1, 8)
    # each channel's max-abs maps to qmax
    q = quantize(x, s, 0, W4)
    assert int(jnp.max(jnp.abs(q))) == 7
