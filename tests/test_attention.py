import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A
from repro.models.layers import apply_mrope, apply_rope


def _qkv(key, B=2, S=64, Hq=4, Hkv=2, D=16):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    return q, k, v, pos


@pytest.mark.parametrize("window", [None, 16])
@pytest.mark.parametrize("softcap", [None, 20.0])
def test_blocked_equals_full(window, softcap):
    q, k, v, pos = _qkv(jax.random.PRNGKey(0))
    full = A.full_attention(q, k, v, pos, pos, causal=True, window=window,
                            logit_softcap=softcap)
    blk = A.blocked_attention(q, k, v, pos, pos, causal=True, window=window,
                              logit_softcap=softcap, kv_block=16)
    np.testing.assert_allclose(np.asarray(blk), np.asarray(full),
                               rtol=2e-5, atol=2e-5)


def test_blocked_nondivisible_kv_block():
    q, k, v, pos = _qkv(jax.random.PRNGKey(1), S=50)
    full = A.full_attention(q, k, v, pos, pos, causal=True)
    blk = A.blocked_attention(q, k, v, pos, pos, causal=True, kv_block=16)
    np.testing.assert_allclose(np.asarray(blk), np.asarray(full),
                               rtol=2e-5, atol=2e-5)


def test_gqa_equals_repeated_kv():
    """GQA grouping == repeating KV heads into an MHA."""
    q, k, v, pos = _qkv(jax.random.PRNGKey(2), Hq=4, Hkv=2)
    got = A.full_attention(q, k, v, pos, pos, causal=True)
    k_rep = jnp.repeat(k, 2, axis=2)
    v_rep = jnp.repeat(v, 2, axis=2)
    # reorder: grouped layout maps q head h -> kv head h // G with G=2;
    # repeated layout maps q head h -> kv head h (after repeat) — they match
    # when q heads are ordered [kv0_g0, kv0_g1, kv1_g0, kv1_g1]
    want = A.full_attention(q, k_rep, v_rep, pos, pos, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_sliding_window_masks_old_tokens():
    q, k, v, pos = _qkv(jax.random.PRNGKey(3), S=32)
    w = A.full_attention(q, k, v, pos, pos, causal=True, window=4)
    # last query must be unaffected by perturbing keys older than window
    k2 = k.at[:, :16].set(jax.random.normal(jax.random.PRNGKey(9),
                                            k[:, :16].shape))
    v2 = v.at[:, :16].set(0.0)
    w2 = A.full_attention(q, k2, v2, pos, pos, causal=True, window=4)
    np.testing.assert_allclose(np.asarray(w[:, -1]), np.asarray(w2[:, -1]),
                               rtol=1e-5, atol=1e-5)


def test_mrope_degenerates_to_rope_on_text():
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 8, 4, 16))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8)).astype(jnp.int32)
    mpos = jnp.broadcast_to(pos[..., None], (2, 8, 3))
    a = apply_rope(x, pos, theta=10000.0)
    b = apply_mrope(x, mpos, sections=(2, 3, 3), theta=10000.0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-6)


def test_decode_matches_full_attention():
    """Sequential decode through the cache == one-shot full attention."""
    cfg_kw = dict(n_heads=4, n_kv=2, head_dim=16)
    key = jax.random.PRNGKey(5)
    p = A.init_attention(key, 32, 4, 2, 16)
    B, S = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(6), (B, S, 32))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    full = A.attention(p, x, pos, causal=True, compute_dtype=jnp.float32,
                       **cfg_kw)
    ck = jnp.zeros((B, S, 2, 16))
    cv = jnp.zeros((B, S, 2, 16))
    outs = []
    for t in range(S):
        y, ck, cv = A.decode_attention(p, x[:, t:t + 1], ck, cv,
                                       jnp.int32(t),
                                       compute_dtype=jnp.float32, **cfg_kw)
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=3e-4, atol=3e-4)


def test_rolling_cache_decode_equals_windowed():
    """Ring-buffer decode (window W) == full attention with sliding window."""
    cfg_kw = dict(n_heads=2, n_kv=2, head_dim=8, window=4)
    p = A.init_attention(jax.random.PRNGKey(7), 16, 2, 2, 8)
    B, S, W = 1, 11, 4
    x = jax.random.normal(jax.random.PRNGKey(8), (B, S, 16))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    full = A.attention(p, x, pos, causal=True, compute_dtype=jnp.float32,
                       **cfg_kw)
    ck = jnp.zeros((B, W, 2, 8))
    cv = jnp.zeros((B, W, 2, 8))
    outs = []
    for t in range(S):
        y, ck, cv = A.decode_attention(p, x[:, t:t + 1], ck, cv, jnp.int32(t),
                                       rolling=True,
                                       compute_dtype=jnp.float32, **cfg_kw)
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=3e-4, atol=3e-4)
