"""Sharded serving: tensor-parallel LUT matmul + data-parallel slot pool.

The multi-device equivalence suite runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI recipe), so
the main pytest process stays single-device.  It asserts, on a 2x2 AND a
1x8 (data, model) mesh:

  * temperature-0 scheduler output is BIT-identical to the single-device
    engine (static-batch ``generate`` oracle), through staggered chunked
    admission, gemma SWA ring stitches, tied embeddings,
    the int8-KV decode cache, head-sharded attention (KV cache split to
    n_kv/tp heads per shard — asserted on the live cache's shard shapes),
    3D split-head projections, and sharded MoE expert banks (qwen2-moe +
    mixtral smokes, incl. the replicated fallbacks for n_kv % tp != 0 and
    E % tp != 0);
  * no jit retrace after warmup (executor cache sizes stay 1);
  * the quantized projections really are sharded (tp leaf count > 0).

Single-device unit tests cover the param marking/spec derivation (head /
expert / GQA-fallback edge cases) and the engine's guard rails.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.dist import tp
from repro.launch.mesh import parse_mesh
from repro.models import transformer as T
from repro.serve.quantize import quantize_params_for_serving

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# single-device units: marking, specs, guard rails
# ---------------------------------------------------------------------------

def _quantized_smoke_params(arch="qwen2-7b", quant="w4a4_lut"):
    cfg = configs.get_config(arch, smoke=True, quant=quant)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, quantize_params_for_serving(params, mode=quant)


def test_mark_tp_params_specs_and_markers():
    cfg, qparams = _quantized_smoke_params()
    marked, specs, n = tp.mark_tp_params(qparams, 4)
    assert n > 0
    attn = marked["blocks"][0]["attn"]
    # column-parallel projection: codes + scales split on N, marker present
    assert "tp_col" in attn["wq"] and attn["wq"]["tp_col"].shape[-1] == 0
    assert specs["blocks"][0]["attn"]["wq"]["w_q"] == P(None, None, "model")
    assert specs["blocks"][0]["attn"]["wq"]["w_scale"] == P(None, None,
                                                           "model")
    # row-parallel output projection: codes split on K, scales replicated
    assert "tp_row" in attn["wo"]
    assert specs["blocks"][0]["attn"]["wo"]["w_q"] == P(None, "model", None)
    assert specs["blocks"][0]["attn"]["wo"]["w_scale"] == P()
    # lm_head (w8a8 int8) is vocab-column-parallel
    assert "tp_col" in marked["lm_head"]
    assert specs["lm_head"]["w_q"] == P(None, "model")
    # biases stay replicated (added after the gather)
    assert specs["blocks"][0]["attn"]["wq"]["b"] == P()


def test_mark_tp_params_indivisible_leaves_stay_replicated():
    cfg, qparams = _quantized_smoke_params()
    # smoke dims (64/32/128/512) don't split 7 ways: nothing shards, but the
    # tree survives untouched (replicated is always correct)
    marked, specs, n = tp.mark_tp_params(qparams, 7)
    assert n == 0
    assert "tp_col" not in marked["blocks"][0]["attn"]["wq"]
    assert specs["blocks"][0]["attn"]["wq"]["w_q"] == P()


# ---------------------------------------------------------------------------
# head-parallel + expert-parallel spec derivation edge cases
# ---------------------------------------------------------------------------

def test_mark_tp_params_head_sharded_attention():
    cfg, qparams = _quantized_smoke_params()
    assert cfg.n_heads % 2 == 0 and cfg.n_kv % 2 == 0
    marked, specs, n = tp.mark_tp_params(qparams, 2, head_dim=cfg.head_dim)
    attn = marked["blocks"][0]["attn"]
    # QKV are head-parallel: codes/scales/bias split on N, NO gather marker
    for k in ("wq", "wk", "wv"):
        assert tp.leaf_tp_mode(attn[k]) == "head", k
        assert specs["blocks"][0]["attn"][k]["w_q"] == P(None, None, "model")
        assert specs["blocks"][0]["attn"][k]["b"] == P(None, "model")
    # the output projection stays ordinary row-parallel: the head-local
    # attention output IS its K slice (shape-dispatched in ops)
    assert tp.leaf_tp_mode(attn["wo"]) == "row"
    assert specs["blocks"][0]["attn"]["wo"]["w_q"] == P(None, "model", None)
    assert tp.has_marker(marked, "tp_head")


def test_mark_tp_params_gqa_indivisible_kv_falls_back_to_replicated_attn():
    """n_kv % tp != 0 (GQA): attention falls back to the col/row (replicated
    attention) marking — still sharded projections, full-head KV cache."""
    cfg = configs.get_config("mixtral-8x22b", smoke=True, quant="w4a4_lut")
    assert cfg.n_kv % 4 != 0 and cfg.n_heads % 4 == 0
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    qparams = quantize_params_for_serving(params, mode="w4a4_lut")
    marked, specs, n = tp.mark_tp_params(qparams, 4, head_dim=cfg.head_dim)
    attn = marked["blocks"][0]["attn"]
    assert not tp.has_marker(marked, "tp_head")
    assert tp.leaf_tp_mode(attn["wq"]) == "col"     # gathered, not local
    assert tp.leaf_tp_mode(attn["wo"]) == "row"
    # head-divisible counts on the same arch DO go head-parallel
    marked2, _, _ = tp.mark_tp_params(qparams, 2, head_dim=cfg.head_dim)
    assert tp.leaf_tp_mode(marked2["blocks"][0]["attn"]["wq"]) == "head"


def test_mark_tp_params_indivisible_heads_fall_back():
    """n_heads itself not divisible: no head marking anywhere (generic
    col/row only shards what divides)."""
    cfg, qparams = _quantized_smoke_params()
    marked, specs, n = tp.mark_tp_params(qparams, 3, head_dim=cfg.head_dim)
    assert not tp.has_marker(marked, "tp_head")
    assert "tp_col" not in marked["blocks"][0]["attn"]["wq"]


def test_mark_tp_params_3d_split_head_leaves():
    """Float [d, H, dh] split-head projections go head-parallel over the H
    axis; wo3 stays replicated (a float psum would drift — attention output
    is gathered in front of it instead)."""
    import dataclasses
    cfg = configs.get_config("qwen2-7b", smoke=True, quant="w4a4_lut")
    cfg = dataclasses.replace(cfg, split_head_params=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    qparams = quantize_params_for_serving(params, mode="w4a4_lut")
    marked, specs, n = tp.mark_tp_params(qparams, 2, head_dim=cfg.head_dim)
    attn = marked["blocks"][0]["attn"]
    for k in ("wq3", "wk3", "wv3"):
        assert tp.leaf_tp_mode(attn[k]) == "head", k
        # stacked [G, d, H, dh]: the head axis is -2
        assert specs["blocks"][0]["attn"][k]["w"] \
            == P(None, None, "model", None)
        assert specs["blocks"][0]["attn"][k]["b"] \
            == P(None, "model", None)
    assert tp.leaf_tp_mode(attn["wo3"]) is None
    assert specs["blocks"][0]["attn"]["wo3"]["w"] == P()
    # markers stay inert single-device
    toks = jnp.arange(6, dtype=jnp.int32)[None]
    import numpy as np
    a, _ = T.prefill(qparams, cfg, toks)
    b, _ = T.prefill(marked, cfg, toks)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _quantized_moe_params(arch="qwen2-moe-a2.7b"):
    cfg = configs.get_config(arch, smoke=True, quant="w4a4_lut")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, quantize_params_for_serving(params, mode="w4a4_lut")


def test_mark_tp_params_expert_banks_sharded():
    cfg, qparams = _quantized_moe_params()       # 8 experts
    marked, specs, n = tp.mark_tp_params(qparams, 2, head_dim=cfg.head_dim)
    moe = marked["blocks"][0]["moe"]
    for k in ("wi", "wg", "wo"):
        assert tp.leaf_tp_mode(moe[k]) == "exp", k
        # stacked [G, E, K(/2), N]: expert axis is -3, for codes AND scales
        assert specs["blocks"][0]["moe"][k]["w_q"] \
            == P(None, "model", None, None)
        assert specs["blocks"][0]["moe"][k]["w_scale"] \
            == P(None, "model", None, None)
    # router replicated => top-k expert choice bit-identical everywhere
    assert tp.leaf_tp_mode(moe["router"]) is None
    assert specs["blocks"][0]["moe"]["router"]["w"] == P()
    # the shared-expert branch is a plain MLP: normal col/row marking
    assert tp.leaf_tp_mode(moe["shared"]["wi"]) == "col"
    assert tp.leaf_tp_mode(moe["shared"]["wo"]) == "row"


def test_mark_tp_params_indivisible_experts_stay_replicated():
    cfg, qparams = _quantized_moe_params()       # 8 experts: 8 % 3 != 0
    marked, specs, n = tp.mark_tp_params(qparams, 3, head_dim=cfg.head_dim)
    moe = marked["blocks"][0]["moe"]
    for k in ("wi", "wg", "wo"):
        assert tp.leaf_tp_mode(moe[k]) is None, k
        assert specs["blocks"][0]["moe"][k]["w_q"] == P()
    # marked tree still runs single-device (replicated banks are inert)
    toks = jnp.arange(4, dtype=jnp.int32)[None]
    import numpy as np
    a, _ = T.prefill(qparams, cfg, toks)
    b, _ = T.prefill(marked, cfg, toks)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serving_cache_specs_head_sharded_layout():
    from repro.launch.specs import serving_cache_specs
    cfg = configs.get_config("qwen2-7b", smoke=True)
    sds = jax.eval_shape(lambda: T.init_cache(cfg, 4, 16))
    specs = serving_cache_specs(sds, "data", "model")
    assert specs[0]["k"] == P(None, "data", None, "model")
    # replicated heads: batch-only sharding; canonical elided form
    specs_rep = serving_cache_specs(sds, "data", None)
    assert specs_rep[0]["k"] == P(None, "data")
    specs_1d = serving_cache_specs(sds, None, "model")
    assert specs_1d[0]["k"] == P(None, None, None, "model")
    # int8-KV scale leaves shard their trailing head axis
    import dataclasses
    cfg8 = dataclasses.replace(cfg, kv_quant="int8")
    sds8 = jax.eval_shape(lambda: T.init_cache(cfg8, 4, 16))
    specs8 = serving_cache_specs(sds8, "data", "model")
    assert specs8[0]["k_scale"] == P(None, "data", None, "model")
    # recurrent-state leaves (mamba h / rwkv S) must NOT head-shard
    cfgz = configs.get_config("zamba2-2.7b", smoke=True)
    sdsz = jax.eval_shape(lambda: T.init_cache(cfgz, 4, 16))
    specsz = serving_cache_specs(sdsz, "data", "model")
    for i, spec in enumerate(cfgz.pattern):
        if spec.kind == "mamba2":
            assert specsz[i]["h"] == P(None, "data")
            break


def test_mark_tp_params_markers_are_inert_single_device():
    """Marked params outside a tp_context run exactly like unmarked ones."""
    cfg, qparams = _quantized_smoke_params()
    marked, _, n = tp.mark_tp_params(qparams, 4)
    assert n > 0
    toks = jnp.arange(6, dtype=jnp.int32)[None]
    a, _ = T.prefill(qparams, cfg, toks)
    b, _ = T.prefill(marked, cfg, toks)
    import numpy as np
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sharded_engine_guard_rails():
    from repro.serve import ServeConfig
    from repro.serve.sharded import ShardedEngine
    cfg = configs.get_config("qwen2-7b", smoke=True, quant="w4a4_lut")
    params = T.init_params(jax.random.PRNGKey(0), cfg)

    class FakeMesh:          # never reached: the quant check fires first
        shape = {"data": 2, "model": 2}

    with pytest.raises(ValueError, match="quant"):
        ShardedEngine(cfg, params, ServeConfig(max_len=16), mesh=FakeMesh())


def test_parse_mesh():
    assert parse_mesh("2x4") == (2, 4)
    assert parse_mesh("1X8") == (1, 8)
    with pytest.raises(ValueError):
        parse_mesh("8")
    with pytest.raises(ValueError):
        parse_mesh("0x4")


# ---------------------------------------------------------------------------
# multi-device equivalence (8 fake CPU devices in a subprocess)
# ---------------------------------------------------------------------------

_EQUIV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, numpy as np
    from repro import configs
    from repro.launch.mesh import make_serving_mesh, parse_mesh
    from repro.models import transformer as T
    from repro.serve import Engine, Request, Scheduler, ServeConfig, \\
        ShardedEngine

    def case(arch, quant, mesh_spec, kv_quant="none",
             slots=4, chunk=2, oracle="generate", split3=False,
             expect_heads=None):
        cfg = dataclasses.replace(
            configs.get_config(arch, smoke=True, quant=quant),
            compute_dtype="float32", kv_quant=kv_quant,
            split_head_params=split3)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        scfg = ServeConfig(max_len=32, quant=quant)
        ref = Engine(cfg, params, scfg)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 6), 0,
                                     cfg.vocab)
        if oracle == "generate":
            want = np.asarray(ref.generate(prompts, max_new_tokens=5)[:, 6:])
        else:
            # int8 live KV has no static-batch analogue (generate's prefill
            # cache stays float): the oracle is the single-device scheduler
            ref_sched = Scheduler(ref, slots=slots, chunk=chunk)
            ref_reqs = [Request(prompt=np.asarray(prompts[i]).tolist(),
                                max_new_tokens=5) for i in range(4)]
            ref_sched.run(ref_reqs)
            want = np.asarray([r.tokens for r in ref_reqs])
        eng = ShardedEngine(cfg, params, scfg,
                            mesh=make_serving_mesh(mesh_spec))
        assert eng.n_tp_leaves > 0, (arch, mesh_spec)
        nd, nm = parse_mesh(mesh_spec)
        if expect_heads is not None:
            assert eng.head_sharded == (expect_heads < cfg.n_kv), \\
                (arch, mesh_spec, eng.head_sharded)
        sched = Scheduler(eng, slots=slots, chunk=chunk)
        reqs = [Request(prompt=np.asarray(prompts[i]).tolist(),
                        max_new_tokens=5) for i in range(4)]
        # staggered admission: two requests land mid-flight
        sched.submit(reqs[0]); sched.submit(reqs[1]); sched.step()
        sched.submit(reqs[2]); sched.submit(reqs[3])
        while sched.has_work:
            sched.step()
        for i, r in enumerate(reqs):
            assert r.tokens == want[i].tolist(), \\
                (arch, mesh_spec, i, r.tokens, want[i].tolist())
        # no retrace after warmup: ONE executable per unified-step variant
        # (and, on monolithic-fallback models, ONE admit executable for the
        # equal-length run)
        sizes = tuple(f._cache_size() for f in eng._step_fns.values())
        if eng.requires_monolithic_admission:
            sizes += (eng._admit_fn._cache_size(),)
        assert sizes and all(s == 1 for s in sizes), (arch, mesh_spec, sizes)
        if expect_heads is not None:
            # per-shard KV cache holds n_kv/tp heads on divisible configs
            # (the documented replicated fallback otherwise)
            c0 = next(c for c in sched.cache
                      if "k" in c or "shared_k" in c)
            k = c0["k"] if "k" in c0 else c0["shared_k"]
            got_heads = k.sharding.shard_shape(k.shape)[-2]
            assert got_heads == expect_heads, \\
                (arch, mesh_spec, got_heads, expect_heads)
            per_shard = eng.kv_cache_bytes(slots)
            total = Engine.kv_cache_bytes(eng, slots)
            shrink = nd * (nm if eng.head_sharded else 1)
            assert per_shard == total // shrink, \\
                (arch, mesh_spec, per_shard, total, shrink)
        print("OK", arch, quant, mesh_spec, "kv=" + kv_quant,
              "head_sharded=", eng.head_sharded,
              "tp_leaves=", eng.n_tp_leaves, flush=True)

    # head-sharded attention on both meshes: 2x2 shards the smoke GQA heads
    # (n_kv/2 per shard); on 1x8 n_heads % 8 != 0 -> documented replicated
    # fallback
    cfg0 = configs.get_config("qwen2-7b", smoke=True)
    case("qwen2-7b", "w4a4_lut", "2x2", expect_heads=cfg0.n_kv // 2)
    case("qwen2-7b", "w4a4_lut", "1x8", expect_heads=cfg0.n_kv)
    # SWA ring stitch + tied embeddings, int8 weights, head-sharded rings
    case("gemma2-2b", "w8a8", "2x2")
    # int8 decode KV cache: head-sharded (2x2) AND replicated (1x8) stitches
    # (scheduler oracle)
    case("qwen2-7b", "w4a4_lut", "2x2", kv_quant="int8", oracle="scheduler",
         expect_heads=cfg0.n_kv // 2)
    case("qwen2-7b", "w4a4_lut", "1x8", kv_quant="int8", oracle="scheduler")
    # 3D split-head float projections: head-parallel column split + gather
    # in front of the replicated wo3
    case("qwen2-7b", "w4a4_lut", "2x2", split3=True,
         expect_heads=cfg0.n_kv // 2)
    # zamba2: shared-attention block (head-sharded shared_k/shared_v) +
    # mamba recurrent state stitches (exact-length admission)
    case("zamba2-2.7b", "w8a8", "2x2",
         expect_heads=configs.get_config("zamba2-2.7b", smoke=True).n_kv // 2)
    print("ALL-OK")
""")


@pytest.mark.slow
def test_sharded_scheduler_bit_identical_subprocess():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _EQUIV_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "ALL-OK" in out.stdout, out.stdout


_MOE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, numpy as np
    from repro import configs
    from repro.dist import tp
    from repro.launch.mesh import make_serving_mesh, parse_mesh
    from repro.models import transformer as T
    from repro.serve import Engine, Request, Scheduler, ServeConfig, \\
        ShardedEngine

    def case(arch, quant, mesh_spec):
        cfg = dataclasses.replace(
            configs.get_config(arch, smoke=True, quant=quant),
            compute_dtype="float32")
        nd, nm = parse_mesh(mesh_spec)
        E = cfg.moe.n_experts
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        scfg = ServeConfig(max_len=32, quant=quant)
        ref = Engine(cfg, params, scfg)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 6), 0,
                                     cfg.vocab)
        want = np.asarray(ref.generate(prompts, max_new_tokens=5)[:, 6:])
        eng = ShardedEngine(cfg, params, scfg,
                            mesh=make_serving_mesh(mesh_spec))
        # expert banks really are sharded when E divides the model axis,
        # and stay replicated (not crashed) otherwise
        assert tp.has_marker(eng.params, "tp_exp") == \\
            (nm > 1 and E % nm == 0), (arch, mesh_spec)
        sched = Scheduler(eng, slots=4, chunk=2)
        reqs = [Request(prompt=np.asarray(prompts[i]).tolist(),
                        max_new_tokens=5) for i in range(4)]
        sched.submit(reqs[0]); sched.submit(reqs[1]); sched.step()
        sched.submit(reqs[2]); sched.submit(reqs[3])
        while sched.has_work:
            sched.step()
        for i, r in enumerate(reqs):
            assert r.tokens == want[i].tolist(), \\
                (arch, mesh_spec, i, r.tokens, want[i].tolist())
        # MoE routing forces the monolithic fallback: admit executable + the
        # decode-only unified step must each compile exactly once
        sizes = (eng._admit_fn._cache_size(),
                 *(f._cache_size() for f in eng._step_fns.values()))
        assert all(s == 1 for s in sizes), (arch, mesh_spec, sizes)
        if eng.head_sharded:
            k = sched.cache[0]["k"]
            assert k.sharding.shard_shape(k.shape)[-2] == cfg.n_kv // nm
        print("OK", arch, mesh_spec, "experts_sharded=",
              tp.has_marker(eng.params, "tp_exp"),
              "head_sharded=", eng.head_sharded, flush=True)

    # qwen2-moe smoke (8 experts, shared expert, qkv bias):
    #   2x2 -> expert-sharded (E/2 per shard) + head-sharded attention
    #   1x8 -> expert-sharded down to 1 expert/shard; heads fall back
    case("qwen2-moe-a2.7b", "w4a4_lut", "2x2")
    case("qwen2-moe-a2.7b", "w4a4_lut", "1x8")
    # mixtral smoke (4 experts, SWA ring, GQA kv=2):
    #   2x2 -> expert- AND head-sharded incl. the rolling-window ring
    #   1x8 -> E % 8 != 0 and n_kv % 8 != 0: everything replicated, exact
    case("mixtral-8x22b", "w8a8", "2x2")
    case("mixtral-8x22b", "w8a8", "1x8")
    print("ALL-OK")
""")


@pytest.mark.slow
def test_sharded_moe_bit_identical_subprocess():
    """Sharded MoE expert banks: temperature-0 output bit-identical to the
    single-device engine with routed experts split over the model axis
    (replicated router => identical top-k), plus the non-divisible
    fallbacks."""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _MOE_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "ALL-OK" in out.stdout, out.stdout


_SAMPLING_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, numpy as np
    from repro import configs
    from repro.launch.mesh import make_serving_mesh
    from repro.models import transformer as T
    from repro.serve import Request, Scheduler, ServeConfig, ShardedEngine

    cfg = dataclasses.replace(
        configs.get_config("qwen2-7b", smoke=True, quant="w4a4_lut"),
        compute_dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = ShardedEngine(cfg, params, ServeConfig(max_len=32, quant="w4a4_lut"),
                        mesh=make_serving_mesh("2x2"))
    sched = Scheduler(eng, slots=4, chunk=2)
    reqs = [Request(prompt=[1 + i, 2, 3, 4], max_new_tokens=4,
                    temperature=0.9, top_k=8) for i in range(4)]
    done = sched.run(reqs)
    assert len(done) == 4
    assert all(len(r.tokens) == 4 and 0 <= t < cfg.vocab
               for r in reqs for t in r.tokens)
    # slot-pool invariants hold after a sampling workload
    assert all(s is None for s in sched.slots) and not sched.queue
    print("SAMPLING-OK")
""")


@pytest.mark.slow
def test_sharded_scheduler_sampling_subprocess():
    """temperature>0 top-k decode runs sharded end-to-end (each data shard
    has its own fold-in stream; tokens are in-vocab and budgets honored)."""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SAMPLING_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "SAMPLING-OK" in out.stdout, out.stdout
