"""Sharded serving: tensor-parallel LUT matmul + data-parallel slot pool.

The multi-device equivalence suite runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI recipe), so
the main pytest process stays single-device.  It asserts, on a 2x2 AND a
1x8 (data, model) mesh:

  * temperature-0 scheduler output is BIT-identical to the single-device
    engine (static-batch ``generate`` oracle), through staggered admission,
    padded pow2 prompt buckets, gemma SWA ring stitches, tied embeddings,
    and the int8-KV decode cache;
  * no jit retrace after warmup (executor cache sizes stay 1);
  * the quantized projections really are sharded (tp leaf count > 0).

Single-device unit tests cover the param marking/spec derivation and the
engine's guard rails.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.dist import tp
from repro.launch.mesh import parse_mesh
from repro.models import transformer as T
from repro.serve.quantize import quantize_params_for_serving

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# single-device units: marking, specs, guard rails
# ---------------------------------------------------------------------------

def _quantized_smoke_params(arch="qwen2-7b", quant="w4a4_lut"):
    cfg = configs.get_config(arch, smoke=True, quant=quant)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, quantize_params_for_serving(params, mode=quant)


def test_mark_tp_params_specs_and_markers():
    cfg, qparams = _quantized_smoke_params()
    marked, specs, n = tp.mark_tp_params(qparams, 4)
    assert n > 0
    attn = marked["blocks"][0]["attn"]
    # column-parallel projection: codes + scales split on N, marker present
    assert "tp_col" in attn["wq"] and attn["wq"]["tp_col"].shape[-1] == 0
    assert specs["blocks"][0]["attn"]["wq"]["w_q"] == P(None, None, "model")
    assert specs["blocks"][0]["attn"]["wq"]["w_scale"] == P(None, None,
                                                           "model")
    # row-parallel output projection: codes split on K, scales replicated
    assert "tp_row" in attn["wo"]
    assert specs["blocks"][0]["attn"]["wo"]["w_q"] == P(None, "model", None)
    assert specs["blocks"][0]["attn"]["wo"]["w_scale"] == P()
    # lm_head (w8a8 int8) is vocab-column-parallel
    assert "tp_col" in marked["lm_head"]
    assert specs["lm_head"]["w_q"] == P(None, "model")
    # biases stay replicated (added after the gather)
    assert specs["blocks"][0]["attn"]["wq"]["b"] == P()


def test_mark_tp_params_indivisible_leaves_stay_replicated():
    cfg, qparams = _quantized_smoke_params()
    # smoke dims (64/32/128/512) don't split 7 ways: nothing shards, but the
    # tree survives untouched (replicated is always correct)
    marked, specs, n = tp.mark_tp_params(qparams, 7)
    assert n == 0
    assert "tp_col" not in marked["blocks"][0]["attn"]["wq"]
    assert specs["blocks"][0]["attn"]["wq"]["w_q"] == P()


def test_mark_tp_params_markers_are_inert_single_device():
    """Marked params outside a tp_context run exactly like unmarked ones."""
    cfg, qparams = _quantized_smoke_params()
    marked, _, n = tp.mark_tp_params(qparams, 4)
    assert n > 0
    toks = jnp.arange(6, dtype=jnp.int32)[None]
    a, _ = T.prefill(qparams, cfg, toks)
    b, _ = T.prefill(marked, cfg, toks)
    import numpy as np
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sharded_engine_guard_rails():
    from repro.serve import ServeConfig
    from repro.serve.sharded import ShardedEngine
    cfg = configs.get_config("qwen2-7b", smoke=True, quant="w4a4_lut")
    params = T.init_params(jax.random.PRNGKey(0), cfg)

    class FakeMesh:          # never reached: the quant check fires first
        shape = {"data": 2, "model": 2}

    with pytest.raises(ValueError, match="quant"):
        ShardedEngine(cfg, params, ServeConfig(max_len=16), mesh=FakeMesh())


def test_parse_mesh():
    assert parse_mesh("2x4") == (2, 4)
    assert parse_mesh("1X8") == (1, 8)
    with pytest.raises(ValueError):
        parse_mesh("8")
    with pytest.raises(ValueError):
        parse_mesh("0x4")


# ---------------------------------------------------------------------------
# multi-device equivalence (8 fake CPU devices in a subprocess)
# ---------------------------------------------------------------------------

_EQUIV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, numpy as np
    from repro import configs
    from repro.launch.mesh import make_serving_mesh
    from repro.models import transformer as T
    from repro.serve import Engine, Request, Scheduler, ServeConfig, \\
        ShardedEngine

    def case(arch, quant, mesh_spec, kv_quant="none", bucket="exact",
             slots=4, chunk=2, oracle="generate"):
        cfg = dataclasses.replace(
            configs.get_config(arch, smoke=True, quant=quant),
            compute_dtype="float32", kv_quant=kv_quant)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        scfg = ServeConfig(max_len=32, quant=quant)
        ref = Engine(cfg, params, scfg)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 6), 0,
                                     cfg.vocab)
        if oracle == "generate":
            want = np.asarray(ref.generate(prompts, max_new_tokens=5)[:, 6:])
        else:
            # int8 live KV has no static-batch analogue (generate's prefill
            # cache stays float): the oracle is the single-device scheduler
            ref_sched = Scheduler(ref, slots=slots, chunk=chunk,
                                  prompt_bucket=bucket)
            ref_reqs = [Request(prompt=np.asarray(prompts[i]).tolist(),
                                max_new_tokens=5) for i in range(4)]
            ref_sched.run(ref_reqs)
            want = np.asarray([r.tokens for r in ref_reqs])
        eng = ShardedEngine(cfg, params, scfg,
                            mesh=make_serving_mesh(mesh_spec))
        assert eng.n_tp_leaves > 0, (arch, mesh_spec)
        sched = Scheduler(eng, slots=slots, chunk=chunk, prompt_bucket=bucket)
        reqs = [Request(prompt=np.asarray(prompts[i]).tolist(),
                        max_new_tokens=5) for i in range(4)]
        # staggered admission: two requests land mid-flight
        sched.submit(reqs[0]); sched.submit(reqs[1]); sched.step()
        sched.submit(reqs[2]); sched.submit(reqs[3])
        while sched.has_work:
            sched.step()
        for i, r in enumerate(reqs):
            assert r.tokens == want[i].tolist(), \\
                (arch, mesh_spec, i, r.tokens, want[i].tolist())
        # no retrace after warmup: ONE admit executable (single prompt
        # bucket) and ONE per decode-chunk variant
        sizes = (eng._admit_fn._cache_size(),
                 *(f._cache_size() for f in eng._scan_fns.values()))
        assert all(s == 1 for s in sizes), (arch, mesh_spec, sizes)
        print("OK", arch, quant, mesh_spec, "kv=" + kv_quant,
              "tp_leaves=", eng.n_tp_leaves, flush=True)

    for mesh_spec in ("2x2", "1x8"):
        case("qwen2-7b", "w4a4_lut", mesh_spec)
    # SWA ring stitch + tied embeddings + padded pow2 buckets, int8 weights
    case("gemma2-2b", "w8a8", "2x2", bucket="pow2")
    # int8 decode KV cache under the sharded stitch (scheduler oracle)
    case("qwen2-7b", "w4a4_lut", "1x8", kv_quant="int8", oracle="scheduler")
    print("ALL-OK")
""")


@pytest.mark.slow
def test_sharded_scheduler_bit_identical_subprocess():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _EQUIV_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "ALL-OK" in out.stdout, out.stdout


_SAMPLING_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, numpy as np
    from repro import configs
    from repro.launch.mesh import make_serving_mesh
    from repro.models import transformer as T
    from repro.serve import Request, Scheduler, ServeConfig, ShardedEngine

    cfg = dataclasses.replace(
        configs.get_config("qwen2-7b", smoke=True, quant="w4a4_lut"),
        compute_dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = ShardedEngine(cfg, params, ServeConfig(max_len=32, quant="w4a4_lut"),
                        mesh=make_serving_mesh("2x2"))
    sched = Scheduler(eng, slots=4, chunk=2, prompt_bucket="exact")
    reqs = [Request(prompt=[1 + i, 2, 3, 4], max_new_tokens=4,
                    temperature=0.9, top_k=8) for i in range(4)]
    done = sched.run(reqs)
    assert len(done) == 4
    assert all(len(r.tokens) == 4 and 0 <= t < cfg.vocab
               for r in reqs for t in r.tokens)
    # slot-pool invariants hold after a sampling workload
    assert all(s is None for s in sched.slots) and not sched.queue
    print("SAMPLING-OK")
""")


@pytest.mark.slow
def test_sharded_scheduler_sampling_subprocess():
    """temperature>0 top-k decode runs sharded end-to-end (each data shard
    has its own fold-in stream; tokens are in-vocab and budgets honored)."""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SAMPLING_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "SAMPLING-OK" in out.stdout, out.stdout
