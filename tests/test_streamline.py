"""Streamlined integer-only stage == float reference, code-for-code."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.streamline import (float_stage_reference,
                                   integer_stage_forward, streamline_stage)
from repro.core.thresholds import BNParams


@given(seed=st.integers(0, 50))
@settings(max_examples=25, deadline=None)
def test_integer_stage_matches_float_reference(seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    K, N, M = 16, 8, 12
    w = jax.random.normal(ks[0], (K, N)) * 0.5
    bn = BNParams(gamma=jax.random.uniform(ks[1], (N,), minval=0.2, maxval=2.0),
                  beta=jax.random.normal(ks[2], (N,)) * 0.3,
                  mean=jax.random.normal(ks[3], (N,)) * 0.2,
                  var=jax.random.uniform(ks[4], (N,), minval=0.5, maxval=1.5))
    act_scale_in = jnp.float32(0.1)
    a_codes = jax.random.randint(ks[5], (M, K), 0, 16)

    stage = streamline_stage(w, bn, act_scale_in)
    got = integer_stage_forward(stage, a_codes, backend="ref")
    want = float_stage_reference(w, bn, act_scale_in, a_codes)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(got.min()) >= 0 and int(got.max()) <= 15


def test_integer_stage_through_pallas_interpret():
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 3)
    K, N, M = 32, 16, 8
    w = jax.random.normal(ks[0], (K, N)) * 0.3
    bn = BNParams(gamma=jnp.ones((N,)), beta=jnp.zeros((N,)),
                  mean=jnp.zeros((N,)), var=jnp.ones((N,)))
    a_codes = jax.random.randint(ks[1], (M, K), 0, 16)
    stage = streamline_stage(w, bn, jnp.float32(0.05))
    ref = integer_stage_forward(stage, a_codes, backend="ref")
    pal = integer_stage_forward(stage, a_codes, backend="interpret")
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))
