"""Quickstart: train a small LM on the synthetic pipeline, checkpoint it,
and serve greedy completions — the whole stack in ~40 lines of user code.

    PYTHONPATH=src python examples/quickstart.py [--arch qwen2-7b]

Every assigned architecture id works (smoke-sized here; the full configs are
exercised by the dry-run: ``python -m repro.launch.dryrun --all``).
"""
import argparse

import jax
import jax.numpy as jnp

from repro import configs
from repro.ckpt import checkpoint
from repro.data import pipeline
from repro.models import transformer as T
from repro.serve.engine import Engine, ServeConfig
from repro.train import loop
from repro.train.step import TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b",
                    choices=[a for a in configs.ALIASES
                             if a not in ("whisper-large-v3", "mobilenetv2")])
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_quickstart")
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, smoke=True)
    dcfg = pipeline.DataConfig(seed=0, vocab=cfg.vocab, seq_len=32,
                               global_batch=8, noise_frac=0.02)
    tcfg = TrainConfig(peak_lr=3e-3, warmup=10, total_steps=args.steps)

    result = loop.run(
        cfg, lambda: T.init_params(jax.random.PRNGKey(0), cfg), dcfg, tcfg,
        loop.RunConfig(steps=args.steps, ckpt_every=20,
                       ckpt_dir=args.ckpt_dir))
    first, last = result["history"][0], result["history"][-1]
    print(f"[train] loss {first['loss']:.3f} -> {last['loss']:.3f} "
          f"over {args.steps} steps ({1/last['wall_s']:.1f} steps/s)")

    # restore from the checkpoint we just wrote and serve
    state = {"params": T.init_params(jax.random.PRNGKey(0), cfg)}
    import repro.train.step as ts
    state = ts.init_state(state["params"])
    state, _ = checkpoint.restore(args.ckpt_dir, state)
    engine = Engine(cfg, state["params"], ServeConfig(max_len=64))
    prompt = jnp.asarray(pipeline.lm_batch(dcfg, 999)["tokens"][:2, :8])
    out = engine.generate(prompt, max_new_tokens=12)
    print("[serve] prompt :", prompt[0].tolist())
    print("[serve] output :", out[0, 8:].tolist())


if __name__ == "__main__":
    main()
