"""Fault tolerance demo: a training run with an injected mid-run failure
restarts from the last committed checkpoint and reproduces the exact loss
trajectory of an uninterrupted run; plus the straggler monitor and an
elastic (re-sharded) data pipeline restart.

    PYTHONPATH=src python examples/fault_tolerant_training.py
"""
import shutil

import jax
import numpy as np

from repro import configs
from repro.data import pipeline
from repro.models import transformer as T
from repro.train import loop
from repro.train.step import TrainConfig


def main():
    cfg = configs.get_config("minicpm-2b", smoke=True)
    dcfg = pipeline.DataConfig(seed=3, vocab=cfg.vocab, seq_len=16,
                               global_batch=4)
    tcfg = TrainConfig(total_steps=20, peak_lr=1e-3, warmup=2)
    init = lambda: T.init_params(jax.random.PRNGKey(0), cfg)

    for d in ("/tmp/ft_a", "/tmp/ft_b"):
        shutil.rmtree(d, ignore_errors=True)

    clean = loop.run(cfg, init, dcfg, tcfg,
                     loop.RunConfig(steps=14, ckpt_every=4, ckpt_dir="/tmp/ft_a",
                                    async_ckpt=False))
    faulty = loop.run(cfg, init, dcfg, tcfg,
                      loop.RunConfig(steps=14, ckpt_every=4,
                                     ckpt_dir="/tmp/ft_b", async_ckpt=False,
                                     fail_at_step=9))
    l1 = {m["step"]: m["loss"] for m in clean["history"]}
    l2 = {m["step"]: m["loss"] for m in faulty["history"]}
    drift = max(abs(l1[s] - l2[s]) for s in range(14))
    print(f"[fault] injected failure at step 9; restarts={faulty['restarts']}")
    print(f"[fault] max loss drift vs uninterrupted run: {drift:.2e} "
          f"({'BITWISE-IDENTICAL' if drift == 0 else 'tolerance-identical'})")

    # elastic restart: the same global batch assembled under 4 shards
    b2 = [pipeline.lm_batch(pipeline.DataConfig(seed=3, vocab=cfg.vocab,
                                                seq_len=16, global_batch=4,
                                                n_shards=2, shard=i), 5)
          for i in range(2)]
    b4 = [pipeline.lm_batch(pipeline.DataConfig(seed=3, vocab=cfg.vocab,
                                                seq_len=16, global_batch=4,
                                                n_shards=4, shard=i), 5)
          for i in range(4)]
    print(f"[elastic] step-5 batch under 2 shards {np.concatenate([b['tokens'] for b in b2]).shape} "
          f"vs 4 shards {np.concatenate([b['tokens'] for b in b4]).shape} — "
          "shard-count independent shapes; checkpoints restore across "
          "topologies (see tests/test_checkpoint_and_loop.py)")

    # straggler monitor
    from repro.dist.straggler import StragglerMonitor
    mon = StragglerMonitor()
    for _ in range(4):                      # 4 step windows
        for h in range(8):
            mon.record(f"host{h}", 1.0 if h != 5 else 2.4)
        rep = mon.evaluate()                # evaluated per window
    print(f"[straggler] fleet median {rep['median']:.2f}s; "
          f"excluded hosts: {rep['exclude']}")


if __name__ == "__main__":
    main()
