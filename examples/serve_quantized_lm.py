"""Serve an LM with batched requests under the paper's W4A4 LUT
multiplication (the technique as a first-class serving feature), comparing
against the bf16 baseline.

    PYTHONPATH=src python examples/serve_quantized_lm.py [--arch gemma2-2b]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import transformer as T
from repro.serve import Request, Scheduler, ServeConfig, make_engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    prompts = jax.random.randint(jax.random.PRNGKey(1), (args.batch, 8), 0,
                                 256)
    outs = {}
    for quant in ("none", "w4a4_lut"):
        cfg = configs.get_config(args.arch, smoke=True, quant=quant)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        eng = make_engine(params, cfg, ServeConfig(max_len=64))
        eng.generate(prompts, max_new_tokens=2)      # compile
        t0 = time.perf_counter()
        out = eng.generate(prompts, max_new_tokens=args.new_tokens)
        dt = time.perf_counter() - t0
        outs[quant] = np.asarray(out)
        print(f"[{quant:9s}] {args.batch * args.new_tokens / dt:7.1f} tok/s "
              f"| sample: {out[0, 8:].tolist()}")
    agree = float((outs["none"][:, 8:] == outs["w4a4_lut"][:, 8:]).mean())
    print(f"[compare ] greedy token agreement bf16 vs W4A4-LUT: {agree:.0%} "
          "(pre-QAT weights; QAT closes the gap — see "
          "examples/train_mobilenet_qat.py)")

    # continuous batching: heterogeneous budgets + streaming, one slot pool
    cfg = configs.get_config(args.arch, smoke=True)
    eng = make_engine(T.init_params(jax.random.PRNGKey(0), cfg), cfg,
                      ServeConfig(max_len=64))
    sched = Scheduler(eng, slots=args.batch, chunk=8)
    reqs = [Request(prompt=np.asarray(prompts[i]).tolist(),
                    max_new_tokens=4 + 6 * (i % 5),   # heterogeneous budgets
                    on_token=lambda r, t: None)       # streaming hook
            for i in range(args.batch)]
    t0 = time.perf_counter()
    sched.run(reqs, now=0.0)
    dt = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in reqs)
    print(f"[schedule ] continuous batching: {len(reqs)} requests, budgets "
          f"{[r.max_new_tokens for r in reqs]}, {toks / dt:7.1f} tok/s "
          f"(incl. compile) | slots reused as budgets finish")

    # paged KV cache: same scheduler, but the slots share a page pool —
    # identical greedy tokens, memory scales with resident tokens, and
    # requests sharing a prompt prefix share physical pages
    peng = make_engine(T.init_params(jax.random.PRNGKey(0), cfg), cfg,
                       ServeConfig(max_len=64, paged=True, page_size=4))
    psched = Scheduler(peng, slots=args.batch, chunk=8)
    base = np.asarray(prompts[0]).tolist()
    preqs = [Request(prompt=base + [i], max_new_tokens=8)
             for i in range(args.batch)]
    psched.run(preqs, now=0.0)
    dense_bytes = eng.kv_cache_bytes(args.batch)
    print(f"[paged    ] page pool: peak {peng.kv_cache_bytes(args.batch)} "
          f"KV bytes resident vs {dense_bytes} dense capacity | "
          f"prefix-hit rate {peng.pool.prefix_hit_rate:.0%} on shared "
          f"prompts | padding waste {psched.padding_waste:.2f}x")


if __name__ == "__main__":
    main()
