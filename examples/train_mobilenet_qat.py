"""End-to-end reproduction of the paper's design flow on MobileNetV2:

  1. train fp32 on a synthetic image task,
  2. W4A4 quantization-aware fine-tune (Sec. 3.6),
  3. export the first pointwise conv's weights as LUT6_2 INIT words — the
     actual FPGA bitstream content of Sec. 3.5 / Fig. 5.

    PYTHONPATH=src python examples/train_mobilenet_qat.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import lut
from repro.core.quantization import W4, compute_scale, quantize
from repro.data import pipeline
from repro.models import mobilenet
from repro.train.step import TrainConfig, init_state, make_train_step


def accuracy(params, cfg, dcfg, n=4):
    hits = tot = 0
    for step in range(500, 500 + n):
        b = pipeline.image_batch(dcfg, step)
        logits = mobilenet.forward(params, cfg, jnp.asarray(b["images"]))
        hits += int((np.asarray(jnp.argmax(logits, -1)) == b["labels"]).sum())
        tot += len(b["labels"])
    return hits / tot


def main():
    cfg_fp = dataclasses.replace(configs.get_config("mobilenetv2", smoke=True),
                                 quant="none")
    cfg_q = dataclasses.replace(cfg_fp, quant="qat")
    dcfg = pipeline.DataConfig(seed=0, global_batch=32)

    params = mobilenet.init_params(jax.random.PRNGKey(0), cfg_fp)
    step = jax.jit(make_train_step(cfg_fp, TrainConfig(peak_lr=2e-3, warmup=5,
                                                       total_steps=80)))
    state = init_state(params)
    for s in range(80):
        b = pipeline.image_batch(dcfg, s)
        state, m = step(state, {"images": jnp.asarray(b["images"]),
                                "labels": jnp.asarray(b["labels"])})
    print(f"[fp32] acc={accuracy(state['params'], cfg_fp, dcfg):.3f} "
          f"loss={float(m['loss']):.3f}")
    print(f"[ptq ] acc={accuracy(state['params'], cfg_q, dcfg):.3f} "
          "(4-bit post-training, no retrain)")

    qstep = jax.jit(make_train_step(cfg_q, TrainConfig(peak_lr=5e-4, warmup=2,
                                                       total_steps=60)))
    qstate = init_state(state["params"])
    for s in range(80, 140):
        b = pipeline.image_batch(dcfg, s)
        qstate, m = qstep(qstate, {"images": jnp.asarray(b["images"]),
                                   "labels": jnp.asarray(b["labels"])})
    print(f"[qat ] acc={accuracy(qstate['params'], cfg_q, dcfg):.3f} "
          "(4-bit quantization-aware)")

    # --- FPGA export: first expand conv (1x1) weights -> LUT6_2 INIT words
    w = qstate["params"]["b1_0_expand"]["w"][0, 0]        # [cin, cout]
    scale = compute_scale(w, W4)
    wq = np.asarray(quantize(w, scale, 0, W4))            # int4 codes
    pairs = wq.T.reshape(-1)[:8]                          # first 4 weight pairs
    print("[export] LUT6_2 INIT words for the first 8 int4 weights "
          "(2 weights per 4-LUT bank):")
    for i in range(0, 8, 2):
        words = lut.lut6_2_init_words(int(pairs[i]), int(pairs[i + 1]))
        print(f"  w{i}={int(pairs[i]):+d} w{i+1}={int(pairs[i+1]):+d}: "
              + " ".join(f"64'h{x:016x}" for x in words))
    n_mults = wq.size
    print(f"[export] layer total: {n_mults} multiplies -> "
          f"{n_mults * lut.luts_per_multiply(4):.0f} LUT6 (Eq. 3)")


if __name__ == "__main__":
    main()
