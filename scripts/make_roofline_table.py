"""Aggregate results/dryrun/*.json into the EXPERIMENTS.md roofline tables."""
import glob
import json
import os
import sys

HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "link_bw": 50e9}


def load(out_dir="results/dryrun", suffix="sp"):
    rows = []
    for f in sorted(glob.glob(os.path.join(out_dir, f"*__{suffix}.json"))):
        try:
            rows.append(json.load(open(f)))
        except json.JSONDecodeError:
            continue
    return rows


def fmt_row(r):
    arch, shape = r["arch"], r["shape"]
    if r["status"] == "skipped":
        return f"| {arch} | {shape} | — | — | — | — | SKIP | — | {r['reason'][:60]}… |"
    t = r["roofline"]
    terms = {"compute": t["compute_s"], "memory": t["memory_s"],
             "collective": t["collective_s"]}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    frac = t["compute_s"] / bound if bound else 0.0
    mem = r.get("memory") or {}
    gb = (mem.get("total_per_device_bytes", 0) or 0) / 1e9
    ratio = r.get("model_vs_hlo_flops")
    return (f"| {arch} | {shape} | {t['compute_s']:.2e} | {t['memory_s']:.2e} "
            f"| {t['collective_s']:.2e} | {gb:.1f} | {dom} | {frac:.2f} "
            f"| {'' if ratio is None else f'{ratio:.2f}'} |")


def main():
    suffix = sys.argv[1] if len(sys.argv) > 1 else "sp"
    rows = load(suffix=suffix)
    print("| arch | shape | compute_s | memory_s | collective_s | mem GB/dev "
          "| bottleneck | compute/bound | model/HLO flops |")
    print("|---|---|---|---|---|---|---|---|---|")
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    for r in sorted(rows, key=lambda r: (r["arch"], order.get(r["shape"], 9))):
        print(fmt_row(r))
    ok = sum(1 for r in rows if r["status"] == "ok")
    sk = sum(1 for r in rows if r["status"] == "skipped")
    print(f"\n{ok} compiled, {sk} documented skips, "
          f"{len(rows)} total recorded cells.")


if __name__ == "__main__":
    main()
